"""Data pipeline, optimizers, checkpointing, grad compression, qtensor."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint
from repro.data import DataConfig, ZipfMarkov, calibration_batches
from repro.optim import (OptimizerConfig, adamw_init, adamw_update,
                         adafactor_init, adafactor_update, lr_schedule)
from repro.optim.grad_compress import (int8_compress, int8_decompress,
                                       topk_error_feedback)
from repro.quant import QTensor


# --- data ------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=256, seq_len=32, global_batch=8, seed=3)
    gen = ZipfMarkov(cfg)
    a1, b1 = gen.batch(5)
    a2, b2 = ZipfMarkov(cfg).batch(5)          # fresh generator, same step
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    # labels are next-token
    np.testing.assert_array_equal(a1[:, 1:], b1[:, :-1])


def test_data_sharding_disjoint_streams():
    cfg = DataConfig(vocab_size=256, seq_len=16, global_batch=8)
    gen = ZipfMarkov(cfg)
    s0, _ = gen.batch(0, shard=0, num_shards=4)
    s1, _ = gen.batch(0, shard=1, num_shards=4)
    assert s0.shape == (2, 16)
    assert not np.array_equal(s0, s1)


def test_calibration_split_disjoint_from_train():
    cfg = DataConfig(vocab_size=256, seq_len=16, global_batch=4)
    train0, _ = ZipfMarkov(cfg).batch(0)
    (calib0, _), = calibration_batches(cfg, 1)
    assert not np.array_equal(train0, calib0)


def test_data_learnable_structure():
    """Markov continuation must be learnable: count repeated-transition
    consistency (the affine map is deterministic)."""
    cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=4, markov_p=1.0)
    toks, labels = ZipfMarkov(cfg).batch(0)
    # with markov_p=1 the whole sequence is the deterministic orbit
    nxt = {}
    for t, l in zip(toks.reshape(-1), labels.reshape(-1)):
        if t in nxt:
            assert nxt[t] == l
        nxt[t] = l


# --- optimizers --------------------------------------------------------------

def _quadratic_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    def loss(p):
        return jnp.mean((p["w"] + p["b"][None, :] - target) ** 2)
    return params, loss


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizers_descend(name):
    params, loss = _quadratic_problem()
    cfg = OptimizerConfig(name=name, lr=0.05, warmup_steps=0,
                          total_steps=1000, weight_decay=0.0)
    if name == "adamw":
        state, update = adamw_init(params), adamw_update
    else:
        state, update = adafactor_init(params), adafactor_update
    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, _ = update(grads, state, params, cfg)
    assert float(loss(params)) < 0.2 * l0


def test_layerwise_update_equals_direct():
    """The lax.map layer-stacked update path must be numerically identical
    to the direct path (same math, less temp memory)."""
    rng = np.random.default_rng(0)
    p3 = {"w": jnp.asarray(rng.normal(size=(6, 8, 4)), jnp.float32)}
    g3 = {"w": jnp.asarray(rng.normal(size=(6, 8, 4)), jnp.float32)}
    p2 = {"w": p3["w"].reshape(48, 4)}
    g2 = {"w": g3["w"].reshape(48, 4)}
    cfg = OptimizerConfig(lr=0.01, warmup_steps=0, clip_norm=1e9,
                          weight_decay=0.0)
    s3 = adamw_init(p3)
    s2 = adamw_init(p2)
    n3, _, _ = adamw_update(g3, s3, p3, cfg)
    n2, _, _ = adamw_update(g2, s2, p2, cfg)
    np.testing.assert_allclose(np.asarray(n3["w"]).reshape(48, 4),
                               np.asarray(n2["w"]), rtol=1e-6, atol=1e-6)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(0, 101, 5)]
    assert lrs[0] < 0.01 and abs(max(lrs) - 1.0) < 0.06
    assert abs(lrs[-1] - 0.1) < 1e-3


# --- grad compression --------------------------------------------------------

def test_int8_roundtrip(rng):
    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    q, s = int8_compress(g)
    back = int8_decompress(q, s)
    assert float(jnp.abs(back - g).max()) <= float(s) * 0.51


def test_topk_error_feedback_conserves_mass(rng):
    g = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    err = jnp.zeros_like(g)
    sparse, err2 = topk_error_feedback(g, err, 16)
    assert int((np.asarray(sparse) != 0).sum()) == 16
    np.testing.assert_allclose(np.asarray(sparse + err2), np.asarray(g),
                               rtol=1e-6, atol=1e-6)
    # feedback: a persistently-dropped coordinate accumulates and escapes
    g2 = jnp.zeros_like(g)
    total = err2
    for _ in range(20):
        sparse, total = topk_error_feedback(g2, total, 16)
    assert float(jnp.abs(total).max()) < float(jnp.abs(err2).max()) + 1e-6


# --- checkpointing -----------------------------------------------------------

def test_checkpoint_roundtrip_and_rotation():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_n=2)
        for s in (1, 2, 3):
            mgr.save(s, tree)
        assert mgr.latest_step() == 3
        assert len(os.listdir(d)) == 2          # rotation
        restored, step = mgr.restore_latest(tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_tmp_never_visible():
    tree = {"x": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 5, tree)
        assert os.path.basename(path) == "step_00000005"
        assert not any(p.endswith(".tmp") for p in os.listdir(d))


def test_checkpoint_async():
    tree = {"x": jnp.arange(10).astype(jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_n=3)
        mgr.save_async(1, tree)
        mgr.wait()
        restored, _ = mgr.restore_latest(tree)
        np.testing.assert_array_equal(np.asarray(restored["x"]),
                                      np.asarray(tree["x"]))


# --- qtensor -----------------------------------------------------------------

def test_qtensor_pack_roundtrip(rng):
    from repro.quant.qtensor import pack_int4, unpack_int4
    q = jnp.asarray(rng.integers(0, 16, size=(8, 32)), jnp.int32)
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(q))),
                                  np.asarray(q))


def test_qtensor_matches_projection(rng):
    from repro.core import projections as proj
    w = jnp.asarray(rng.normal(size=(16, 256)), jnp.float32)
    qt = QTensor.from_dense(w, 4, 128)
    np.testing.assert_allclose(np.asarray(qt.dequant()),
                               np.asarray(proj.quant_project(w, 4, 128)),
                               atol=1e-5)
    assert qt.nbytes() < w.size * 4 * 0.16      # ≥ 6× smaller than f32
