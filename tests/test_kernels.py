"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.quant.qtensor import QTensor


@pytest.mark.parametrize("m,k", [(32, 64), (100, 300), (128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_awp_pgd_step(rng, m, k, dtype):
    w = jnp.asarray(rng.normal(size=(m, k)), dtype)
    th = jnp.asarray(rng.normal(size=(m, k)), dtype)
    c = jnp.asarray(rng.normal(size=(k, k)), dtype)
    out = ops.awp_pgd_step(w, th, c, 0.17)
    oracle = ref.awp_pgd_step(w, th, c, 0.17)
    tol = 2e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("rows,d,k", [(16, 256, 128), (7, 100, 13),
                                      (32, 512, 1), (8, 64, 63)])
def test_topk_row_kernel(rng, rows, d, k):
    z = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    out = np.asarray(ops.topk_row(z, k))
    assert ((out != 0).sum(axis=1) == k).all()
    np.testing.assert_allclose(out, np.asarray(ref.topk_row(z, k)), atol=1e-6)


def test_topk_row_kernel_ties(rng):
    z = jnp.asarray(np.round(rng.normal(size=(4, 64)), 1), jnp.float32)
    out = np.asarray(ops.topk_row(z, 20))
    assert ((out != 0).sum(axis=1) == 20).all()
    # same kept-magnitude objective as the sort-based oracle
    np.testing.assert_allclose(
        np.abs(out).sum(1),
        np.sort(np.abs(np.asarray(z)), axis=1)[:, -20:].sum(1), rtol=1e-6)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("rows,d,g", [(16, 256, 128), (5, 384, 128), (8, 64, 32)])
def test_quant_proj_kernel(rng, bits, rows, d, g):
    z = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.quant_project(z, bits, g)),
                               np.asarray(ref.quant_project(z, bits, g)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,n,k", [(24, 96, 256), (8, 64, 128), (33, 50, 384)])
def test_dequant_matmul_kernel(rng, m, n, k):
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    qt = QTensor.from_dense(w, 4, 128)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    out = ops.dequant_matmul(x, qt.packed, qt.scale, qt.zero, 128)
    oracle = ref.dequant_matmul(x, qt.packed, qt.scale, qt.zero, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(oracle), np.asarray(x @ qt.dequant().T),
                               rtol=2e-4, atol=2e-4)


def test_fused_prune_loop_matches_library(rng):
    from repro.core import awp
    w = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    c = x.T @ x / 256
    eta = 2.0 / float(jnp.linalg.norm(c))
    k = 32
    th_kernel = ops.awp_prune_fused(w, c, k, eta, iters=15,
                                    theta0=ops.topk_row(w, k))
    th = ref.topk_row(w, k)
    for _ in range(15):
        th = ref.topk_row(ref.awp_pgd_step(w, th, c, eta), k)
    np.testing.assert_allclose(np.asarray(th_kernel), np.asarray(th),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,m,k", [(3, 16, 32), (5, 40, 100)])
def test_awp_pgd_step_batched(rng, b, m, k):
    """Batched grid vs the per-item 2-D kernel and the jnp oracle,
    including per-item η."""
    w = jnp.asarray(rng.normal(size=(b, m, k)), jnp.float32)
    th = jnp.asarray(rng.normal(size=(b, m, k)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(b, k, k)), jnp.float32)
    eta = jnp.asarray(rng.uniform(0.05, 0.3, size=(b,)), jnp.float32)
    out = np.asarray(ops.awp_pgd_step(w, th, c, eta))
    oracle = np.asarray(ref.awp_pgd_step(w, th, c, eta))
    np.testing.assert_allclose(out, oracle, rtol=2e-5, atol=2e-5)
    for i in range(b):
        per_item = ops.awp_pgd_step(w[i], th[i], c[i], eta[i])
        np.testing.assert_allclose(out[i], np.asarray(per_item),
                                   rtol=2e-5, atol=2e-5)


def test_pgd_fused_step_matches_jnp_step(rng):
    """pgd with PGDConfig(use_pallas=True, interpret=True) must reproduce
    the jnp-step loop: same iterate, iteration count, and gradient norm."""
    from repro.core import awp, projections as proj
    w = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(128, 32)), jnp.float32)
    c = x.T @ x / 128
    k = 16
    theta0 = ref.topk_row(w, k)
    project = lambda z, t: proj.topk_row(z, k)
    res_jnp = awp.pgd(w, c, project, theta0,
                      awp.PGDConfig(max_iters=8, tol=0.0))
    res_pal = awp.pgd(w, c, project, theta0,
                      awp.PGDConfig(max_iters=8, tol=0.0, use_pallas=True,
                                    interpret=True))
    assert int(res_pal.iters) == int(res_jnp.iters)
    np.testing.assert_allclose(np.asarray(res_pal.theta),
                               np.asarray(res_jnp.theta),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(res_pal.grad_norm),
                               float(res_jnp.grad_norm),
                               rtol=2e-3, atol=2e-3)


def test_pgd_batched_fused_step_matches_jnp_step(rng):
    from repro.core import awp, projections as proj
    w = jnp.asarray(rng.normal(size=(3, 16, 32)), jnp.float32)
    x = np.asarray(rng.normal(size=(3, 128, 32)), np.float32)
    c = jnp.asarray(np.einsum("bti,btj->bij", x, x) / 128)
    project = lambda z, t: proj.topk_row(z, 16)
    theta0 = ref.topk_row(w, 16)
    res_jnp = awp.pgd_batched(w, c, project, theta0,
                              awp.PGDConfig(max_iters=5, tol=0.0))
    res_pal = awp.pgd_batched(w, c, project, theta0,
                              awp.PGDConfig(max_iters=5, tol=0.0,
                                            use_pallas=True, interpret=True))
    np.testing.assert_allclose(np.asarray(res_pal.theta),
                               np.asarray(res_jnp.theta),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 60), st.integers(0, 2 ** 31 - 1))
def test_property_topk_kernel_vs_oracle(k, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(3, 61)), jnp.float32)
    out = np.asarray(ops.topk_row(z, k))
    assert ((out != 0).sum(axis=1) == k).all()
    np.testing.assert_allclose(out, np.asarray(ref.topk_row(z, k)), atol=1e-6)
