"""AWP algorithm tests: recipes, convergence, theory (Appendix A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import awp, calibration as calib, metrics
from repro.core.baselines import wanda, magnitude


def _problem(rng, d_in=96, d_out=48, n=512, outliers=True):
    scales = np.ones(d_in)
    if outliers:
        scales[rng.choice(d_in, d_in // 10, replace=False)] = 8.0
    x = (rng.normal(size=(n, d_in)) * scales).astype(np.float32)
    w = rng.normal(size=(d_out, d_in)).astype(np.float32)
    st_ = calib.update(calib.init(d_in), jnp.asarray(x))
    return jnp.asarray(w), calib.covariance(st_), st_


def test_prune_beats_wanda_and_magnitude(rng):
    w, c, _ = _problem(rng)
    k = 48
    l_awp = float(awp.activation_loss(w, awp.prune(w, c, k).theta, c))
    l_wanda = float(awp.activation_loss(w, wanda.prune_weight(w, c, k), c))
    l_mag = float(awp.activation_loss(w, magnitude.prune_weight(w, k), c))
    assert l_awp <= l_wanda + 1e-6
    assert l_awp <= l_mag + 1e-6


def test_prune_row_sparsity(rng):
    w, c, _ = _problem(rng)
    theta = np.asarray(awp.prune(w, c, 24).theta)
    assert ((theta != 0).sum(axis=1) <= 24).all()


def test_prune_nm(rng):
    w, c, _ = _problem(rng, d_in=64)
    theta = np.asarray(awp.prune(w, c, 32, nm=(2, 4)).theta)
    g = theta.reshape(theta.shape[0], -1, 4)
    assert ((g != 0).sum(axis=-1) <= 2).all()


def test_quantize_improves_or_matches_rtn(rng):
    from repro.core.baselines import rtn
    w, c, _ = _problem(rng, d_in=128)
    q_awp = awp.quantize(w, c, 4, group_size=64).theta
    q_rtn = rtn.quantize_weight(w, 4, 64)
    assert float(awp.activation_loss(w, q_awp, c)) <= \
        float(awp.activation_loss(w, q_rtn, c)) + 1e-6


def test_quantize_scaled_beats_plain(rng):
    w, c, stats = _problem(rng, d_in=128)
    am = calib.act_mean_abs(stats)
    l_plain = float(awp.activation_loss(w, awp.quantize(w, c, 4, group_size=64).theta, c))
    l_scaled = float(awp.activation_loss(
        w, awp.quantize_scaled(w, c, am, 4, group_size=64).theta, c))
    assert l_scaled <= l_plain + 1e-6


def test_joint_is_sparse_and_quantized(rng):
    w, c, _ = _problem(rng, d_in=128)
    theta = np.asarray(awp.joint(w, c, 64, 4, group_size=64).theta)
    assert ((theta != 0).sum(axis=1) <= 64).all()
    # nonzeros lie on a 16-level grid per (row, group): few unique values
    row = theta[0].reshape(2, 64)
    for g in row:
        nz = np.unique(g[g != 0])
        assert len(nz) <= 16


def test_fig1_loss_trace_decreases(rng):
    w, c, _ = _problem(rng)
    res = awp.prune(w, c, 48, trace_loss=True, max_iters=60)
    tr = np.asarray(res.loss_trace)
    assert tr[-1] <= tr[0]
    assert (np.diff(tr) <= 1e-4).all()          # monotone within tolerance


def test_stopping_criterion_engages(rng):
    # C = I, k = d_in: unconstrained geometric convergence — the ‖∇f‖/‖W‖
    # stopping rule must fire well before the 200-iteration cap.
    w = jnp.asarray(rng.normal(size=(16, 96)), jnp.float32)
    c = jnp.eye(96)
    res = awp.prune(w, c, 96, theta0=jnp.zeros_like(w))
    assert int(res.iters) < 200
    assert float(res.grad_norm) < 1e-4


def test_certified_eta_converges(rng):
    """Appendix A.2: with η = 1/(2λmax) the loss is non-increasing on a
    well-conditioned problem."""
    w, c, _ = _problem(rng, outliers=False)
    eta = metrics.certified_eta(c)
    from repro.core import projections as proj
    res = awp.pgd(w, c, lambda z, t: proj.topk_row(z, 48),
                  proj.topk_row(w, 48), awp.PGDConfig(max_iters=40, tol=0.0,
                                                      eta_scale=1.0,
                                                      trace_loss=True))
    # eta_scale=1.0 means η = 1/‖C‖_F ≥ certified; rerun explicitly:
    def step(theta):
        z = theta + eta * (w - theta) @ c
        return proj.topk_row(z, 48)
    theta = proj.topk_row(w, 48)
    prev = float(awp.activation_loss(w, theta, c))
    for _ in range(20):
        theta = step(theta)
        cur = float(awp.activation_loss(w, theta, c))
        assert cur <= prev + 1e-5
        prev = cur


def test_condition_number_and_eta():
    c = np.diag([1.0, 4.0]).astype(np.float32)
    assert abs(metrics.condition_number(c) - 4.0) < 1e-6
    assert abs(metrics.certified_eta(c) - 1 / 8.0) < 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_awp_never_worse_than_init(seed):
    """PGD with Wanda init must end ≤ Wanda's loss (it could only stall)."""
    rng = np.random.default_rng(seed)
    d_in = 64
    x = rng.normal(size=(256, d_in)).astype(np.float32)
    w = jnp.asarray(rng.normal(size=(16, d_in)), np.float32)
    c = calib.covariance(calib.update(calib.init(d_in), jnp.asarray(x)))
    k = 32
    init = wanda.prune_weight(w, c, k)
    res = awp.prune(w, c, k)
    assert float(awp.activation_loss(w, res.theta, c)) <= \
        float(awp.activation_loss(w, init, c)) + 1e-5
