"""Baseline implementations: SparseGPT, GPTQ, AWQ, RTN, sequential pipelines."""
import jax.numpy as jnp
import numpy as np

from repro.core import awp, calibration as calib
from repro.core.baselines import (awq, gptq, magnitude, rtn, sequential,
                                  sparsegpt, wanda)


def _problem(rng, d_in=128, d_out=64, n=1024):
    scales = np.exp(rng.normal(0, 0.7, size=d_in))
    x = (rng.normal(size=(n, d_in)) * scales).astype(np.float32)
    w = rng.normal(size=(d_out, d_in)).astype(np.float32)
    stats = calib.update(calib.init(d_in), jnp.asarray(x))
    return w, stats


def loss(w, t, c):
    return float(awp.activation_loss(jnp.asarray(w), jnp.asarray(t), c))


def test_sparsegpt_beats_magnitude(rng):
    w, stats = _problem(rng)
    c = calib.covariance(stats)
    k = 64
    l_sgpt = loss(w, sparsegpt.prune_weight(w, np.asarray(c), k), c)
    l_mag = loss(w, np.asarray(magnitude.prune_weight(jnp.asarray(w), k)), c)
    assert l_sgpt < l_mag
    out = sparsegpt.prune_weight(w, np.asarray(c), k)
    assert ((out != 0).sum(axis=1) <= k).all()


def test_gptq_beats_rtn(rng):
    w, stats = _problem(rng)
    c = calib.covariance(stats)
    l_gptq = loss(w, gptq.quantize_weight(w, np.asarray(c), 4, 64), c)
    l_rtn = loss(w, np.asarray(rtn.quantize_weight(jnp.asarray(w), 4, 64)), c)
    assert l_gptq < l_rtn


def test_awq_beats_rtn(rng):
    w, stats = _problem(rng)
    c = calib.covariance(stats)
    am = calib.act_mean_abs(stats)
    l_awq = loss(w, awq.quantize_weight(jnp.asarray(w), c, am, 4, 64), c)
    l_rtn = loss(w, np.asarray(rtn.quantize_weight(jnp.asarray(w), 4, 64)), c)
    assert l_awq <= l_rtn + 1e-7


def test_wanda_score_diag_approx(rng):
    """Wanda == magnitude pruning when activations are isotropic."""
    w = rng.normal(size=(16, 64)).astype(np.float32)
    c = jnp.eye(64)
    np.testing.assert_allclose(
        np.asarray(wanda.prune_weight(jnp.asarray(w), c, 20)),
        np.asarray(magnitude.prune_weight(jnp.asarray(w), 20)))


def test_sequential_pipelines_shapes(rng):
    w, stats = _problem(rng)
    c = calib.covariance(stats)
    am = calib.act_mean_abs(stats)
    k = 64
    wa = np.asarray(sequential.wanda_then_awq(jnp.asarray(w), c, am, k, 4, 64))
    aw = np.asarray(sequential.awq_then_wanda(jnp.asarray(w), c, am, k, 4, 64))
    assert ((wa != 0).sum(axis=1) <= k).all()
    assert ((aw != 0).sum(axis=1) <= k).all()


def test_paper_ordering_table1_style(rng):
    """Tables 1-2 ordering at small scale: activation-aware ≫ magnitude,
    AWP best, gap growing with sparsity."""
    w, stats = _problem(rng, d_in=96, d_out=48)
    c = calib.covariance(stats)
    wj = jnp.asarray(w)
    for ratio in (0.5, 0.7):
        k = int(96 * (1 - ratio))
        l_mag = loss(w, np.asarray(magnitude.prune_weight(wj, k)), c)
        l_wanda = loss(w, np.asarray(wanda.prune_weight(wj, c, k)), c)
        l_awp = loss(w, np.asarray(awp.prune(wj, c, k).theta), c)
        assert l_awp <= l_wanda <= l_mag + 1e-6
