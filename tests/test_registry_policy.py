"""The registered-method + per-layer-policy API: registry errors, policy
precedence/skip rules, pluggable methods through compress_model, and
mixed-precision packed-QTensor checkpoints served with matched logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.core import calibration as calib, registry
from repro.core.compress import (CompressionConfig, as_policy, compress_layer,
                                 compress_model)
from repro.core.specs import (JointSpec, Policy, PruneSpec, QuantSpec,
                              qualified_name, spec_from_dict)
from repro.models import build_model, make_batch


def _setup(arch="granite-8b", n_batches=2):
    cfg = get_tiny_config(arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batches = [make_batch(cfg, jax.random.PRNGKey(i), 2, 24)
               for i in range(n_batches)]
    return cfg, model, params, batches


def _layer_stats(rng, d_in=32, d_out=16, n=256):
    x = rng.normal(size=(n, d_in)).astype(np.float32)
    w = jnp.asarray(rng.normal(size=(d_out, d_in)), jnp.float32)
    return w, calib.update(calib.init(d_in), jnp.asarray(x))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_unknown_method_error_lists_registered(rng):
    w, st = _layer_stats(rng)
    with pytest.raises(ValueError) as ei:
        compress_layer(w, st, QuantSpec(method="does_not_exist"))
    msg = str(ei.value)
    assert "does_not_exist" in msg
    for name in ("awp_prune", "wanda", "rtn", "gptq"):
        assert name in msg, msg


def test_spec_method_mismatch_fails_fast(rng):
    w, st = _layer_stats(rng)
    with pytest.raises(TypeError, match="bits"):
        compress_layer(w, st, PruneSpec(method="rtn"))   # rtn needs QuantSpec
    # a JointSpec carries every field, so any method accepts it
    res = compress_layer(w, st, JointSpec(method="magnitude", ratio=0.5))
    assert res.theta is not None


def test_compress_model_validates_policy_up_front():
    cfg, model, params, batches = _setup(n_batches=1)
    with pytest.raises(TypeError, match="rtn"):
        compress_model(model, params, batches,
                       Policy({"*.mlp.*": PruneSpec(method="rtn")}))


def test_all_builtin_methods_registered():
    names = registry.available()
    assert set(names) >= {"magnitude", "wanda", "sparsegpt", "awp_prune",
                          "awp_prune_nm", "rtn", "awq", "gptq", "awp_quant",
                          "awp_quant_scaled", "awp_joint", "wanda_awq",
                          "awq_wanda"}


def test_custom_method_runs_through_compress_model():
    """A method registered OUTSIDE core/compress.py drives the full driver."""
    @registry.register("test_halve", spec_cls=QuantSpec)
    def _halve(w, stats, spec):
        return registry.CompressResult(theta=w * 0.5)

    cfg, model, params, batches = _setup(n_batches=1)
    cp, report = compress_model(model, params, batches,
                                QuantSpec(method="test_halve"))
    assert len(report) > 0
    assert all(r.method == "test_halve" for r in report)
    w0 = np.asarray(params["blocks"]["attn"]["wq"][0])
    np.testing.assert_allclose(np.asarray(cp["blocks"]["attn"]["wq"][0]),
                               w0 * 0.5, rtol=1e-6)


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------

def test_policy_first_match_precedence():
    p8, p4 = QuantSpec(bits=8), QuantSpec(bits=4)
    pol = Policy({"blocks.0.*": None,
                  "*.attn.*": p8,
                  "*": p4})
    assert pol.spec_for("blocks.0.attn.wq") is None       # skip wins: first
    assert pol.spec_for("blocks.1.attn.wq") is p8
    assert pol.spec_for("blocks.1.mlp.wu") is p4
    assert pol.spec_for("shared.attn.wq") is p8


def test_policy_default_and_aliases():
    pol = Policy({"*wq*": None}, default=PruneSpec(ratio=0.7))
    assert pol.spec_for("blocks.3.attn.wq") is None        # qualified name
    assert pol.spec_for("blocks.3.moe.wu.2", "moe_wu_2").ratio == 0.7
    assert Policy().spec_for("anything") is None           # empty: all dense


def test_policy_roundtrips_through_dict():
    pol = Policy({"blocks.0.*": None, "*.attn.*": QuantSpec(bits=8)},
                 default=JointSpec(ratio=0.25, bits=4))
    pol2 = Policy.from_dict(pol.to_dict())
    assert pol2.spec_for("blocks.0.mlp.wu") is None
    assert pol2.spec_for("blocks.2.attn.wk") == QuantSpec(bits=8)
    assert pol2.spec_for("other") == JointSpec(ratio=0.25, bits=4)
    # nm tuples survive json-ish round trips
    s = spec_from_dict(PruneSpec(nm=(2, 4)).to_dict())
    assert s.nm == (2, 4)


def test_legacy_config_converts_to_policy():
    cfg = CompressionConfig(method="awp_quant", bits=3, group_size=64,
                            skip=("wq", "wk"))
    pol = as_policy(cfg)
    assert pol.spec_for("blocks.0.attn.wq", "wq") is None
    spec = pol.spec_for("blocks.0.mlp.wu", "wu")
    assert isinstance(spec, QuantSpec) and spec.bits == 3


def test_legacy_skip_matches_short_name_only():
    """skip=("o",) must hit "wo" — NOT the "o" inside "blocks.0.…"."""
    pol = as_policy(CompressionConfig(skip=("o",)))
    assert pol.spec_for("blocks.0.attn.wo", "wo") is None
    assert pol.spec_for("blocks.0.attn.wq", "wq") is not None
    # alias_only rules survive serialization
    pol2 = Policy.from_dict(pol.to_dict())
    assert pol2.spec_for("blocks.0.attn.wq", "wq") is not None
    assert pol2.spec_for("blocks.0.attn.wo", "wo") is None


def test_qualified_names():
    assert qualified_name(("blocks", "attn", "wq"), 3) == "blocks.3.attn.wq"
    assert qualified_name(("blocks", "moe", "wu", 7), 2) == "blocks.2.moe.wu.7"
    assert qualified_name(("shared", "attn", "wq"), None) == "shared.attn.wq"


def test_policy_skips_block_and_mixes_methods():
    cfg, model, params, batches = _setup(n_batches=1)
    pol = Policy({"blocks.0.*": None,
                  "*.attn.*": PruneSpec(method="magnitude", ratio=0.5)},
                 default=QuantSpec(method="rtn", bits=4, group_size=32))
    cp, report = compress_model(model, params, batches, pol)
    names = {r.qualname for r in report}
    assert not any(n.startswith("blocks.0.") for n in names)
    methods = {r.qualname: r.method for r in report}
    assert methods["blocks.1.attn.wq"] == "magnitude"
    assert methods["blocks.1.mlp.wu"] == "rtn"
    np.testing.assert_array_equal(
        np.asarray(cp["blocks"]["attn"]["wq"][0]),
        np.asarray(params["blocks"]["attn"]["wq"][0]))


# ---------------------------------------------------------------------------
# artifacts → packed checkpoint → serving
# ---------------------------------------------------------------------------

def test_mixed_precision_packed_checkpoint_serves_matched_logits(tmp_path):
    """Acceptance: 8-bit attn / 4-bit MLP, block 0 skipped → packed QTensor
    checkpoint → restored model serves the same logits as the dequantized
    reference, bit for bit."""
    from repro.checkpoint import load_packed_checkpoint, save_packed_checkpoint
    cfg, model, params, batches = _setup(n_batches=2)
    pol = Policy({"blocks.0.*": None,
                  "*.attn.*": QuantSpec(bits=8, group_size=32),
                  "*.mlp.*": QuantSpec(bits=4, group_size=32)})
    cp, report = compress_model(model, params, batches, pol)

    arts = report.artifacts
    assert arts["blocks.1.attn.wq"].result.qtensor.bits == 8
    assert arts["blocks.1.mlp.wu"].result.qtensor.bits == 4
    assert "blocks.0.attn.wq" not in arts
    assert len(report.packed_layers()) == len(arts)

    path = save_packed_checkpoint(str(tmp_path / "ck"), 0, cp, report)
    target = model.init(jax.random.PRNGKey(1))       # different values
    loaded, qts, manifest = load_packed_checkpoint(path, target)
    assert set(qts) == set(arts)
    assert manifest["packed"]["blocks.1.attn.wq"]["bits"] == 8

    for a, b in zip(jax.tree.leaves(loaded), jax.tree.leaves(cp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    logits_packed, _ = jax.jit(model.loss)(loaded, batches[0])
    logits_ref, _ = jax.jit(model.loss)(cp, batches[0])
    assert float(logits_packed) == float(logits_ref)

    # the policy that produced the checkpoint is recorded in the manifest
    pol2 = Policy.from_dict(manifest["policy"])
    assert pol2.spec_for("blocks.0.attn.wq") is None


def test_joint_artifacts_roundtrip_with_mask(tmp_path):
    """awp_joint emits mask + QTensor; the packed checkpoint reproduces the
    sparse-and-quantized weight exactly."""
    from repro.checkpoint import load_packed_checkpoint, save_packed_checkpoint
    cfg, model, params, batches = _setup(n_batches=1)
    cp, report = compress_model(
        model, params, batches,
        JointSpec(method="awp_joint", ratio=0.5, bits=4, group_size=32))
    art = report.artifacts["blocks.0.attn.wq"]
    assert art.result.mask is not None and art.result.qtensor is not None
    assert float(np.asarray(art.result.mask).mean()) <= 0.55

    path = save_packed_checkpoint(str(tmp_path / "ck"), 0, cp, report)
    loaded, qts, _ = load_packed_checkpoint(
        path, model.init(jax.random.PRNGKey(1)))
    for a, b in zip(jax.tree.leaves(loaded), jax.tree.leaves(cp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # sparsity survives the packed round trip
    w = np.asarray(loaded["blocks"]["attn"]["wq"][0])
    assert (w == 0).mean() > 0.45


def test_dense_restore_of_packed_checkpoint_fails_loudly(tmp_path):
    """restore_checkpoint on a packed checkpoint would return zeroed
    quantized layers — it must refuse instead."""
    from repro.checkpoint import (load_packed_checkpoint, restore_checkpoint,
                                  save_packed_checkpoint)
    cfg, model, params, batches = _setup(n_batches=1)
    cp, report = compress_model(model, params, batches,
                                QuantSpec(method="rtn", bits=4, group_size=32))
    path = save_packed_checkpoint(str(tmp_path / "ck"), 0, cp, report)
    with pytest.raises(ValueError, match="packed checkpoint"):
        restore_checkpoint(path, model.init(jax.random.PRNGKey(1)))
    # and the reverse: packed loader refuses a dense checkpoint
    from repro.checkpoint import save_checkpoint
    dense_path = save_checkpoint(str(tmp_path / "dense"), 0, cp)
    with pytest.raises(ValueError, match="not a packed checkpoint"):
        load_packed_checkpoint(dense_path, model.init(jax.random.PRNGKey(1)))
