"""Runtime-sanitizer pass: the compression core under ``jax_debug_nans``
and ``jax_enable_checks``.

Why a DEDICATED, separately-marked invocation instead of flipping the
sanitizers on for everything:

* ``jax_debug_nans`` re-executes every primitive op-by-op (de-optimized)
  whenever an output contains NaN, and disables donation-friendly
  whole-program execution — the engine suites assert *compile counts* and
  the one-transfer-per-step contract, both of which the sanitizer's
  re-execution machinery perturbs;
* the serving attention path masks with intentional ``-inf`` logits and
  the fault-injection chaos suite (test_faults.py) injects NaNs ON
  PURPOSE to prove step() contains them — under ``jax_debug_nans`` those
  tests would abort inside jax instead of exercising our handling;
* ``jax_enable_checks`` adds per-op invariant checking that changes
  timings enough to matter for the bench smokes.

So the bit-parity/contract suites run clean-config, and this module — run
as its own CI step via ``-m sanitizers`` — sweeps the numeric core
(PGD pruning, quantization, batched engine, calibration) where a silent
NaN would corrupt results rather than crash.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibration, registry
from repro.core.specs import PruneSpec, QuantSpec

pytestmark = pytest.mark.sanitizers


@pytest.fixture(autouse=True)
def jax_sanitizers():
    """Enable debug_nans + enable_checks for this module only, restoring
    the clean config afterwards whatever happens."""
    old_nans = jax.config.jax_debug_nans
    old_checks = jax.config.jax_enable_checks
    jax.config.update("jax_debug_nans", True)
    jax.config.update("jax_enable_checks", True)
    try:
        yield
    finally:
        jax.config.update("jax_debug_nans", old_nans)
        jax.config.update("jax_enable_checks", old_checks)


@pytest.fixture()
def layer():
    rng = np.random.default_rng(42)
    w = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    stats = calibration.init(32)
    for _ in range(3):
        acts = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
        stats = calibration.update(stats, acts)
    return w, stats


def test_awp_prune_nan_free_under_debug_nans(layer):
    w, stats = layer
    fn = registry.get_method("awp_prune")
    res = fn(w, stats, PruneSpec(method="awp_prune", ratio=0.5))
    theta = np.asarray(res.theta)
    assert np.isfinite(theta).all()
    assert (theta != 0).sum() <= theta.size // 2 + theta.shape[0]


def test_awp_quant_nan_free_under_debug_nans(layer):
    w, stats = layer
    fn = registry.get_method("awp_quant")
    res = fn(w, stats, QuantSpec(method="awp_quant", bits=4, group_size=16))
    assert np.isfinite(np.asarray(res.theta)).all()
    assert res.qtensor is not None
    assert np.isfinite(np.asarray(res.qtensor.dequant())).all()


def test_calibration_covariance_damped_under_checks(layer):
    _w, stats = layer
    cov = calibration.covariance(stats, damp=0.01)
    assert np.isfinite(np.asarray(cov)).all()


def test_debug_nans_actually_armed():
    """Guard against the fixture silently not taking effect: an injected
    NaN must abort — otherwise this whole module is vacuous."""
    with pytest.raises(FloatingPointError):
        x = jnp.zeros((4,))
        jax.block_until_ready(x / x)
