"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement). Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_tiny_config, list_archs
from repro.models import build_model, make_batch
from repro.optim import OptimizerConfig
from repro.training.train_loop import TrainConfig, make_train_step

ARCHS = list_archs(include_paper=True)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_tiny_config(arch)
    model = build_model(cfg, remat=False)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, key, 2, 24)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    assert int(metrics["tokens"]) > 0

    step_fn, opt_init = make_train_step(
        model, TrainConfig(optimizer=OptimizerConfig(lr=1e-3)))
    state = {"params": params, "opt": opt_init(params),
             "step": jnp.zeros((), jnp.int32)}
    state, m2 = jax.jit(step_fn)(state, batch)
    assert not bool(jnp.isnan(m2["loss"])), f"{arch}: NaN after train step"
    assert int(state["step"]) == 1
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, kv: a + float(jnp.abs(kv[0] - kv[1]).sum()),
        jax.tree.map(lambda a, b: (a, b), state["params"], params), 0.0,
        is_leaf=lambda x: isinstance(x, tuple))
    assert moved > 0


@pytest.mark.parametrize("arch", ["granite-8b", "qwen3-moe-235b-a22b",
                                  "falcon-mamba-7b", "zamba2-1.2b",
                                  "musicgen-medium"])
def test_smoke_decode_matches_full_forward(arch):
    cfg = get_tiny_config(arch)
    model = build_model(cfg, remat=False)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 16
    batch = make_batch(cfg, key, B, S)
    if cfg.frontend == "audio_frames":
        pytest.skip("frame-stub frontend has no token decode path")
    tokens_only = {k: v for k, v in batch.items() if k != "labels"}
    cache = model.init_cache(B, S + 4, jnp.float32)
    logits, cache = jax.jit(model.prefill)(params, tokens_only, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    logits2, cache = jax.jit(model.decode_step)(params, tok, cache)
    full_batch = dict(tokens_only)
    full_batch["tokens"] = jnp.concatenate([tokens_only["tokens"], tok], 1)
    full = model.logits(params, full_batch)
    np.testing.assert_allclose(np.asarray(logits2[:, 0]),
                               np.asarray(full[:, -1]), rtol=6e-4, atol=6e-4)


@pytest.mark.parametrize("arch", ARCHS[:10])
def test_full_config_dims_match_assignment(arch):
    """The full configs carry the exact published dims (spot checks)."""
    cfg = get_config(arch)
    expected = {
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 131072),
        "nemotron-4-340b": (96, 18432, 96, 8, 256000),
        "starcoder2-15b": (40, 6144, 48, 4, 49152),
        "starcoder2-7b": (32, 4608, 36, 4, 49152),
        "granite-8b": (36, 4096, 32, 8, 49152),
        "falcon-mamba-7b": (64, 4096, 0, 0, 65024),
        "musicgen-medium": (48, 1536, 24, 24, 2048),
        "zamba2-1.2b": (38, 2048, 32, 32, 32000),
        "internvl2-1b": (24, 896, 14, 2, 151655),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.vocab_size) == expected


def test_param_counts_plausible():
    """Sanity: computed N is within 25% of the advertised model size."""
    targets = {"qwen3-moe-235b-a22b": 235e9, "grok-1-314b": 314e9,
               "nemotron-4-340b": 340e9, "starcoder2-15b": 15e9,
               "starcoder2-7b": 7e9, "granite-8b": 8e9,
               "falcon-mamba-7b": 7e9, "zamba2-1.2b": 1.2e9}
    for arch, n in targets.items():
        got = get_config(arch).param_count()
        assert 0.75 * n < got < 1.35 * n, (arch, got, n)
