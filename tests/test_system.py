"""End-to-end system behaviour: train a small LM on structured data, then
compress with AWP and every baseline, and check the paper's ordering claims
hold on *real* (trained-model) activation statistics; serve the compressed
model and check the quantized decode path agrees."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.core import metrics
from repro.core.compress import CompressionConfig, compress_model
from repro.data import DataConfig, ZipfMarkov, calibration_batches
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.training.train_loop import TrainConfig, make_train_step


@functools.lru_cache(maxsize=1)
def trained_model():
    cfg = get_tiny_config("llama2-7b")
    model = build_model(cfg, remat=False)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=16)
    gen = ZipfMarkov(dc)
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=20,
                                                 total_steps=400))
    step_fn, opt_init = make_train_step(model, tcfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt_init(params),
             "step": jnp.zeros((), jnp.int32)}
    jstep = jax.jit(step_fn, donate_argnums=0)
    m = {}
    for i in range(120):
        t, l = gen.batch(i)
        state, m = jstep(state, {"tokens": jnp.asarray(t),
                                 "labels": jnp.asarray(l)})
    calib = [{"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
             for t, l in calibration_batches(dc, 2)]
    eval_batches = [gen.batch(1000 + i) for i in range(4)]
    return model, state["params"], calib, eval_batches, float(m["loss"])


def _ppl(model, params, eval_batches):
    def loss_fn(params, tokens, labels):
        _, m = jax.jit(model.loss)(params, {"tokens": tokens, "labels": labels})
        return m["sum_nll"], m["tokens"]
    return metrics.perplexity(
        loss_fn, params,
        [(jnp.asarray(t), jnp.asarray(l)) for t, l in eval_batches])


def test_e2e_training_learned_structure():
    model, params, calib, eval_batches, final_loss = trained_model()
    assert final_loss < 3.5           # Zipf-Markov is learnable
    ppl = _ppl(model, params, eval_batches)
    assert ppl < np.exp(final_loss) * 1.6


def test_e2e_awp_prune_beats_magnitude_and_wanda():
    model, params, calib, eval_batches, _ = trained_model()
    base_ppl = _ppl(model, params, eval_batches)
    ppls = {}
    for method in ("magnitude", "wanda", "awp_prune"):
        ccfg = CompressionConfig(method=method, ratio=0.6)
        cp, _ = compress_model(model, params, calib, ccfg)
        ppls[method] = _ppl(model, cp, eval_batches)
    # paper Tables 1-2 ordering on trained-model statistics
    assert ppls["awp_prune"] <= ppls["wanda"] * 1.02
    assert ppls["awp_prune"] < ppls["magnitude"]
    assert ppls["awp_prune"] >= base_ppl * 0.98   # compression can't help


def test_e2e_joint_compression_runs_and_orders():
    model, params, calib, eval_batches, _ = trained_model()
    ppls = {}
    for method in ("awp_joint", "wanda_awq", "awq_wanda"):
        ccfg = CompressionConfig(method=method, ratio=0.5, bits=4,
                                 group_size=64)
        cp, reports = compress_model(model, params, calib, ccfg)
        ppls[method] = _ppl(model, cp, eval_batches)
        sp = np.mean([r.sparsity for r in reports])
        assert sp > 0.45, (method, sp)
    assert ppls["awp_joint"] <= min(ppls["wanda_awq"],
                                    ppls["awq_wanda"]) * 1.05


def test_e2e_quantized_serving_path():
    """Compress INT4, convert to packed QTensors, decode via the fused
    dequant-matmul kernel path == dense matmul on compressed weights."""
    from repro.quant import QTensor
    from repro.kernels import ops
    model, params, calib, _, _ = trained_model()
    ccfg = CompressionConfig(method="rtn", bits=4, group_size=64)
    cp, _ = compress_model(model, params, calib, ccfg)
    w = np.asarray(cp["blocks"]["mlp"]["wu"][0]).T     # paper orientation
    qt = QTensor.from_dense(jnp.asarray(w), 4, 64)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, w.shape[1])), jnp.float32)
    y_kernel = ops.dequant_matmul(x, qt.packed, qt.scale, qt.zero, 64)
    y_dense = x @ jnp.asarray(w).T
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_dense),
                               rtol=2e-3, atol=2e-3)


def test_e2e_fault_tolerant_restart_bitwise():
    """Kill-and-restore: resuming from a checkpoint + deterministic data
    reproduces the exact same parameters as an uninterrupted run."""
    import tempfile
    from repro.checkpoint import CheckpointManager
    cfg = get_tiny_config("llama32-1b")
    model = build_model(cfg, remat=False)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    gen = ZipfMarkov(dc)
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3))
    step_fn, opt_init = make_train_step(model, tcfg)
    jstep = jax.jit(step_fn)

    def fresh():
        p = model.init(jax.random.PRNGKey(1))
        return {"params": p, "opt": opt_init(p), "step": jnp.zeros((), jnp.int32)}

    def run(state, start, n):
        for i in range(start, start + n):
            t, l = gen.batch(i)
            state, _ = jstep(state, {"tokens": jnp.asarray(t),
                                     "labels": jnp.asarray(l)})
        return state

    ref = run(fresh(), 0, 8)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        half = run(fresh(), 0, 4)
        mgr.save(4, half)
        restored, step = mgr.restore_latest(half)
        resumed = run(restored, step, 4)
    np.testing.assert_array_equal(
        np.asarray(ref["params"]["blocks"]["attn"]["wq"]),
        np.asarray(resumed["params"]["blocks"]["attn"]["wq"]))
