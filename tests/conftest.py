import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N fake CPU devices.

    The main pytest process must keep seeing ONE device (smoke tests and
    benches depend on it), so every multi-device test runs isolated."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                       capture_output=True, text=True)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)
