import functools
import inspect
import itertools
import os
import random
import subprocess
import sys
import types

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


# ---------------------------------------------------------------------------
# hypothesis fallback: on a clean interpreter (no `pip install hypothesis`)
# provide a minimal shim so @given-based tests still run — each test executes
# over a few fixed, deterministic examples instead of a random search.
# conftest is imported before the test modules, so the fake lands in
# sys.modules ahead of their `from hypothesis import ...`.
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
except ImportError:
    _N_EXAMPLES = 5

    class _Strategy:
        def __init__(self, values):
            self._values = list(values)

        def examples(self, n):
            return list(itertools.islice(itertools.cycle(self._values), n))

    def _integers(min_value, max_value):
        rng = random.Random(0xA3F ^ min_value ^ max_value)
        vals = [min_value, max_value, (min_value + max_value) // 2]
        vals += [rng.randint(min_value, max_value) for _ in range(7)]
        return _Strategy(vals)

    def _sampled_from(seq):
        return _Strategy(seq)

    def _given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                cols = [s.examples(_N_EXAMPLES) for s in strategies]
                for values in zip(*cols):
                    fn(*values)
            # hide the wrapped signature or pytest would treat the
            # strategy-filled parameters as fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    def _settings(**_kwargs):
        return lambda fn: fn                  # max_examples/deadline: no-op

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N fake CPU devices.

    The main pytest process must keep seeing ONE device (smoke tests and
    benches depend on it), so every multi-device test runs isolated."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                       capture_output=True, text=True)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def assert_flat_compiles():
    """Context manager asserting an engine compiles NOTHING inside the
    block — the runtime counterpart of the ``recompile-hazard`` lint.

        with assert_flat_compiles(engine, compiled):   # baseline optional
            engine.run()

    Compares ``engine.compile_counts()`` after the block against the
    baseline (default: counts on entry) per program kind; ``None`` counts
    (cache introspection unavailable on some backends) are skipped, same
    as the historical ad-hoc assertions in test_obs.py."""
    import contextlib

    @contextlib.contextmanager
    def guard(engine, baseline=None):
        before = dict(baseline if baseline is not None
                      else engine.compile_counts())
        yield before
        after = engine.compile_counts()
        assert set(after) == set(before), (
            f"compile_counts keys changed: {sorted(before)} -> "
            f"{sorted(after)}")
        for kind, n in after.items():
            want = before[kind]
            if n is None or want is None:
                continue
            assert n == want, (
                f"post-warmup recompile: {kind} compiled {n} time(s), "
                f"expected {want}")

    return guard
