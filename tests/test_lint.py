"""The static analyzer analyzed: per-rule true-positive/true-negative
fixtures, the suppression/baseline workflow, the CLI contract, and the
tier-1 gate — a whole-tree run over THIS repo must be clean modulo the
checked-in baseline, so any new hazard fails the suite before CI."""
import json
import os
import sys
import textwrap

import pytest

from conftest import REPO

from repro.lint import core as lint
from repro.lint.astutil import Module
from repro.lint.contracts import extract_metric_uses, load_schema_families

MODULE_RULES = ("host-sync", "recompile-hazard", "tracer-leak",
                "pallas-tiling", "dtype-drift", "register-contract")

_EMPTY_SCHEMA = {"families": {"counters": [], "gauges": [],
                              "histograms": []}}


def lint_tree(tmp_path, files, schema=None, rules=None, config=None):
    """Write ``files`` ({repo-relative path: source}) under a scratch root
    shaped like this repo and lint it."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    sp = tmp_path / "scripts" / "metrics_schema.json"
    if not sp.exists():
        sp.parent.mkdir(parents=True, exist_ok=True)
        sp.write_text(json.dumps(schema or _EMPTY_SCHEMA))
    return lint.run_lint(str(tmp_path), config, rules=rules)


def rules_hit(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

def test_host_sync_item_in_jit_flagged(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/serving/x.py": """
        import jax, jax.numpy as jnp

        @jax.jit
        def step(x):
            return jnp.sum(x).item()
        """}, rules=["host-sync"])
    assert [f.rule for f in fs] == ["host-sync"]
    assert ".item()" in fs[0].message and fs[0].symbol == "step"


def test_host_sync_clean_outside_hot_paths(tmp_path):
    # same sync, but in plain host code: not a finding
    fs = lint_tree(tmp_path, {"src/repro/serving/x.py": """
        import numpy as np

        def summarize(arr):
            return float(np.asarray(arr).mean())
        """}, rules=["host-sync"])
    assert fs == []


def test_host_sync_static_args_branch_ok(tmp_path):
    # branching on a static_argnames param is NOT an implicit sync
    fs = lint_tree(tmp_path, {"src/repro/kernels/x.py": """
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("fast",))
        def f(x, fast=True):
            if fast:
                return x * 2
            return x + 1
        """}, rules=["host-sync"])
    assert fs == []


def test_host_sync_branch_on_traced_flagged(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/kernels/x.py": """
        import jax, jax.numpy as jnp

        @jax.jit
        def f(x):
            if x:
                return x * 2
            return x
        """}, rules=["host-sync"])
    assert rules_hit(fs) == {"host-sync"}
    assert "branching on a traced value" in fs[0].message


def test_host_sync_hotpath_marker_and_producer_taint(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/serving/x.py": """
        import numpy as np

        class Engine:
            def step(self):  # lint: hotpath
                out = self._decode(1)
                toks = np.asarray(out)
                return toks
        """}, rules=["host-sync"])
    assert len(fs) == 1 and "np.asarray" in fs[0].message


def test_host_sync_allow_comment_suppresses(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/serving/x.py": """
        import numpy as np

        class Engine:
            def step(self):  # lint: hotpath
                out = self._decode(1)
                toks = np.asarray(out)  # lint: allow[host-sync] one per step
                return toks
        """}, rules=["host-sync"])
    assert fs == []


# ---------------------------------------------------------------------------
# recompile-hazard
# ---------------------------------------------------------------------------

def test_recompile_jit_in_loop_flagged(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/core/x.py": """
        import jax

        def run(fns, x):
            out = []
            for f in fns:
                out.append(jax.jit(f)(x))
            return out
        """}, rules=["recompile-hazard"])
    assert rules_hit(fs) == {"recompile-hazard"}
    assert "inside a loop" in fs[0].message


def test_recompile_module_level_jit_ok(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/core/x.py": """
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("k",))
        def f(x, k):
            return x[:k]

        def run(xs):
            return [f(x, k=4) for x in xs]
        """}, rules=["recompile-hazard"])
    assert fs == []


def test_recompile_unhashable_static_arg_flagged(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/core/x.py": """
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("shape",))
        def f(x, shape):
            return x.reshape(shape)

        def run(x):
            return f(x, shape=[4, 4])
        """}, rules=["recompile-hazard"])
    assert len(fs) == 1 and "unhashable" in fs[0].message


# ---------------------------------------------------------------------------
# tracer-leak
# ---------------------------------------------------------------------------

def test_tracer_leak_self_assignment_flagged(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/serving/x.py": """
        import jax, jax.numpy as jnp

        class M:
            def go(self, x):
                @jax.jit
                def inner(x):
                    z = jnp.exp(x)
                    self.cache = z
                    return z
                return inner(x)
        """}, rules=["tracer-leak"])
    assert rules_hit(fs) == {"tracer-leak"}
    assert "self.cache" in fs[0].message


def test_tracer_leak_ref_store_is_fine(tmp_path):
    # the Pallas write idiom: subscript stores into refs are not leaks
    fs = lint_tree(tmp_path, {"src/repro/kernels/x.py": """
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def kern(x_ref, o_ref):
            acc = x_ref[...] * 2
            o_ref[...] = acc

        def call(x, spec):
            return pl.pallas_call(kern, out_shape=spec)(x)
        """}, rules=["tracer-leak"])
    assert fs == []


# ---------------------------------------------------------------------------
# pallas-tiling
# ---------------------------------------------------------------------------

BAD_KERNEL = """
    import jax
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def call(x, spec):
        return pl.pallas_call(
            kern,
            grid=(4, 4),
            in_specs=[pl.BlockSpec((8, 100), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            out_shape=spec,
        )(x)
    """


def test_pallas_tiling_misaligned_and_arity_flagged(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/kernels/x.py": BAD_KERNEL},
                   rules=["pallas-tiling"])
    msgs = " | ".join(f.message for f in fs)
    assert "not a multiple of 128" in msgs
    assert "index_map takes 1 args but grid has 2" in msgs


def test_pallas_tiling_only_checks_kernel_files(tmp_path):
    # same code outside kernels/ is out of scope for the tiling rule
    fs = lint_tree(tmp_path, {"src/repro/serving/x.py": BAD_KERNEL},
                   rules=["pallas-tiling"])
    assert fs == []


def test_pallas_tiling_aligned_kernel_clean(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/kernels/x.py": """
        import jax
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu
        import jax.numpy as jnp

        def kern(x_ref, o_ref, acc_ref):
            o_ref[...] = x_ref[...]

        def call(x, spec):
            grid = (4, 4)
            return pl.pallas_call(
                kern,
                grid=grid,
                in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, j))],
                out_specs=pl.BlockSpec((16, 256), lambda i, j: (i, j)),
                out_shape=spec,
                scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32)],
            )(x)
        """}, rules=["pallas-tiling"])
    assert fs == []


# ---------------------------------------------------------------------------
# dtype-drift
# ---------------------------------------------------------------------------

def test_dtype_drift_f64_on_jax_call_flagged(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/models/layers.py": """
        import jax.numpy as jnp

        def make(n):
            return jnp.zeros((n,), dtype=jnp.float64)
        """}, rules=["dtype-drift"])
    assert len(fs) == 1 and "float64" in fs[0].message


def test_dtype_drift_host_numpy_f64_ok(tmp_path):
    # GPTQ-style host-side f64 Hessian math is intentional
    fs = lint_tree(tmp_path, {"src/repro/core/baselines/x.py": """
        import numpy as np

        def hinv(c):
            h = np.array(c, dtype=np.float64, copy=True)
            return np.linalg.inv(h)
        """}, rules=["dtype-drift"])
    assert fs == []


def test_dtype_drift_strong_scalar_in_sensitive_file(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/models/layers.py": """
        import numpy as np

        def scale(x):
            return x * np.float32(2.0)
        """}, rules=["dtype-drift"])
    assert len(fs) == 1 and "strong-typed" in fs[0].message
    # weak-typed Python scalar: clean
    fs = lint_tree(tmp_path / "b", {"src/repro/models/layers.py": """
        def scale(x):
            return x * 2.0
        """}, rules=["dtype-drift"])
    assert fs == []


# ---------------------------------------------------------------------------
# contracts
# ---------------------------------------------------------------------------

def test_register_contract_bad_return_flagged(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/core/x.py": """
        from repro.core import registry as _registry

        @_registry.register("bad", spec_cls=None)
        def bad(w, stats, spec):
            return w * 2
        """}, rules=["register-contract"])
    assert len(fs) == 1 and "not a CompressResult" in fs[0].message


def test_register_contract_helper_indirection_ok(tmp_path):
    fs = lint_tree(tmp_path, {"src/repro/core/x.py": """
        from repro.core import registry as _registry

        def _wrap(res):
            return _registry.CompressResult(theta=res)

        @_registry.register("direct", spec_cls=None)
        def direct(w, stats, spec):
            return _registry.CompressResult(theta=w)

        @_registry.register("via_helper", spec_cls=None)
        def via_helper(w, stats, spec):
            return _wrap(w)
        """}, rules=["register-contract"])
    assert fs == []


def test_metrics_contract_bidirectional(tmp_path):
    schema = {"families": {"counters": ["good_total", "ghost_total"],
                           "gauges": [], "histograms": []}}
    fs = lint_tree(tmp_path, {"src/repro/obs/x.py": """
        def setup(m):
            m.counter("good_total", "declared")
            m.counter("rogue_total", "not declared")
        """}, schema=schema, rules=["metrics-contract"])
    msgs = " | ".join(f.message for f in fs)
    assert "rogue_total" in msgs                   # code -> schema
    assert "ghost_total" in msgs                   # schema -> code
    assert "good_total" not in msgs


def test_metric_extraction_resolves_engine_idioms(tmp_path):
    src = textwrap.dedent("""
        def setup(m):
            def counter(key, help):
                return m.counter(f"engine_{key}_total", help)
            c = {k: counter(k, h) for k, h in (
                ("alpha", "a"), ("beta", "b"))}
            m.histogram("lat_seconds", "latency")
            return c
        """)
    mod = Module("x.py", "x.py", src)
    uses = {(u.kind, u.name, u.exact) for u in extract_metric_uses(mod)}
    assert ("counters", "engine_alpha_total", True) in uses
    assert ("counters", "engine_beta_total", True) in uses
    assert ("histograms", "lat_seconds", True) in uses


# ---------------------------------------------------------------------------
# baseline workflow + fingerprints
# ---------------------------------------------------------------------------

def test_baseline_roundtrip_and_line_drift(tmp_path):
    files = {"src/repro/serving/x.py": """
        import jax, jax.numpy as jnp

        @jax.jit
        def step(x):
            return jnp.sum(x).item()
        """}
    fs = lint_tree(tmp_path, files, rules=["host-sync"])
    assert len(fs) == 1
    bp = tmp_path / "baseline.json"
    lint.save_baseline(str(bp), fs)
    baseline = lint.load_baseline(str(bp))

    # same finding, new line number (comment inserted): still baselined
    shifted = {"src/repro/serving/x.py": """
        import jax, jax.numpy as jnp

        # an unrelated comment that shifts every line
        @jax.jit
        def step(x):
            return jnp.sum(x).item()
        """}
    root2 = tmp_path / "v2"
    fs2 = lint_tree(root2, shifted, rules=["host-sync"])
    new, old, stale = lint.partition(fs2, baseline)
    assert new == [] and len(old) == 1 and stale == []

    # finding fixed: baseline entry goes stale
    fixed = {"src/repro/serving/x.py": """
        import jax, jax.numpy as jnp

        @jax.jit
        def step(x):
            return jnp.sum(x)
        """}
    root3 = tmp_path / "v3"
    fs3 = lint_tree(root3, fixed, rules=["host-sync"])
    new, old, stale = lint.partition(fs3, baseline)
    assert new == [] and old == [] and len(stale) == 1


def test_registry_rejects_duplicates_and_lists_rules():
    names = lint.available()
    for rule in MODULE_RULES + ("metrics-contract",):
        assert rule in names
    with pytest.raises(ValueError):
        lint.register("host-sync")(lambda ctx: None)


def test_severity_override_and_off():
    cfg = lint.LintConfig(severity_overrides=(
        ("src/repro/legacy/*", "host-sync", "warning"),
        ("src/repro/vendor/*", "*", "off")))
    assert cfg.severity_for("host-sync", "src/repro/legacy/a.py",
                            "error") == "warning"
    assert cfg.severity_for("dtype-drift", "src/repro/vendor/b.py",
                            "error") == "off"
    assert cfg.severity_for("host-sync", "src/repro/serving/c.py",
                            "error") == "error"


# ---------------------------------------------------------------------------
# CLI + tier-1 gate
# ---------------------------------------------------------------------------

def _cli():
    sys.path.insert(0, os.path.join(REPO, "scripts"))
    import run_lint
    return run_lint


def test_cli_whole_tree_clean_modulo_baseline():
    """The tier-1 gate: linting THIS repo with the checked-in baseline
    must be clean — a new hazard anywhere in src/repro fails here first."""
    assert _cli().main(["--format", "json"]) == 0


def test_cli_seeded_violation_exits_nonzero(tmp_path):
    bad = tmp_path / "seeded.py"
    bad.write_text(textwrap.dedent("""
        import jax, jax.numpy as jnp

        @jax.jit
        def step(x):
            return jnp.sum(x).item()
        """))
    assert _cli().main([str(bad)]) == 1


def test_cli_update_baseline_then_clean(tmp_path, capsys):
    cli = _cli()
    root = tmp_path
    (root / "src/repro").mkdir(parents=True)
    (root / "scripts").mkdir()
    (root / "scripts/metrics_schema.json").write_text(
        json.dumps(_EMPTY_SCHEMA))
    (root / "src/repro/x.py").write_text(textwrap.dedent("""
        import jax, jax.numpy as jnp

        @jax.jit
        def step(x):
            return jnp.sum(x).item()
        """))
    args = ["--root", str(root), "--baseline", "scripts/lint_baseline.json"]
    assert cli.main(args) == 1                       # new finding
    assert cli.main(args + ["--update-baseline"]) == 0
    assert cli.main(args) == 0                       # baselined now
    report = root / "report.json"
    assert cli.main(args + ["--no-baseline", "--format", "json",
                            "--output", "report.json"]) == 1
    data = json.loads(report.read_text())
    assert data["counts"]["new"] == 1
    assert data["new"][0]["rule"] == "host-sync"


def test_schema_families_match_snapshot_checker():
    """The shared contract file parses and covers the core families the
    CI snapshot checks require."""
    fams = load_schema_families(
        os.path.join(REPO, "scripts", "metrics_schema.json"))
    assert "engine_requests_total" in fams["counters"]
    assert "compress_layers_total" in fams["counters"]
    assert "request_ttft_seconds" in fams["histograms"]
    assert "engine_queue_depth" in fams["gauges"]
