"""QTensor-native serving: pytree registration, stacked per-block QTensor
leaves from packed checkpoints, packed-vs-dense logits parity through
prefill + decode_step (interpret-mode kernel), and the col_scale /
decode-tile kernel paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.core.compress import compress_model
from repro.core.specs import JointSpec, Policy, QuantSpec
from repro.models import build_model, make_batch
from repro.models.layers import expert_apply, linear_apply
from repro.quant import QTensor, matmul_impl


def _qt(rng, d_out=16, d_in=64, bits=4, group=32, **kw):
    w = jnp.asarray(rng.normal(size=(d_out, d_in)), jnp.float32)
    return w, QTensor.from_dense(w, bits, group, **kw)


# ---------------------------------------------------------------------------
# pytree registration
# ---------------------------------------------------------------------------

def test_qtensor_is_pytree_node(rng):
    w, qt = _qt(rng)
    leaves = jax.tree.leaves(qt)
    assert len(leaves) == 3                      # packed/scale/zero, no aux
    assert all(hasattr(l, "dtype") for l in leaves)
    qt2 = jax.tree.map(lambda x: x, qt)          # identity roundtrip
    assert isinstance(qt2, QTensor)
    assert (qt2.bits, qt2.group_size, qt2.shape) == (4, 32, (16, 64))
    np.testing.assert_array_equal(np.asarray(qt2.packed), np.asarray(qt.packed))
    # col_scale participates as a child when present
    _, qs = _qt(rng, col_scale=jnp.ones((64,), jnp.float32))
    assert len(jax.tree.leaves(qs)) == 4


def test_qtensor_through_jit_and_vmap(rng):
    w, qt = _qt(rng)
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)

    @jax.jit
    def f(qt, x):
        assert qt.bits == 4 and qt.shape == (16, 64)   # aux stays static
        return qt.matmul(x)

    np.testing.assert_allclose(np.asarray(f(qt, x)), np.asarray(x @ qt.dequant().T),
                               rtol=1e-5, atol=1e-5)
    # stacked children + vmap over the leading dim
    stacked = jax.tree.map(lambda a: jnp.stack([a, a, a]), qt)
    assert stacked.packed.shape == (3, 16, 32)
    y = jax.vmap(lambda q: q.matmul(x))(stacked)
    assert y.shape == (3, 8, 16)
    # tree.map slicing recovers a per-item QTensor (the block_slice pattern)
    sl = jax.tree.map(lambda a: a[1], stacked)
    assert isinstance(sl, QTensor) and sl.packed.shape == (16, 32)
    np.testing.assert_array_equal(np.asarray(sl.dequant()),
                                  np.asarray(qt.dequant()))


def test_qtensor_scan_over_stacked_leaves(rng):
    _, qt = _qt(rng)
    stacked = jax.tree.map(lambda a: jnp.stack([a, a]), qt)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)

    def body(c, q):
        return c + q.matmul(x).sum(), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), stacked)
    np.testing.assert_allclose(float(total), 2 * float(qt.matmul(x).sum()),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# linear_apply / expert_apply dispatch
# ---------------------------------------------------------------------------

def test_linear_apply_dense_and_qtensor_agree(rng):
    w, qt = _qt(rng)
    x = jnp.asarray(rng.normal(size=(2, 5, 64)), jnp.float32)
    y_dense = linear_apply(qt.dequant().T, x)          # stored orientation
    y_packed = linear_apply(qt, x)
    assert y_packed.shape == (2, 5, 16)
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_dense),
                               rtol=1e-5, atol=1e-5)
    with matmul_impl("kernel"):                        # interpret-mode Pallas
        y_kernel = linear_apply(qt, x)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)


def test_expert_apply_matches_einsum(rng):
    ws = [jnp.asarray(rng.normal(size=(24, 64)), jnp.float32)
          for _ in range(3)]                           # per-expert (f, d)
    qts = [QTensor.from_dense(w, 4, 32) for w in ws]
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *qts)
    x = jnp.asarray(rng.normal(size=(6, 64)), jnp.float32)
    dense = jnp.stack([q.dequant().T for q in qts])    # (E, d, f)
    ref = jnp.einsum("td,edf->tef", x, dense)
    out = expert_apply(stacked, x)
    assert out.shape == (6, 3, 24)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# kernel paths: col_scale pre-scaling, decode-shaped tiles
# ---------------------------------------------------------------------------

def test_kernel_matmul_col_scale_uses_kernel(rng):
    s = jnp.asarray(np.exp(rng.normal(0, 1, size=64)), jnp.float32)
    w, qt = _qt(rng, col_scale=s)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    ref = qt.matmul(x)
    out = qt.kernel_matmul(x)                          # must NOT fall back
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # odd d_in still falls back to the reference dequant
    w_odd = jnp.asarray(rng.normal(size=(4, 33)), jnp.float32)
    qt_odd = QTensor.from_dense(w_odd, 4, 11)
    x_odd = jnp.asarray(rng.normal(size=(2, 33)), jnp.float32)
    np.testing.assert_allclose(np.asarray(qt_odd.kernel_matmul(x_odd)),
                               np.asarray(qt_odd.matmul(x_odd)),
                               rtol=1e-5, atol=1e-5)


def test_decode_shaped_kernel_tiles(rng):
    """Small-M (decode) calls pick an 8-row tile and stay correct."""
    from repro.kernels.dequant_matmul import _auto_bm, dequant_matmul
    assert _auto_bm(1) == 8 and _auto_bm(8) == 8
    assert _auto_bm(9) == 16 and _auto_bm(128) == 128 and _auto_bm(4096) == 128
    w, qt = _qt(rng, d_out=32, d_in=128, group=32)
    for m in (1, 3, 8):
        x = jnp.asarray(rng.normal(size=(m, 128)), jnp.float32)
        out = dequant_matmul(x, qt.packed, qt.scale, qt.zero,
                             group_size=32, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(qt.matmul(x)),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# packed checkpoint → QTensor leaves → serving parity
# ---------------------------------------------------------------------------

def _compress_and_save(tmp_path, arch="llama32-1b", policy=None):
    from repro.checkpoint import save_packed_checkpoint
    cfg = get_tiny_config(arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batches = [make_batch(cfg, jax.random.PRNGKey(7), 2, 24)]
    policy = policy or Policy({"*.attn.*": QuantSpec(bits=8, group_size=32),
                               "*.mlp.*": QuantSpec(bits=4, group_size=32)})
    cp, report = compress_model(model, params, batches, policy)
    path = save_packed_checkpoint(str(tmp_path / "ck"), 0, cp, report)
    return cfg, model, params, batches, cp, report, path


def test_load_packed_checkpoint_returns_qtensor_leaves(tmp_path):
    from repro.checkpoint import load_packed_checkpoint
    cfg, model, params, batches, cp, report, path = _compress_and_save(tmp_path)
    target = model.init(jax.random.PRNGKey(1))
    loaded, qts, manifest = load_packed_checkpoint(path, target)
    blocks = loaded["blocks"]
    # every packed layer lives as a stacked QTensor leaf — no dense float
    for sub, names in (("attn", ("wq", "wk", "wv", "wo")),
                       ("mlp", ("wg", "wu", "wd"))):
        for n in names:
            leaf = blocks[sub][n]
            assert isinstance(leaf, QTensor), (sub, n)
            assert leaf.packed.shape[0] == cfg.num_layers   # stacked
    assert blocks["attn"]["wq"].bits == 8
    assert blocks["mlp"]["wu"].bits == 4
    # unquantized params restore densely
    assert not isinstance(blocks["attn"]["norm"], QTensor)
    np.testing.assert_array_equal(np.asarray(loaded["embed"]),
                                  np.asarray(cp["embed"]))
    assert set(qts) == set(report.artifacts)

    # materialize=True is the legacy dense escape hatch, bit-exact
    dense, _, _ = load_packed_checkpoint(path, target, materialize=True)
    for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(cp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_serving_logits_parity(tmp_path):
    """Acceptance: prefill + decode_step on the QTensor-leaf tree match the
    dense-dequant reference — on the reference impl AND the interpret-mode
    Pallas kernel (decode-shaped tiles)."""
    from repro.checkpoint import load_packed_checkpoint
    cfg, model, params, batches, cp, report, path = _compress_and_save(tmp_path)
    loaded, _, _ = load_packed_checkpoint(path, params)
    toks = batches[0]["tokens"][:, :16]
    b = toks.shape[0]

    def run(p):
        cache = model.init_cache(b, 20, jnp.float32)
        logits, cache = jax.jit(model.prefill)(p, {"tokens": toks}, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        dec, _ = jax.jit(model.decode_step)(p, tok, cache)
        return logits, dec

    ref_pre, ref_dec = run(cp)
    pre, dec = run(loaded)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(ref_pre),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref_dec),
                               rtol=1e-5, atol=1e-5)
    with matmul_impl("kernel"):
        pre_k, dec_k = run(loaded)
    np.testing.assert_allclose(np.asarray(pre_k), np.asarray(ref_pre),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(dec_k), np.asarray(ref_dec),
                               rtol=1e-3, atol=1e-3)


def test_partial_and_masked_leaves_fall_back_dense(tmp_path):
    """Leaves with an unquantized block (blocks.0.* dense) or sparsity masks
    cannot stay packed — they materialize to the legacy dense weight."""
    from repro.checkpoint import load_packed_checkpoint
    pol = Policy({"blocks.0.*": None,
                  "*.attn.*": QuantSpec(bits=4, group_size=32),
                  "*.mlp.*": JointSpec(method="awp_joint", ratio=0.5,
                                       bits=4, group_size=32)})
    cfg, model, params, batches, cp, report, path = _compress_and_save(
        tmp_path, policy=pol)
    loaded, qts, _ = load_packed_checkpoint(path, params)
    assert not isinstance(loaded["blocks"]["attn"]["wq"], QTensor)  # partial
    assert not isinstance(loaded["blocks"]["mlp"]["wu"], QTensor)   # masked
    for a, b in zip(jax.tree.leaves(loaded), jax.tree.leaves(cp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_packed_serving_parity(tmp_path):
    """Per-expert QTensors stack into (L, E, …) leaves and the masked-dense
    MoE path reads them packed."""
    from repro.checkpoint import load_packed_checkpoint
    cfg, model, params, batches, cp, report, path = _compress_and_save(
        tmp_path, arch="qwen3-moe-235b-a22b",
        policy=Policy({"*": QuantSpec(bits=4, group_size=16)}))
    loaded, _, _ = load_packed_checkpoint(path, params)
    wu = loaded["blocks"]["moe"]["wu"]
    assert isinstance(wu, QTensor)
    assert wu.packed.shape[:2] == (cfg.num_layers, cfg.num_experts)
    toks = batches[0]["tokens"][:, :8]
    b = toks.shape[0]
    cache = model.init_cache(b, 10, jnp.float32)
    ref, _ = jax.jit(model.prefill)(cp, {"tokens": toks},
                                    model.init_cache(b, 10, jnp.float32))
    out, cache = jax.jit(model.prefill)(loaded, {"tokens": toks}, cache)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    tok = jnp.argmax(out[:, -1], -1)[:, None]
    dec, _ = jax.jit(model.decode_step)(loaded, tok, cache)
    assert dec.shape == (b, 1, cfg.padded_vocab)


def test_serve_step_fns_fold_argmax(tmp_path):
    """The jitted serving steps return (B, 1) int32 tokens — greedy
    selection runs on device, matching host-side argmax of the logits."""
    from repro.launch.serve import make_step_fns, packed_weight_bytes
    from repro.checkpoint import load_packed_checkpoint
    cfg, model, params, batches, cp, report, path = _compress_and_save(tmp_path)
    loaded, _, _ = load_packed_checkpoint(path, params)
    prefill, decode = make_step_fns(model)
    toks = batches[0]["tokens"][:, :12]
    b = toks.shape[0]
    cache = model.init_cache(b, 16, jnp.float32)
    tok, cache2 = prefill(loaded, {"tokens": toks}, cache)
    assert tok.shape == (b, 1) and tok.dtype == jnp.int32
    ref_logits, ref_cache = jax.jit(model.prefill)(
        loaded, {"tokens": toks}, model.init_cache(b, 16, jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(tok), np.asarray(jnp.argmax(ref_logits[:, -1], -1)[:, None]))
    tok2, _ = decode(loaded, tok, cache2)
    ref_dec, _ = jax.jit(model.decode_step)(loaded, tok, ref_cache)
    np.testing.assert_array_equal(
        np.asarray(tok2), np.asarray(jnp.argmax(ref_dec[:, -1], -1)[:, None]))
    packed_b, dense_b = packed_weight_bytes(loaded)
    assert 0 < packed_b < dense_b
