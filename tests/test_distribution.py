"""Distribution tests (subprocess, 8 fake devices): sharding rules, MoE a2a
vs dense equivalence, row/col-sharded AWP equivalence, DDP+int8 training,
elastic checkpoint restore across mesh shapes."""
import numpy as np
import pytest

from conftest import run_multidevice
from repro.sharding import ShardingRules, rules_for_cell
from jax.sharding import PartitionSpec as P


def test_rules_adaptive_fallback_no_mesh():
    r = ShardingRules(mesh=None)
    assert r.spec(("batch", None, "tp"), (8, 4, 16)) == P(None, None, None)


def test_rules_for_cell_families():
    # no mesh: everything degrades to no-op rules
    r = rules_for_cell(None, "ssm", "train")
    assert r.mesh is None


def test_spec_divisibility_fallback():
    run_multidevice("""
import jax
from repro.launch.mesh import make_mesh
from repro import compat
from repro.sharding import ShardingRules
from jax.sharding import PartitionSpec as P
mesh = make_mesh((2, 4), ("data", "model"))
r = ShardingRules.for_mesh(mesh)
# 36 doesn't divide model=4? 36/4=9 ok; use 37 -> fallback to None
assert r.spec((None, "tp"), (8, 37)) == P(None, None)
assert r.spec((None, "tp"), (8, 36)) == P(None, "model")
# duplicate axis use: second occurrence replicates
assert r.spec(("batch", "fsdp"), (8, 8)) == P(("data",), None)
assert r.spec(("rows", None), (16, 5)) == P(("data", "model"), None)
print("ok")
""")


def test_moe_a2a_equals_dense():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_tiny_config
from repro.models.moe import moe_params, moe_apply_dense, moe_apply_a2a
from repro.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro import compat
cfg = dataclasses.replace(get_tiny_config("qwen3-moe-235b-a22b"), capacity_factor=8.0)
mesh = make_mesh((2, 4), ("data", "model"))
rules = ShardingRules.for_mesh(mesh)
p = moe_params(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 64, cfg.d_model))
y_dense = moe_apply_dense(p, x, cfg)
with compat.set_mesh(mesh):
    y_a2a = jax.jit(lambda p, x: moe_apply_a2a(p, x, cfg, rules))(p, x)
np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_a2a), rtol=2e-4, atol=2e-4)
print("ok")
""")


def test_awp_row_and_col_sharded_equal_single_device():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed as dist, projections as proj
from repro.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro import compat
mesh = make_mesh((2, 4), ("data", "model"))
rules = ShardingRules.for_mesh(mesh)
rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
x = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
c = x.T @ x / 256
k, eta, iters = 32, float(2.0 / jnp.linalg.norm(c)), 10
ref = dist.awp_prune_rowsharded_fn(k, eta, iters)(w, c)
row = dist.awp_prune_rowsharded(w, c, k, eta, iters, rules)
np.testing.assert_allclose(np.asarray(row), np.asarray(ref), rtol=2e-4, atol=2e-4)
with compat.set_mesh(mesh):
    col = jax.jit(dist.awp_prune_colsharded_fn(k, eta, iters, rules))(w, c)
np.testing.assert_allclose(np.asarray(col), np.asarray(ref), rtol=2e-4, atol=2e-4)
print("ok")
""")


def test_calib_c_distributed():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed as dist
from repro.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro import compat
mesh = make_mesh((8,), ("data",))
rules = ShardingRules.for_mesh(mesh)
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
ref = np.asarray(a.T @ a / 64)
with compat.set_mesh(mesh):
    c = jax.jit(lambda a: dist.calib_c_distributed(a, rules))(a)
np.testing.assert_allclose(np.asarray(c), ref, rtol=1e-4, atol=1e-5)
print("ok")
""")


def test_ddp_int8_training_converges():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_tiny_config
from repro.models import build_model, make_batch
from repro.training.train_loop import TrainConfig, make_train_step_ddp
from repro.optim import OptimizerConfig
from repro.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro import compat
mesh = make_mesh((8,), ("data",))
rules = ShardingRules.for_mesh(mesh)
cfg = get_tiny_config("granite-8b")
model = build_model(cfg, remat=False)
tcfg = TrainConfig(optimizer=OptimizerConfig(lr=2e-3, warmup_steps=5, total_steps=100))
step_fn, opt_init = make_train_step_ddp(model, tcfg, rules, compress="int8")
params = model.init(jax.random.PRNGKey(0))
state = {"params": params, "opt": opt_init(params), "step": jnp.zeros((), jnp.int32)}
from repro.data import DataConfig, ZipfMarkov
gen = ZipfMarkov(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=16))
with compat.set_mesh(mesh):
    jstep = jax.jit(step_fn)
    losses = []
    for i in range(25):
        t, l = gen.batch(i)
        state, m = jstep(state, {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)})
        losses.append(float(m["loss"]))
assert losses[-1] < losses[0] - 0.5, losses
print("ok", losses[0], losses[-1])
""", timeout=900)


def test_elastic_checkpoint_restore_across_meshes():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch.mesh import make_mesh
from repro import compat
rng = np.random.default_rng(0)
tree = {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)}
mesh1 = make_mesh((2, 4), ("data", "model"))
sh1 = {"w": NamedSharding(mesh1, P("data", "model"))}
t1 = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sh1)
with tempfile.TemporaryDirectory() as d:
    path = save_checkpoint(d, 1, t1)
    # restore onto a DIFFERENT mesh shape (elastic shrink/grow)
    mesh2 = make_mesh((8,), ("data",))
    sh2 = {"w": NamedSharding(mesh2, P("data", None))}
    t2 = restore_checkpoint(path, tree, sh2)
    np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(tree["w"]))
    assert t2["w"].sharding.spec == P("data", None)
print("ok")
""")


def test_sharded_train_step_matches_single_device():
    """pjit-sharded tiny train step == unsharded step (numerics)."""
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_tiny_config
from repro.models import build_model, make_batch
from repro.training.train_loop import TrainConfig, make_train_step
from repro.optim import OptimizerConfig
from repro.sharding import ShardingRules, tree_shardings
from repro.launch.mesh import make_mesh
from repro import compat
cfg = get_tiny_config("granite-8b")
mesh = make_mesh((2, 2), ("data", "model"))
rules = ShardingRules.for_mesh(mesh)
m_sharded = build_model(cfg, rules, remat=False)
m_plain = build_model(cfg, remat=False)
key = jax.random.PRNGKey(0)
params = m_plain.init(key)
batch = make_batch(cfg, key, 4, 16)
tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3))
step_s, opt_init = make_train_step(m_sharded, tcfg)
step_p, _ = make_train_step(m_plain, tcfg)
state = {"params": params, "opt": opt_init(params), "step": jnp.zeros((), jnp.int32)}
s_plain, mp = jax.jit(step_p)(state, batch)
with compat.set_mesh(mesh):
    p_sh = tree_shardings(rules, m_sharded.param_logical_axes(),
                          jax.eval_shape(m_sharded.init, key))
    sp = jax.device_put(params, p_sh)
    state_s = {"params": sp, "opt": opt_init(sp), "step": jnp.zeros((), jnp.int32)}
    b_sh = {k: NamedSharding(mesh, P(rules.batch_axes)) for k in batch}
    bs = jax.device_put(batch, b_sh)
    s_shard, ms = jax.jit(step_s)(state_s, bs)
assert abs(float(mp["loss"]) - float(ms["loss"])) < 2e-4
w1 = np.asarray(s_plain["params"]["blocks"]["attn"]["wq"])
w2 = np.asarray(s_shard["params"]["blocks"]["attn"]["wq"])
np.testing.assert_allclose(w1, w2, rtol=2e-3, atol=2e-4)
print("ok")
""", timeout=900)


def test_moe_a2a_packed_experts():
    """Stacked QTensor experts ride the all-to-all path when the expert
    count tiles the TP axis (slot factor r == 1): a2a == masked-dense on
    the same packed weights, and moe_apply auto-routes to a2a."""
    run_multidevice("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_tiny_config
from repro.models.moe import moe_params, moe_apply_dense, moe_apply_a2a, moe_apply
from repro.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro.quant import QTensor
from repro import compat
cfg = dataclasses.replace(get_tiny_config("qwen3-moe-235b-a22b"), capacity_factor=8.0)
mesh = make_mesh((2, 4), ("data", "model"))
rules = ShardingRules.for_mesh(mesh)
p = moe_params(jax.random.PRNGKey(0), cfg)
def pack(w):   # (E, d_in, d_out) dense stack -> stacked per-expert QTensor
    qts = [QTensor.from_dense(w[e].T, bits=4, group_size=16) for e in range(w.shape[0])]
    return jax.tree.map(lambda *a: jnp.stack(a), *qts)
pq = dict(p, wu=pack(p["wu"]), wd=pack(p["wd"]), wg=pack(p["wg"]))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 64, cfg.d_model))
y_dense = moe_apply_dense(pq, x, cfg)
with compat.set_mesh(mesh):
    y_a2a = jax.jit(lambda p, x: moe_apply_a2a(p, x, cfg, rules))(pq, x)
    y_auto = jax.jit(lambda p, x: moe_apply(p, x, cfg, rules))(pq, x)
np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_a2a), rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_auto), rtol=0, atol=0)
print("ok")
""", timeout=900)


def test_qtensor_logical_axes_shard_packed_leaves():
    """adapt_logical_axes expands dense leaf axes into per-child QTensor
    axes; tree_shardings then shards packed/scale/zero over TP/FSDP instead
    of replicating (the packed-checkpoint-restore path)."""
    run_multidevice("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.launch.mesh import make_mesh
from repro.sharding import ShardingRules, adapt_logical_axes, tree_specs, tree_shardings, P
from repro.quant import QTensor
mesh = make_mesh((2, 4), ("data", "model"))
rules = ShardingRules.for_mesh(mesh)
w = jax.random.normal(jax.random.PRNGKey(0), (16, 64))   # paper (d_out, d_in)
qt = QTensor.from_dense(w, bits=4, group_size=32,
                        col_scale=jnp.ones((64,), jnp.float32))
stacked = jax.tree.map(lambda a: jnp.stack([a] * 3), qt)
params = {"blocks": {"attn": {"wq": stacked, "norm": jnp.ones((3, 8))}}}
axes = {"blocks": {"attn": {"wq": (None, "fsdp", "tp"), "norm": (None, None)}}}
adapted = adapt_logical_axes(axes, params)
wq_ax = adapted["blocks"]["attn"]["wq"]
assert isinstance(wq_ax, QTensor) and wq_ax.packed == (None, "tp", "fsdp")
specs = tree_specs(rules, adapted, jax.eval_shape(lambda: params))
wq = specs["blocks"]["attn"]["wq"]
assert wq.packed == P(None, "model", ("data",))          # sharded, not replicated
assert wq.scale == P(None, "model", ("data",))
assert wq.col_scale == P(None, ("data",))
assert specs["blocks"]["attn"]["norm"] == P(None, None)
sh = tree_shardings(rules, adapted, jax.eval_shape(lambda: params))
placed = jax.device_put(params, sh)                      # actually places on mesh
assert isinstance(placed["blocks"]["attn"]["wq"], QTensor)
assert isinstance(placed["blocks"]["attn"]["wq"].packed.sharding, NamedSharding)
assert str(placed["blocks"]["attn"]["wq"].packed.sharding.spec) == str(wq.packed)
print("ok")
""", timeout=900)
