"""Continuous-batching engine: scheduler invariants, slot-cache
quantization, engine-vs-static greedy parity, per-slot sampling
determinism, and the no-recompilation-after-warmup contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import build_model
from repro.quant import matmul_impl
from repro.serving import (Engine, EngineConfig, GenerationRequest,
                           KVCacheConfig, SamplingParams, Scheduler,
                           cache_bytes, init_slot_cache, kv_dequantize,
                           kv_quantize, kv_update, sample_tokens)
from repro.serving.kv_cache import _reference_dequant
from repro.serving.scheduler import default_buckets


# ---------------------------------------------------------------------------
# shared tiny model (compiles are the dominant test cost)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_tiny_config("llama32-1b")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, lens, gens, rng=None, **sampling):
    rng = rng or np.random.default_rng(0)
    return [GenerationRequest(
                rid=i,
                prompt=rng.integers(1, cfg.vocab_size, size=l).astype(np.int32),
                max_new_tokens=g,
                sampling=SamplingParams(seed=100 + i, **sampling))
            for i, (l, g) in enumerate(zip(lens, gens))]


def _static_step_fns(model):
    from repro.launch.serve import make_step_fns
    return make_step_fns(model)


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------

def test_scheduler_fifo_admission_and_slot_reuse():
    s = Scheduler(num_slots=2, max_len=64)
    for i in range(5):
        s.submit(GenerationRequest(rid=i, prompt=np.arange(4, dtype=np.int32),
                                   max_new_tokens=4))
    a0 = s.admit()
    a1 = s.admit()
    assert a0[1].rid == 0 and a1[1].rid == 1          # FIFO order
    assert {a0[0], a1[0]} == {0, 1}                   # distinct slots
    assert s.admit() is None                          # full: no admission
    assert s.num_active == 2 and not s.idle

    freed = a0[0]
    assert s.retire(freed).rid == 0                   # eviction frees slot
    a2 = s.admit()
    assert a2[0] == freed and a2[1].rid == 2          # slot reused, FIFO kept
    for slot in list(s.active_slots()):
        s.retire(slot)
    assert s.admit()[1].rid == 3 and s.admit()[1].rid == 4
    assert s.admit() is None and len(s.queue) == 0


def test_scheduler_rejects_oversized_and_empty_requests():
    s = Scheduler(num_slots=1, max_len=16)
    with pytest.raises(ValueError):
        s.submit(GenerationRequest(rid=0, prompt=np.zeros(10, np.int32),
                                   max_new_tokens=7))   # 10 + 7 > 16
    with pytest.raises(ValueError):
        s.submit(GenerationRequest(rid=1, prompt=np.zeros(0, np.int32),
                                   max_new_tokens=4))


def test_prompt_bucketing():
    assert default_buckets(64) == (8, 16, 32, 64)
    assert default_buckets(48) == (8, 16, 32, 48)
    s = Scheduler(num_slots=1, max_len=48)
    assert s.bucket_for(1) == 8 and s.bucket_for(8) == 8
    assert s.bucket_for(9) == 16 and s.bucket_for(33) == 48
    s2 = Scheduler(num_slots=1, max_len=64, prompt_buckets=(12, 24))
    assert s2.bucket_for(5) == 12 and s2.bucket_for(13) == 24
    # prompts beyond the largest bucket are rejected at submit time (the
    # bucketed prefill pad could not hold them)
    with pytest.raises(ValueError):
        s2.submit(GenerationRequest(rid=9, prompt=np.zeros(30, np.int32),
                                    max_new_tokens=4))


# ---------------------------------------------------------------------------
# KV cache quantization
# ---------------------------------------------------------------------------

def test_kv_quantize_roundtrip_and_kernel_parity(rng):
    x = jnp.asarray(rng.normal(size=(3, 9, 2, 32)), jnp.float32)
    q = kv_quantize(x, 16)
    deq = _reference_dequant(q, jnp.float32)
    # int8 asymmetric per-group: error bounded by ~scale/2
    assert float(jnp.abs(deq - x).max()) < 0.05
    with matmul_impl("kernel"):                       # interpret-mode Pallas
        deq_k = kv_dequantize(q)
    np.testing.assert_array_equal(np.asarray(deq_k), np.asarray(deq))
    # one-sided/constant groups round-trip (the grid always includes 0, so
    # the zero-point is representable) and zero rows stay exactly zero
    c = jnp.full((1, 1, 1, 32), 3.25)
    qc = kv_quantize(c, 32)
    np.testing.assert_allclose(np.asarray(_reference_dequant(qc, jnp.float32)),
                               3.25, atol=0.02)
    z = kv_quantize(jnp.zeros((1, 1, 1, 32)), 32)
    assert float(jnp.abs(_reference_dequant(z, jnp.float32)).max()) == 0.0


def test_kv_update_scalar_and_vector_writes(rng):
    x = jnp.asarray(rng.normal(size=(2, 3, 2, 16)), jnp.float32)
    base = kv_quantize(jnp.zeros((2, 8, 2, 16)), 16)
    splice = kv_update(base, x, jnp.int32(2))         # scalar: rows 2..4
    got = _reference_dequant(splice, jnp.float32)[:, 2:5]
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_reference_dequant(
                                   kv_quantize(x, 16), jnp.float32)))
    tok = x[:, :1]
    scatter = kv_update(base, tok, jnp.asarray([1, 6]))  # per-slot positions
    deq = _reference_dequant(scatter, jnp.float32)
    ref = _reference_dequant(kv_quantize(tok, 16), jnp.float32)
    np.testing.assert_allclose(np.asarray(deq[0, 1]), np.asarray(ref[0, 0]))
    np.testing.assert_allclose(np.asarray(deq[1, 6]), np.asarray(ref[1, 0]))
    assert float(jnp.abs(deq[0, 2:]).max()) == 0.0    # rest untouched


def test_int8_cache_bytes_about_half_of_dense(tiny_lm):
    cfg, model, params = tiny_lm
    dense = init_slot_cache(cfg, KVCacheConfig(num_slots=4, max_len=32,
                                               dtype=jnp.bfloat16))
    int8 = init_slot_cache(cfg, KVCacheConfig(num_slots=4, max_len=32,
                                              quantized=True))
    ratio = cache_bytes(dense) / cache_bytes(int8)
    assert 1.5 <= ratio <= 2.0                        # ≈ half the bytes


def test_quantized_cache_logit_tolerance(tiny_lm):
    """INT8 KV cache vs dense through prefill + decode_step: prefill logits
    are exact (attention reads the fresh dense K/V), decode logits are
    within int8 tolerance."""
    cfg, model, params = tiny_lm
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    dense = model.init_cache(2, 16, jnp.float32)
    quant = init_slot_cache(cfg, KVCacheConfig(num_slots=2, max_len=16,
                                               quantized=True))
    quant["pos"] = jnp.zeros((), jnp.int32)           # static-style scalar pos
    ld, cd = jax.jit(model.prefill)(params, {"tokens": toks}, dense)
    lq, cq = jax.jit(model.prefill)(params, {"tokens": toks}, quant)
    np.testing.assert_array_equal(np.asarray(ld), np.asarray(lq))
    tok = jnp.argmax(ld[:, -1], -1)[:, None]
    dd, _ = jax.jit(model.decode_step)(params, tok, cd)
    dq, _ = jax.jit(model.decode_step)(params, tok, cq)
    scale = float(jnp.abs(dd).max())
    assert float(jnp.abs(dd - dq).max()) < 0.05 * scale


# ---------------------------------------------------------------------------
# engine: greedy parity, slot reuse, no recompilation
# ---------------------------------------------------------------------------

def test_engine_greedy_matches_static_path(tiny_lm):
    """Acceptance: mixed-length trace through 4 slots (requests > slots, so
    slots get reused mid-run) — every request's greedy output bit-identical
    to the static path, with zero recompilation after warmup."""
    cfg, model, params = tiny_lm
    max_len = 64
    reqs = _requests(cfg, lens=[5, 13, 8, 21, 3, 16, 9, 30],
                     gens=[6, 3, 9, 4, 8, 5, 2, 7])
    engine = Engine(model, params, EngineConfig(num_slots=4, max_len=max_len))
    compiled = engine.warmup(reqs)
    assert compiled["decode"] == 1                    # one program for all slots

    for r in reqs:
        engine.submit(r)
    results = engine.run()
    assert engine.compile_counts() == compiled        # no recompilation
    assert len(results) == len(reqs)
    by_rid = {r.rid: r for r in results}
    from repro.launch.serve import static_greedy_reference
    step_fns = _static_step_fns(model)        # hoisted: compile once
    for req in reqs:
        got = by_rid[req.rid].tokens
        assert len(got) == req.max_new_tokens
        assert got == static_greedy_reference(model, params, req, max_len,
                                              step_fns), req.rid
    assert engine.scheduler.idle
    assert 0.0 < engine.utilization() <= 1.0


def test_engine_warmup_fits_tight_budgets(tiny_lm):
    """Warmup clones must respect prompt_len + max_new <= max_len even when
    the trace's requests leave no decode headroom (gen=1 at a full-length
    prompt): the clone's budget is clipped and decode still gets compiled
    via the minimal fallback request."""
    cfg, model, params = tiny_lm
    engine = Engine(model, params, EngineConfig(num_slots=2, max_len=16))
    reqs = _requests(cfg, lens=[15, 4], gens=[1, 2])
    compiled = engine.warmup(reqs)                    # must not raise
    assert compiled["decode"] == 1
    for r in reqs:
        engine.submit(r)
    results = engine.run()
    assert engine.compile_counts() == compiled
    assert sorted(len(r.tokens) for r in results) == [1, 2]


def test_engine_int8_cache_completes_with_half_bytes(tiny_lm):
    cfg, model, params = tiny_lm
    reqs = _requests(cfg, lens=[5, 13, 8, 21], gens=[6, 3, 9, 4])
    dense = Engine(model, params,
                   EngineConfig(num_slots=4, max_len=64,
                                kv_dtype=jnp.bfloat16))
    int8 = Engine(model, params,
                  EngineConfig(num_slots=4, max_len=64, kv_quantized=True))
    for r in reqs:
        int8.submit(r)
    res = int8.run()
    assert sorted(len(r.tokens) for r in res) == sorted(
        r.max_new_tokens for r in reqs)
    ratio = dense.kv_cache_bytes() / int8.kv_cache_bytes()
    assert 1.5 <= ratio <= 2.0


# ---------------------------------------------------------------------------
# per-slot sampling
# ---------------------------------------------------------------------------

def test_sample_tokens_semantics(rng):
    logits = jnp.asarray(rng.normal(size=(4, 64)) * 3, jnp.float32)
    temps = jnp.asarray([0.0, 1.0, 1.0, 0.7])
    topks = jnp.asarray([0, 0, 5, 1])
    seeds = jnp.asarray([0, 7, 7, 9], jnp.uint32)
    steps = jnp.asarray([0, 3, 3, 1], jnp.uint32)
    a = sample_tokens(logits, temps, topks, seeds, steps)
    b = sample_tokens(logits, temps, topks, seeds, steps)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))  # deterministic
    assert int(a[0]) == int(jnp.argmax(logits[0]))    # temp 0 → greedy
    assert int(a[3]) == int(jnp.argmax(logits[3]))    # top_k 1 → greedy
    # the key depends on (seed, step), never the slot: permutation-invariant
    perm = jnp.asarray([2, 0, 3, 1])
    c = sample_tokens(logits[perm], temps[perm], topks[perm], seeds[perm],
                      steps[perm])
    np.testing.assert_array_equal(np.asarray(c), np.asarray(a)[np.asarray(perm)])
    # top-k actually truncates: k=5 samples land in the top 5
    many = sample_tokens(jnp.tile(logits[2][None], (32, 1)),
                         jnp.full((32,), 1.5), jnp.full((32,), 5),
                         jnp.arange(32, dtype=jnp.uint32),
                         jnp.zeros((32,), jnp.uint32))
    top5 = set(np.asarray(jax.lax.top_k(logits[2], 5)[1]).tolist())
    assert set(np.asarray(many).tolist()) <= top5


def test_engine_sampling_deterministic_across_runs(tiny_lm):
    """Fixed per-request keys: two engines over the same sampled trace
    produce identical token streams (key = fold_in(seed, token index),
    independent of slot placement)."""
    cfg, model, params = tiny_lm

    def run(slots):
        rng = np.random.default_rng(3)
        reqs = _requests(cfg, lens=[5, 13, 8, 21, 9], gens=[6, 3, 9, 4, 5],
                         rng=rng, temperature=0.9, top_k=8)
        eng = Engine(model, params, EngineConfig(num_slots=slots, max_len=64))
        for r in reqs:
            eng.submit(r)
        return {r.rid: r.tokens for r in eng.run()}

    a = run(slots=4)
    b = run(slots=2)          # different slot layout, same keys
    assert a == b
    assert any(len(set(t)) > 1 or len(t) == 1 for t in a.values())
