"""Continuous-batching engine: scheduler invariants, slot-cache
quantization, engine-vs-static greedy parity, per-slot sampling
determinism, and the no-recompilation-after-warmup contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import build_model
from repro.quant import matmul_impl
from repro.serving import (Engine, EngineConfig, GenerationRequest,
                           KVCacheConfig, SamplingParams, Scheduler,
                           cache_bytes, init_slot_cache, kv_dequantize,
                           kv_quantize, kv_update, sample_tokens)
from repro.serving.kv_cache import _reference_dequant
from repro.serving.scheduler import default_buckets


# ---------------------------------------------------------------------------
# shared tiny model (compiles are the dominant test cost)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_tiny_config("llama32-1b")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, lens, gens, rng=None, **sampling):
    rng = rng or np.random.default_rng(0)
    return [GenerationRequest(
                rid=i,
                prompt=rng.integers(1, cfg.vocab_size, size=l).astype(np.int32),
                max_new_tokens=g,
                sampling=SamplingParams(seed=100 + i, **sampling))
            for i, (l, g) in enumerate(zip(lens, gens))]


def _static_step_fns(model):
    from repro.launch.serve import make_step_fns
    return make_step_fns(model)


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------

def test_scheduler_fifo_admission_and_slot_reuse():
    s = Scheduler(num_slots=2, max_len=64)
    for i in range(5):
        s.submit(GenerationRequest(rid=i, prompt=np.arange(4, dtype=np.int32),
                                   max_new_tokens=4))
    a0 = s.admit()
    a1 = s.admit()
    assert a0[1].rid == 0 and a1[1].rid == 1          # FIFO order
    assert {a0[0], a1[0]} == {0, 1}                   # distinct slots
    assert s.admit() is None                          # full: no admission
    assert s.num_active == 2 and not s.idle

    freed = a0[0]
    assert s.retire(freed).rid == 0                   # eviction frees slot
    a2 = s.admit()
    assert a2[0] == freed and a2[1].rid == 2          # slot reused, FIFO kept
    for slot in list(s.active_slots()):
        s.retire(slot)
    assert s.admit()[1].rid == 3 and s.admit()[1].rid == 4
    assert s.admit() is None and len(s.queue) == 0


def test_scheduler_rejects_oversized_empty_and_zero_budget_requests():
    s = Scheduler(num_slots=1, max_len=16)
    with pytest.raises(ValueError):
        s.submit(GenerationRequest(rid=0, prompt=np.zeros(10, np.int32),
                                   max_new_tokens=7))   # 10 + 7 > 16
    with pytest.raises(ValueError):
        s.submit(GenerationRequest(rid=1, prompt=np.zeros(0, np.int32),
                                   max_new_tokens=4))
    # a max_new_tokens=0 request would still emit one token (prefill
    # samples unconditionally) — rejected at submit time
    with pytest.raises(ValueError):
        s.submit(GenerationRequest(rid=2, prompt=np.ones(4, np.int32),
                                   max_new_tokens=0))


def test_prompt_bucketing():
    assert default_buckets(64) == (8, 16, 32, 64)
    assert default_buckets(48) == (8, 16, 32, 48)
    s = Scheduler(num_slots=1, max_len=48)
    assert s.bucket_for(1) == 8 and s.bucket_for(8) == 8
    assert s.bucket_for(9) == 16 and s.bucket_for(33) == 48
    s2 = Scheduler(num_slots=1, max_len=64, prompt_buckets=(12, 24))
    assert s2.bucket_for(5) == 12 and s2.bucket_for(13) == 24
    # beyond the largest bucket: admitted (chunked prefill at the largest
    # bucket's width), no longer rejected
    s2.submit(GenerationRequest(rid=9, prompt=np.zeros(30, np.int32),
                                max_new_tokens=4))
    assert s2.bucket_for(30) == 24
    batch = s2.admit_batch()
    assert batch.chunked and batch.bucket == 24
    assert [r.rid for _, r in batch.items] == [9]
    # a bucket wider than max_len would silently clip live prompt tokens at
    # the cache edge — rejected at construction instead
    with pytest.raises(ValueError):
        Scheduler(num_slots=1, max_len=16, prompt_buckets=(8, 32))


def test_scheduler_admit_batch_groups_fifo_head_run():
    s = Scheduler(num_slots=4, max_len=64)          # buckets 8/16/32/64
    lens = [5, 8, 13, 7, 6]                          # buckets 8,8,16,8,8
    for i, l in enumerate(lens):
        s.submit(GenerationRequest(rid=i, prompt=np.ones(l, np.int32),
                                   max_new_tokens=2))
    b0 = s.admit_batch()                             # head-run: rids 0,1
    assert not b0.chunked and b0.bucket == 8
    assert [r.rid for _, r in b0.items] == [0, 1]    # stops at rid 2 (b16)
    b1 = s.admit_batch()
    assert b1.bucket == 16 and [r.rid for _, r in b1.items] == [2]
    b2 = s.admit_batch()                             # free-list caps the run
    assert b2.bucket == 8 and [r.rid for _, r in b2.items] == [3]
    assert s.admit_batch() is None and len(s.queue) == 1
    for slot, _ in b0.items:
        s.retire(slot)
    b3 = s.admit_batch()
    assert [r.rid for _, r in b3.items] == [4]


# ---------------------------------------------------------------------------
# KV cache quantization
# ---------------------------------------------------------------------------

def test_kv_quantize_roundtrip_and_kernel_parity(rng):
    x = jnp.asarray(rng.normal(size=(3, 9, 2, 32)), jnp.float32)
    q = kv_quantize(x, 16)
    deq = _reference_dequant(q, jnp.float32)
    # int8 asymmetric per-group: error bounded by ~scale/2
    assert float(jnp.abs(deq - x).max()) < 0.05
    with matmul_impl("kernel"):                       # interpret-mode Pallas
        deq_k = kv_dequantize(q)
    np.testing.assert_array_equal(np.asarray(deq_k), np.asarray(deq))
    # one-sided/constant groups round-trip (the grid always includes 0, so
    # the zero-point is representable) and zero rows stay exactly zero
    c = jnp.full((1, 1, 1, 32), 3.25)
    qc = kv_quantize(c, 32)
    np.testing.assert_allclose(np.asarray(_reference_dequant(qc, jnp.float32)),
                               3.25, atol=0.02)
    z = kv_quantize(jnp.zeros((1, 1, 1, 32)), 32)
    assert float(jnp.abs(_reference_dequant(z, jnp.float32)).max()) == 0.0


def test_kv_update_scalar_and_vector_writes(rng):
    x = jnp.asarray(rng.normal(size=(2, 3, 2, 16)), jnp.float32)
    base = kv_quantize(jnp.zeros((2, 8, 2, 16)), 16)
    splice = kv_update(base, x, jnp.int32(2))         # scalar: rows 2..4
    got = _reference_dequant(splice, jnp.float32)[:, 2:5]
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_reference_dequant(
                                   kv_quantize(x, 16), jnp.float32)))
    tok = x[:, :1]
    scatter = kv_update(base, tok, jnp.asarray([1, 6]))  # per-slot positions
    deq = _reference_dequant(scatter, jnp.float32)
    ref = _reference_dequant(kv_quantize(tok, 16), jnp.float32)
    np.testing.assert_allclose(np.asarray(deq[0, 1]), np.asarray(ref[0, 0]))
    np.testing.assert_allclose(np.asarray(deq[1, 6]), np.asarray(ref[1, 0]))
    assert float(jnp.abs(deq[0, 2:]).max()) == 0.0    # rest untouched


def test_write_slot_batched_matches_sequential(rng, tiny_lm):
    """One batched write_slot dispatch (B rows) is bit-identical to B
    sequential single-slot splices, for dense AND INT8 QuantizedKV storage;
    padding rows (slot == num_slots) are dropped."""
    cfg, _, _ = tiny_lm
    from repro.serving import write_slot
    for quantized in (False, True):
        cache = init_slot_cache(cfg, KVCacheConfig(num_slots=4, max_len=32,
                                                   quantized=quantized))
        kv = {"k": cache["k"], "v": cache["v"]}
        shape = (cfg.num_layers, 3, 8, cfg.num_kv_heads,
                 cfg.resolved_head_dim)
        k_new = jnp.asarray(rng.normal(size=shape), jnp.float32)
        v_new = jnp.asarray(rng.normal(size=shape), jnp.float32)
        slots = jnp.asarray([2, 0, 4])               # 4 == num_slots: pad row
        batched = write_slot(kv, slots, k_new, v_new)
        seq = kv
        for i in (0, 1):                              # pad row never written
            seq = write_slot(seq, jnp.int32(int(slots[i])),
                             k_new[:, i:i + 1], v_new[:, i:i + 1])
        for name in ("k", "v"):
            for a, b in zip(jax.tree.leaves(batched[name]),
                            jax.tree.leaves(seq[name])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_slot_rows_roundtrip(rng, tiny_lm):
    """slot_rows/set_slot_rows (the chunked prefill's working view) slice
    and splice one slot's rows exactly, dense and quantized."""
    cfg, _, _ = tiny_lm
    from repro.serving import set_slot_rows, slot_rows
    for quantized in (False, True):
        cache = init_slot_cache(cfg, KVCacheConfig(num_slots=3, max_len=16,
                                                   quantized=quantized))
        entry = cache["k"]
        row = slot_rows(entry, jnp.int32(1))
        leaves = jax.tree.leaves(row)
        assert all(l.shape[1] == 1 for l in leaves)
        bumped = jax.tree.map(lambda x: x + 1, row)
        back = set_slot_rows(entry, jnp.int32(1), bumped)
        for a, b in zip(jax.tree.leaves(slot_rows(back, jnp.int32(1))),
                        jax.tree.leaves(bumped)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(slot_rows(back, jnp.int32(0))),
                        jax.tree.leaves(slot_rows(entry, jnp.int32(0)))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_cache_bytes_about_half_of_dense(tiny_lm):
    cfg, model, params = tiny_lm
    dense = init_slot_cache(cfg, KVCacheConfig(num_slots=4, max_len=32,
                                               dtype=jnp.bfloat16))
    int8 = init_slot_cache(cfg, KVCacheConfig(num_slots=4, max_len=32,
                                              quantized=True))
    ratio = cache_bytes(dense) / cache_bytes(int8)
    assert 1.5 <= ratio <= 2.0                        # ≈ half the bytes


def test_quantized_cache_logit_tolerance(tiny_lm):
    """INT8 KV cache vs dense through prefill + decode_step: prefill logits
    are exact (attention reads the fresh dense K/V), decode logits are
    within int8 tolerance."""
    cfg, model, params = tiny_lm
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    dense = model.init_cache(2, 16, jnp.float32)
    quant = init_slot_cache(cfg, KVCacheConfig(num_slots=2, max_len=16,
                                               quantized=True))
    quant["pos"] = jnp.zeros((), jnp.int32)           # static-style scalar pos
    ld, cd = jax.jit(model.prefill)(params, {"tokens": toks}, dense)
    lq, cq = jax.jit(model.prefill)(params, {"tokens": toks}, quant)
    np.testing.assert_array_equal(np.asarray(ld), np.asarray(lq))
    tok = jnp.argmax(ld[:, -1], -1)[:, None]
    dd, _ = jax.jit(model.decode_step)(params, tok, cd)
    dq, _ = jax.jit(model.decode_step)(params, tok, cq)
    scale = float(jnp.abs(dd).max())
    assert float(jnp.abs(dd - dq).max()) < 0.05 * scale


# ---------------------------------------------------------------------------
# engine: greedy parity, slot reuse, no recompilation
# ---------------------------------------------------------------------------

def test_engine_greedy_matches_static_path(tiny_lm, assert_flat_compiles):
    """Acceptance: mixed-length trace through 4 slots (requests > slots, so
    slots get reused mid-run) — every request's greedy output bit-identical
    to the static path, with zero recompilation after warmup."""
    cfg, model, params = tiny_lm
    max_len = 64
    reqs = _requests(cfg, lens=[5, 13, 8, 21, 3, 16, 9, 30],
                     gens=[6, 3, 9, 4, 8, 5, 2, 7])
    engine = Engine(model, params, EngineConfig(num_slots=4, max_len=max_len))
    compiled = engine.warmup(reqs)
    assert compiled["decode"] == 1                    # one program for all slots

    with assert_flat_compiles(engine, compiled):      # no recompilation
        for r in reqs:
            engine.submit(r)
        results = engine.run()
    assert len(results) == len(reqs)
    by_rid = {r.rid: r for r in results}
    from repro.launch.serve import static_greedy_reference
    step_fns = _static_step_fns(model)        # hoisted: compile once
    for req in reqs:
        got = by_rid[req.rid].tokens
        assert len(got) == req.max_new_tokens
        assert got == static_greedy_reference(model, params, req, max_len,
                                              step_fns), req.rid
    assert engine.scheduler.idle
    assert 0.0 < engine.utilization() <= 1.0


def test_engine_warmup_fits_tight_budgets(tiny_lm, assert_flat_compiles):
    """Warmup clones must respect prompt_len + max_new <= max_len even when
    the trace's requests leave no decode headroom (gen=1 at a full-length
    prompt): the clone's budget is clipped and decode still gets compiled
    via the minimal fallback request."""
    cfg, model, params = tiny_lm
    engine = Engine(model, params, EngineConfig(num_slots=2, max_len=16))
    reqs = _requests(cfg, lens=[15, 4], gens=[1, 2])
    compiled = engine.warmup(reqs)                    # must not raise
    assert compiled["decode"] == 1
    with assert_flat_compiles(engine, compiled):
        for r in reqs:
            engine.submit(r)
        results = engine.run()
    assert sorted(len(r.tokens) for r in results) == [1, 2]


def test_engine_burst_admits_in_one_dispatch(tiny_lm, assert_flat_compiles):
    """Acceptance: a burst of B same-bucket requests admits in ONE batched
    prefill dispatch (not B), with greedy outputs still bit-identical to
    the static path and zero recompilation after warmup."""
    cfg, model, params = tiny_lm
    max_len = 64
    reqs = _requests(cfg, lens=[20, 22, 19, 24], gens=[4, 6, 3, 5])  # b32 ×4
    engine = Engine(model, params, EngineConfig(num_slots=4, max_len=max_len))
    compiled = engine.warmup(reqs)
    with assert_flat_compiles(engine, compiled):     # no recompilation
        for r in reqs:
            engine.submit(r)
        results = engine.run()
    assert engine.prefill_dispatches == 1            # one device call for 4
    assert engine.prefill_admitted == len(reqs)
    by_rid = {r.rid: r.tokens for r in results}
    step_fns = _static_step_fns(model)
    from repro.launch.serve import static_greedy_reference
    for req in reqs:
        assert by_rid[req.rid] == static_greedy_reference(
            model, params, req, max_len, step_fns), req.rid


def test_engine_compile_flat_across_burst_sizes(tiny_lm):
    """Warmup pre-compiles the (bucket × pow2-batch-bucket) prefill grid:
    bursts of every size then run with zero new compiles."""
    cfg, model, params = tiny_lm
    engine = Engine(model, params, EngineConfig(num_slots=4, max_len=32))
    compiled = engine.warmup(_requests(cfg, lens=[10], gens=[2]))
    rng = np.random.default_rng(7)
    rid = 0
    for burst in (1, 2, 3, 4):
        reqs = _requests(cfg, lens=[12] * burst, gens=[2] * burst, rng=rng)
        for r in reqs:
            r.rid = rid = rid + 1
            engine.submit(r)
        engine.run()
        assert engine.compile_counts() == compiled, burst
    # 1+2+3+4 requests in 4 dispatches (one per burst: run() drains the
    # queue before the first decode step of each burst)
    assert engine.prefill_dispatches == 4
    assert engine.prefill_admitted == 10


def test_engine_chunked_long_prompt_matches_static_path(
        tiny_lm, assert_flat_compiles):
    """Acceptance: prompts LONGER than the largest bucket stream through
    the bucket-width chunk program and still produce greedy output
    bit-identical to the static path — including slot reuse after a
    long-prompt request (2 slots, 4 requests) — with no compile after
    warmup."""
    cfg, model, params = tiny_lm
    max_len = 64
    reqs = _requests(cfg, lens=[20, 40, 9, 33], gens=[4, 3, 5, 2])
    engine = Engine(model, params,
                    EngineConfig(num_slots=2, max_len=max_len,
                                 prompt_buckets=(8, 16)))
    compiled = engine.warmup(reqs)
    assert compiled["chunk"] == 1                    # one program, ever
    with assert_flat_compiles(engine, compiled):     # no recompilation
        for r in reqs:
            engine.submit(r)
        results = engine.run()
    # ceil(20/16) + ceil(40/16) + ceil(33/16) chunks; rid 2 (9 <= 16) is
    # a normal bucketed admission
    assert engine.chunk_dispatches == 2 + 3 + 3
    assert engine.chunked_admitted == 3
    by_rid = {r.rid: r.tokens for r in results}
    step_fns = _static_step_fns(model)
    from repro.launch.serve import static_greedy_reference
    for req in reqs:
        assert by_rid[req.rid] == static_greedy_reference(
            model, params, req, max_len, step_fns), req.rid


def test_engine_chunked_int8_cache_completes(tiny_lm):
    """Long prompts through the INT8 QuantizedKV slot cache: the chunk
    program quantizes on write and attends the dequantized rows — the
    trace completes with the right budgets and no post-warmup compiles."""
    cfg, model, params = tiny_lm
    reqs = _requests(cfg, lens=[20, 40, 9, 33], gens=[4, 3, 5, 2])
    engine = Engine(model, params,
                    EngineConfig(num_slots=2, max_len=64,
                                 prompt_buckets=(8, 16), kv_quantized=True))
    compiled = engine.warmup(reqs)
    for r in reqs:
        engine.submit(r)
    results = engine.run()
    assert engine.compile_counts() == compiled
    assert sorted(len(r.tokens) for r in results) == sorted(
        r.max_new_tokens for r in reqs)


def test_engine_warmup_guards_non_idle(tiny_lm):
    """warmup() drains the scheduler, so calling it with live submissions
    would silently execute and discard them — it must raise instead, and
    a proper warmup-then-submit run returns only caller rids."""
    cfg, model, params = tiny_lm
    engine = Engine(model, params, EngineConfig(num_slots=2, max_len=32))
    reqs = _requests(cfg, lens=[6], gens=[2])
    engine.submit(reqs[0])
    with pytest.raises(RuntimeError):
        engine.warmup(reqs)
    results = engine.run()                           # the real request runs
    assert [r.rid for r in results] == [0]
    engine2 = Engine(model, params, EngineConfig(num_slots=2, max_len=32))
    engine2.warmup(reqs)                             # idle: fine
    engine2.submit(reqs[0])
    assert [r.rid for r in engine2.run()] == [0]     # warmup rids filtered


def test_engine_int8_cache_completes_with_half_bytes(tiny_lm):
    cfg, model, params = tiny_lm
    reqs = _requests(cfg, lens=[5, 13, 8, 21], gens=[6, 3, 9, 4])
    dense = Engine(model, params,
                   EngineConfig(num_slots=4, max_len=64,
                                kv_dtype=jnp.bfloat16))
    int8 = Engine(model, params,
                  EngineConfig(num_slots=4, max_len=64, kv_quantized=True))
    for r in reqs:
        int8.submit(r)
    res = int8.run()
    assert sorted(len(r.tokens) for r in res) == sorted(
        r.max_new_tokens for r in reqs)
    ratio = dense.kv_cache_bytes() / int8.kv_cache_bytes()
    assert 1.5 <= ratio <= 2.0


# ---------------------------------------------------------------------------
# per-slot sampling
# ---------------------------------------------------------------------------

def test_sample_tokens_semantics(rng):
    logits = jnp.asarray(rng.normal(size=(4, 64)) * 3, jnp.float32)
    temps = jnp.asarray([0.0, 1.0, 1.0, 0.7])
    topks = jnp.asarray([0, 0, 5, 1])
    seeds = jnp.asarray([0, 7, 7, 9], jnp.uint32)
    steps = jnp.asarray([0, 3, 3, 1], jnp.uint32)
    a = sample_tokens(logits, temps, topks, seeds, steps)
    b = sample_tokens(logits, temps, topks, seeds, steps)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))  # deterministic
    assert int(a[0]) == int(jnp.argmax(logits[0]))    # temp 0 → greedy
    assert int(a[3]) == int(jnp.argmax(logits[3]))    # top_k 1 → greedy
    # the key depends on (seed, step), never the slot: permutation-invariant
    perm = jnp.asarray([2, 0, 3, 1])
    c = sample_tokens(logits[perm], temps[perm], topks[perm], seeds[perm],
                      steps[perm])
    np.testing.assert_array_equal(np.asarray(c), np.asarray(a)[np.asarray(perm)])
    # top-k actually truncates: k=5 samples land in the top 5
    many = sample_tokens(jnp.tile(logits[2][None], (32, 1)),
                         jnp.full((32,), 1.5), jnp.full((32,), 5),
                         jnp.arange(32, dtype=jnp.uint32),
                         jnp.zeros((32,), jnp.uint32))
    top5 = set(np.asarray(jax.lax.top_k(logits[2], 5)[1]).tolist())
    assert set(np.asarray(many).tolist()) <= top5


def test_engine_sampling_deterministic_across_runs(tiny_lm):
    """Fixed per-request keys: two engines over the same sampled trace
    produce identical token streams (key = fold_in(seed, token index),
    independent of slot placement)."""
    cfg, model, params = tiny_lm

    def run(slots):
        rng = np.random.default_rng(3)
        reqs = _requests(cfg, lens=[5, 13, 8, 21, 9], gens=[6, 3, 9, 4, 5],
                         rng=rng, temperature=0.9, top_k=8)
        eng = Engine(model, params, EngineConfig(num_slots=slots, max_len=64))
        for r in reqs:
            eng.submit(r)
        return {r.rid: r.tokens for r in eng.run()}

    a = run(slots=4)
    b = run(slots=2)          # different slot layout, same keys
    assert a == b
    assert any(len(set(t)) > 1 or len(t) == 1 for t in a.values())
