"""Calibration statistics: streaming equivalence, damping, derived scales."""
import jax.numpy as jnp
import numpy as np

from repro.core import calibration as calib


def test_streaming_equals_batch(rng):
    x = rng.normal(size=(512, 32)).astype(np.float32)
    st = calib.init(32)
    for chunk in np.split(x, 8):
        st = calib.update(st, jnp.asarray(chunk))
    c_stream = np.asarray(calib.covariance(st))
    c_batch = x.T @ x / len(x)
    np.testing.assert_allclose(c_stream, c_batch, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(calib.act_mean_abs(st)),
                               np.abs(x).mean(0), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(calib.col_l2(st)),
                               np.linalg.norm(x, axis=0), rtol=1e-4)


def test_update_flattens_leading_dims(rng):
    x = rng.normal(size=(4, 16, 8)).astype(np.float32)
    st = calib.update(calib.init(8), jnp.asarray(x))
    assert float(st.n) == 64


def test_damping_regularizes():
    st = calib.init(4)
    st = calib.update(st, jnp.asarray(np.ones((8, 4), np.float32)))
    c0 = np.asarray(calib.covariance(st))           # rank-1: singular
    c1 = np.asarray(calib.covariance(st, damp=0.01))
    assert np.linalg.matrix_rank(c0) == 1
    assert np.linalg.matrix_rank(c1) == 4
    ev = np.linalg.eigvalsh(c1)
    assert ev[0] > 0
