"""Dry-run machinery smoke tests (subprocess with fake devices): production
mesh construction, one tiny-cell lower+compile, roofline parser."""
from conftest import run_multidevice
from repro.analysis.roofline import collective_bytes, Roofline


def test_collective_parser():
    hlo = """
  %all-gather.1 = f32[16,128]{1,0} all-gather(%x), replica_groups={}
  %ar = (f32[64]{0}, bf16[2,4]{1,0}) all-reduce(%a, %b), channel_id=1
  %dot.2 = f32[8,8]{1,0} dot(%p, %q)
  %a2a = f32[4,4]{1,0} all-to-all(%m), dimensions={0}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 128 * 4
    assert out["all-reduce"] == 64 * 4 + 2 * 4 * 2
    assert out["all-to-all"] == 4 * 4 * 4
    assert out["reduce-scatter"] == 0


def test_roofline_terms():
    r = Roofline(flops_per_device=197e12, bytes_per_device=819e9,
                 collective_per_device=0.0, chips=256,
                 model_flops=197e12 * 256)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert r.bottleneck in ("compute", "memory")
    assert abs(r.roofline_frac - 1.0) < 1e-6
    assert abs(r.useful_flops_frac - 1.0) < 1e-9


def test_production_mesh_and_tiny_cell_lowering():
    run_multidevice("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_production_mesh, make_mesh
from repro import compat
from repro.configs import get_tiny_config
from repro.models import build_model, batch_specs
from repro.sharding import rules_for_cell, tree_shardings
from repro.training.train_loop import TrainConfig, make_train_step
from repro.optim import OptimizerConfig

# production mesh builds (needs 512 placeholder devices)
mesh_mp = make_production_mesh(multi_pod=True)
assert mesh_mp.devices.size == 512 and mesh_mp.axis_names == ("pod", "data", "model")
mesh_sp = make_production_mesh()
assert mesh_sp.devices.size == 256

# AOT lower+compile a tiny arch on a small mesh, ShapeDtypeStructs only
mesh = make_mesh((2, 2), ("data", "model"))
cfg = get_tiny_config("granite-8b")
rules = rules_for_cell(mesh, cfg.family, "train", global_batch=8)
model = build_model(cfg, rules, param_dtype=jnp.bfloat16, remat=True)
p_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
p_sh = tree_shardings(rules, model.param_logical_axes(), p_sds)
step_fn, opt_init = make_train_step(model, TrainConfig())
o_sds = jax.eval_shape(opt_init, p_sds)
from repro.sharding import opt_logical_axes
o_sh = tree_shardings(rules, opt_logical_axes("adamw", model.param_logical_axes(), p_sds), o_sds)
state_sds = {"params": p_sds, "opt": o_sds, "step": jax.ShapeDtypeStruct((), jnp.int32)}
state_sh = {"params": p_sh, "opt": o_sh, "step": NamedSharding(mesh, P())}
b_sds = batch_specs(cfg, 8, 16)
b_sh = {k: NamedSharding(mesh, P(("data",))) for k in b_sds}
with compat.set_mesh(mesh):
    compiled = jax.jit(step_fn, in_shardings=(state_sh, b_sh),
                       donate_argnums=0).lower(state_sds, b_sds).compile()
mem = compiled.memory_analysis()
assert mem.temp_size_in_bytes > 0
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)): ca = ca[0]
assert ca.get("flops", 0) > 0
print("ok")
""", n_devices=512, timeout=900)
