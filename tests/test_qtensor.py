"""QTensor packing: code dtypes per bit width, round trips, col_scale."""
import jax.numpy as jnp
import numpy as np

from repro.core import projections as proj
from repro.quant import QTensor


def test_int4_pack_roundtrip(rng):
    w = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    qt = QTensor.from_dense(w, 4, 32)
    assert qt.packed.dtype == jnp.uint8
    assert qt.packed.shape == (8, 32)                  # two nibbles per byte
    np.testing.assert_allclose(np.asarray(qt.dequant()),
                               np.asarray(proj.quant_project(w, 4, 32)),
                               rtol=1e-6, atol=1e-6)


def test_int8_codes_do_not_wrap(rng):
    """bits=8 codes span [0, 255]; int8 storage wrapped them negative."""
    w = jnp.asarray(rng.normal(size=(4, 64)) * 10, jnp.float32)
    qt = QTensor.from_dense(w, 8, 32)
    assert qt.packed.dtype == jnp.uint8
    codes = np.asarray(qt.codes())
    assert codes.min() >= 0 and codes.max() > 127      # exercises the wrap
    np.testing.assert_allclose(np.asarray(qt.dequant()),
                               np.asarray(proj.quant_project(w, 8, 32)),
                               rtol=1e-5, atol=1e-5)
    # int8 quantization of a well-scaled weight is near-lossless
    err = float(jnp.abs(qt.dequant() - w).max())
    width = float((w.max() - w.min()) / 255)
    assert err <= width


def test_every_bit_width_roundtrips(rng):
    w = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    for bits in (2, 3, 4, 8):
        qt = QTensor.from_dense(w, bits, 32)
        np.testing.assert_allclose(
            np.asarray(qt.dequant()),
            np.asarray(proj.quant_project(w, bits, 32)),
            rtol=1e-5, atol=1e-5), bits


def test_col_scale_packs_scaled_space(rng):
    """AWQ-style: codes live on the W·diag(s) grid, dequant folds s back."""
    w = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    s = jnp.asarray(np.exp(rng.normal(0, 1, size=64)), jnp.float32)
    qt = QTensor.from_dense(w, 4, 32, col_scale=s)
    ref = proj.quant_project(w * s[None, :], 4, 32) / s[None, :]
    np.testing.assert_allclose(np.asarray(qt.dequant()), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert qt.nbytes() > QTensor.from_dense(w, 4, 32).nbytes()  # s is stored


def test_int4_odd_fanin_falls_back_to_bytes(rng):
    """bits=4 with odd d_in can't nibble-pack; codes stay uint8."""
    w = jnp.asarray(rng.normal(size=(4, 33)), jnp.float32)
    qt = QTensor.from_dense(w, 4, 11)
    assert qt.packed.dtype == jnp.uint8 and qt.packed.shape == (4, 33)
    np.testing.assert_allclose(np.asarray(qt.dequant()),
                               np.asarray(proj.quant_project(w, 4, 11)),
                               rtol=1e-6, atol=1e-6)


def test_gptq_codes_pack_exactly(rng):
    """GPTQ's mid-stream grids can't be re-derived from its output; the
    adapter packs the codes it actually used and dequant matches."""
    import jax

    from repro.core import calibration as calib
    from repro.core.baselines.gptq import quantize_weight
    from repro.core.compress import compress_layer
    from repro.core.specs import QuantSpec
    x = (rng.normal(size=(512, 64)) *
         np.exp(rng.normal(0, 0.7, size=64))).astype(np.float32)
    w = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    st = calib.update(calib.init(64), jnp.asarray(x))
    spec = QuantSpec(method="gptq", bits=4, group_size=32)
    res = compress_layer(w, st, spec)
    ref = quantize_weight(np.asarray(w), np.asarray(
        calib.covariance(st, damp=spec.damp), np.float64), 4, 32)
    # theta == dequant(artifact) by construction, == GPTQ output up to ulp
    np.testing.assert_array_equal(np.asarray(res.theta),
                                  np.asarray(res.qtensor.dequant()))
    np.testing.assert_allclose(np.asarray(res.theta), ref,
                               rtol=1e-5, atol=1e-5)


def test_nbytes_compression_factor(rng):
    w = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    qt = QTensor.from_dense(w, 4, 128)
    dense = w.size * 4
    assert dense / qt.nbytes() > 6     # ~8x minus scale/zero overhead
