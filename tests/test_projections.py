"""Unit + hypothesis property tests for the projection operators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import projections as proj


def test_topk_row_exact_k(rng):
    z = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    out = proj.topk_row(z, 10)
    assert ((np.asarray(out) != 0).sum(axis=1) == 10).all()
    # kept entries are the largest by |.|
    kept = np.sort(np.abs(np.asarray(out)), axis=1)[:, -10:]
    best = np.sort(np.abs(np.asarray(z)), axis=1)[:, -10:]
    np.testing.assert_allclose(kept, best)


def test_topk_row_edges(rng):
    z = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(proj.topk_row(z, 8)), np.asarray(z))
    assert (np.asarray(proj.topk_row(z, 0)) == 0).all()


def test_topk_matrix(rng):
    z = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    out = proj.topk_matrix(z, 5)
    assert (np.asarray(out) != 0).sum() == 5


def test_prune_n_m(rng):
    z = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
    out = np.asarray(proj.prune_n_m(z, 2, 4))
    groups = out.reshape(8, 8, 4)
    assert ((groups != 0).sum(axis=-1) == 2).all()


def test_topk_row_dynamic_matches_static(rng):
    z = jnp.asarray(rng.normal(size=(6, 40)), jnp.float32)
    for k in (4, 20, 39):
        dyn = proj.topk_row_dynamic(z, jnp.float32(k / 40))
        stat = proj.topk_row(z, k)
        np.testing.assert_allclose(np.asarray(dyn), np.asarray(stat))


def test_ramp_ratio():
    r = [float(proj.ramp_ratio(jnp.int32(t), 0.8, 25)) for t in range(30)]
    assert abs(r[0] - 0.8 / 25) < 1e-6
    assert abs(r[24] - 0.8) < 1e-6 and abs(r[29] - 0.8) < 1e-6
    assert all(b >= a - 1e-9 for a, b in zip(r, r[1:]))


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_quant_grid_membership(rng, bits):
    z = jnp.asarray(rng.normal(size=(8, 64)) * 3, jnp.float32)
    qp = proj.quant_params(z, bits, 32)
    assert qp.q.min() >= 0 and qp.q.max() <= 2 ** bits - 1
    deq = proj.quant_project(z, bits, 32)
    # projection error bounded by half a bin per entry
    g = np.asarray(z).reshape(8, 2, 32)
    width = (g.max(-1) - g.min(-1)) / (2 ** bits - 1)
    err = np.abs(np.asarray(deq).reshape(8, 2, 32) - g)
    assert (err <= width[..., None] * 0.5 + 1e-5).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 63), st.integers(0, 2 ** 31 - 1))
def test_property_topk_idempotent(k, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    once = proj.topk_row(z, k)
    twice = proj.topk_row(once, k)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice))


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([2, 3, 4, 8]), st.integers(0, 2 ** 31 - 1))
def test_property_quant_idempotent(bits, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    once = proj.quant_project(z, bits, 32)
    twice = proj.quant_project(once, bits, 32)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 31), st.integers(0, 2 ** 31 - 1))
def test_property_projection_nonexpansive(k, seed):
    """Proj is the closest point of the constraint set: the projection can
    never be farther from z than any other set member (here: 0)."""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    p = proj.topk_row(z, k)
    d_proj = float(jnp.linalg.norm(z - p))
    assert d_proj <= float(jnp.linalg.norm(z)) + 1e-5
