"""Request lifecycle and failure semantics under deterministic fault
injection: typed submit rejection and load shedding, cancellation and
deadlines, the non-finite-logit decode guard, failure-atomic steps under
injected allocation/spill faults, the engine invariant checker, stall and
deadlock detection, and the chaos acceptance run (seeded FaultPlan on an
overloaded paged engine: invariants hold after every step, every request
terminal, zero leaked pages, survivors bit-identical to fault-free)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import build_model
from repro.serving import (DuplicateRequestError, Engine, EngineConfig,
                           EngineInvariantError, EngineStalledError,
                           FaultPlan, GenerationRequest, InjectedFault,
                           QueueFullError, RequestStatus, SamplingParams,
                           cache_is_finite)

# ---------------------------------------------------------------------------
# shared tiny model (compiles are the dominant test cost)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_tiny_config("llama32-1b")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, lens, gens, base=0, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [GenerationRequest(
                rid=base + i,
                prompt=rng.integers(1, cfg.vocab_size, size=l).astype(np.int32),
                max_new_tokens=g,
                sampling=SamplingParams(seed=100 + i), **kw)
            for i, (l, g) in enumerate(zip(lens, gens))]


PAGED = dict(num_slots=3, max_len=48, kv_layout="paged", page_size=8,
             num_pages=9, prefix_caching=False)
TRACE = ([7, 9, 11, 7, 9, 13, 7, 9], [10, 12, 9, 11, 8, 10, 12, 9])


def _paged_engine(tiny_lm, faults=None, **over):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(**{**PAGED, **over}))
    eng.warmup(_requests(cfg, TRACE[0][:2], TRACE[1][:2]))
    if faults is not None:
        eng.set_faults(faults)
    return eng


def _drive(eng, reqs, check_every_step=True, max_steps=2000):
    """Submit, then step with per-step invariant checks → {rid: result}."""
    for r in reqs:
        eng.submit(r)
    for _ in range(max_steps):
        if eng.scheduler.idle:
            break
        eng.step()
        if check_every_step:
            eng.check_invariants()
    assert eng.scheduler.idle, "drive exhausted max_steps"
    out, eng._done = eng._done, []
    return {r.rid: r for r in out}


@pytest.fixture(scope="module")
def paged_baseline(tiny_lm):
    """Fault-free paged run of the shared overload trace — the parity
    oracle every chaos test compares survivors against."""
    cfg, _, _ = tiny_lm
    eng = _paged_engine(tiny_lm)
    out = _drive(eng, _requests(cfg, *TRACE, seed=1))
    assert all(r.status == "ok" for r in out.values())
    assert eng.alloc.pages_in_use == 0
    return out


def _chaos_reqs(cfg):
    return _requests(cfg, *TRACE, seed=1)


# ---------------------------------------------------------------------------
# FaultPlan determinism (no model)
# ---------------------------------------------------------------------------

def test_fault_plan_is_deterministic_and_scripted():
    def draw(plan):
        seq = []
        for step in range(50):
            plan.tick()
            seq.append((plan.fail_alloc(), plan.poison_logits(rid=step % 3)))
        return seq
    a = draw(FaultPlan(seed=7, alloc_fail=0.3, nan_logits=0.1))
    b = draw(FaultPlan(seed=7, alloc_fail=0.3, nan_logits=0.1))
    assert a == b                       # same seed → identical replay
    c = draw(FaultPlan(seed=8, alloc_fail=0.3, nan_logits=0.1))
    assert a != c

    plan = FaultPlan(script=((3, "alloc_fail"), (5, "nan_logits", 2)))
    hits = []
    for step in range(1, 8):
        plan.tick()
        if plan.fail_alloc():
            hits.append(("alloc", step))
        if plan.poison_logits(rid=2):
            hits.append(("nan2", step))
        assert not plan.poison_logits(rid=1)   # rid filter
    assert hits == [("alloc", 3), ("nan2", 5)]

    capped = FaultPlan(seed=0, alloc_fail=1.0, max_faults=2)
    assert sum(capped.fail_alloc() for _ in range(10)) == 2

    vclock = FaultPlan(slow_step_s=0.5)
    t0 = vclock.now()
    vclock.tick()
    vclock.tick()
    assert vclock.now() - t0 == 1.0     # virtual clock, no wall time

    with pytest.raises(ValueError):
        FaultPlan(script=((1, "bogus_kind"),))

    with pytest.raises(InjectedFault):
        FaultPlan(spill_fail=1.0).check_spill()


# ---------------------------------------------------------------------------
# lifecycle: typed rejection, shedding, cancel, deadlines, stall
# ---------------------------------------------------------------------------

def test_duplicate_rid_and_queue_full_raise_typed(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=1, max_len=32,
                                             max_queue=2))
    eng.warmup(_requests(cfg, [6], [2]))
    rs = _requests(cfg, [6, 6, 6], [2, 2, 2])
    eng.submit(rs[0])
    with pytest.raises(DuplicateRequestError):
        eng.submit(rs[0])               # silently overwriting is a bug
    eng.submit(rs[1])                   # queue now at max_queue=2
    with pytest.raises(QueueFullError):
        eng.submit(rs[2])
    # try_submit converts the shed into a terminal rejected result
    assert eng.try_submit(rs[2]) is False
    with pytest.raises(DuplicateRequestError):
        eng.try_submit(rs[0])           # duplicates still raise
    out = {r.rid: r for r in eng.run()}
    assert out[2].status == "rejected" and out[2].finish_reason == "rejected"
    assert "max_queue" in out[2].error
    assert all(out[i].status == "ok" for i in range(2))
    assert eng.queue_stats()["rejected"] == 1
    assert eng.check_invariants()


def test_finish_reason_length_vs_eos(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=1, max_len=32))
    eng.warmup(_requests(cfg, [6], [4]))
    probe = _requests(cfg, [6], [4])[0]
    first = {r.rid: r for r in _run_one(eng, probe)}[0].tokens[0]
    again = GenerationRequest(rid=1, prompt=probe.prompt, max_new_tokens=4,
                              sampling=probe.sampling, eos_id=first)
    res = _run_one(eng, again)[0]
    assert res.status == "ok" and res.finish_reason == "eos"
    assert res.tokens == [first]        # stopped at the eos sample
    res2 = _run_one(eng, GenerationRequest(
        rid=2, prompt=probe.prompt, max_new_tokens=4,
        sampling=probe.sampling))[0]
    assert res2.status == "ok" and res2.finish_reason == "length"
    assert len(res2.tokens) == 4


def _run_one(eng, req):
    eng.submit(req)
    return eng.run()


def test_cancel_running_queued_and_unknown(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=1, max_len=32))
    eng.warmup(_requests(cfg, [6], [4]))
    running, queued = _requests(cfg, [6, 6], [8, 8], base=10)
    eng.submit(running)
    eng.submit(queued)
    eng.step()
    eng.step()                          # running has prefill + decode tokens
    assert eng.cancel(10) and eng.cancel(11)
    assert not eng.cancel(10)           # already terminal
    assert not eng.cancel(424242)       # never submitted
    out = {r.rid: r for r in eng.run()}
    assert out[10].status == "cancelled" and len(out[10].tokens) >= 2
    assert out[11].status == "cancelled" and out[11].tokens == []
    assert eng.check_invariants()


def test_deadline_expires_queued_and_running(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=1, max_len=32))
    eng.warmup(_requests(cfg, [6], [4]))
    eng.set_faults(FaultPlan(slow_step_s=1.0))   # deterministic clock
    running = _requests(cfg, [6], [20], base=30, deadline_s=5.0)[0]
    queued = _requests(cfg, [6], [4], base=40, deadline_s=3.0)[0]
    eng.submit(running)
    eng.submit(queued)
    out = {r.rid: r for r in eng.run()}
    # the running request kept its pre-deadline tokens; the queued one
    # expired behind it without ever touching a slot
    assert out[30].status == "deadline" and 0 < len(out[30].tokens) < 20
    assert out[40].status == "deadline" and out[40].tokens == []
    assert eng.check_invariants()


def test_stalled_run_raises_typed_with_stuck_state(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=1, max_len=32))
    eng.warmup(_requests(cfg, [6], [4]))
    eng.submit(_requests(cfg, [6], [20], base=50)[0])
    with pytest.raises(EngineStalledError) as ei:
        eng.run(max_steps=3)
    stuck = ei.value.stuck
    assert [s["rid"] for s in stuck] == [50]
    assert stuck[0]["where"] == "slot 0" and stuck[0]["generated"] > 0
    assert "rid=50" in str(ei.value)
    # the engine is still serviceable: finish the request normally
    out = {r.rid: r for r in eng.run()}
    assert out[50].status == "ok"


def test_deadlock_detected_early_when_pool_never_allocates(tiny_lm):
    cfg, _, _ = tiny_lm
    eng = _paged_engine(tiny_lm, faults=FaultPlan(seed=1, alloc_fail=1.0))
    eng.submit(_requests(cfg, [7], [4])[0])
    with pytest.raises(EngineStalledError) as ei:
        eng.run()                       # patience, not max_steps, trips
    assert "no progress" in str(ei.value)
    assert ei.value.stuck[0]["where"] == "queued"
    assert eng.check_invariants()       # nothing half-admitted


# ---------------------------------------------------------------------------
# decode guard: non-finite logits fail the slot, not the batch
# ---------------------------------------------------------------------------

def test_real_nan_in_cache_fails_only_the_poisoned_slot(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=2, max_len=32))
    eng.warmup(_requests(cfg, [6], [4]))
    a, b = _requests(cfg, [6, 6], [8, 8])
    eng.submit(a)
    eng.submit(b)
    eng.step()                          # both prefilled + one decode
    clean = {r.rid: list(r.tokens) for r in eng._results.values()}
    slot = next(s for s in eng.scheduler.active_slots()
                if eng.scheduler.slots[s].request.rid == 0)
    # genuine corruption, not a flag: NaN K rows make slot 0's attention
    # (and therefore its logits) non-finite on the next decode step
    eng.kv["k"] = eng.kv["k"].at[:, slot].set(jnp.nan)
    assert not cache_is_finite(eng.kv)  # the diagnostic localizes it
    out = {r.rid: r for r in eng.run()}
    assert out[0].status == "error" and "non-finite" in out[0].error
    assert out[0].tokens == clean[0]    # poisoned-step token not emitted
    assert out[1].status == "ok" and len(out[1].tokens) == 8
    assert eng.check_invariants()


def test_scripted_nan_poison_hits_exact_victim_paged(tiny_lm, paged_baseline):
    cfg, _, _ = tiny_lm
    eng = _paged_engine(tiny_lm,
                        faults=FaultPlan(seed=5, script=((4, "nan_logits",
                                                          2),)))
    out = _drive(eng, _chaos_reqs(cfg))
    bad = [rid for rid, r in out.items() if r.status != "ok"]
    assert bad == [2] and out[2].status == "error"
    for rid, r in out.items():
        if r.status == "ok":
            assert r.tokens == paged_baseline[rid].tokens
    assert eng.alloc.pages_in_use == 0


# ---------------------------------------------------------------------------
# chaos: injected faults under page pressure
# ---------------------------------------------------------------------------

def test_alloc_fault_chaos_keeps_bit_parity(tiny_lm, paged_baseline):
    # allocation faults only reroute scheduling (preempt/resume round-trips
    # are bit-exact), so EVERY request must still match the fault-free run
    cfg, _, _ = tiny_lm
    plan = FaultPlan(seed=7, alloc_fail=0.3)
    eng = _paged_engine(tiny_lm, faults=plan)
    out = _drive(eng, _chaos_reqs(cfg))
    assert all(r.status == "ok" for r in out.values())
    for rid, r in out.items():
        assert r.tokens == paged_baseline[rid].tokens, rid
    assert plan.fired["alloc_fail"] > 0      # the plan actually fired
    assert eng.preemptions > 0               # and forced real preemptions
    assert eng.alloc.pages_in_use == 0


def test_spill_fault_fails_victim_and_survivors_keep_parity(
        tiny_lm, paged_baseline):
    cfg, _, _ = tiny_lm
    plan = FaultPlan(seed=3, alloc_fail=0.3, spill_fail=0.5)
    eng = _paged_engine(tiny_lm, faults=plan)
    out = _drive(eng, _chaos_reqs(cfg))
    errs = {rid for rid, r in out.items() if r.status == "error"}
    assert errs and plan.fired["spill_fail"] > 0
    for rid, r in out.items():
        assert r.status in ("ok", "error")
        if r.status == "ok":
            assert r.tokens == paged_baseline[rid].tokens, rid
        else:
            assert "spill" in r.error or "restore" in r.error
    assert eng.alloc.pages_in_use == 0       # victims leaked nothing


def test_chaos_acceptance_overloaded_paged_engine(tiny_lm, paged_baseline):
    """The acceptance run from the issue: overload + combined fault plan +
    a mid-flight cancel; invariants checked after EVERY step; every request
    reaches a terminal status; zero leaked pages/slots; every ``ok``
    survivor bit-identical to the fault-free run."""
    cfg, _, _ = tiny_lm
    plan = FaultPlan(seed=11, alloc_fail=0.15, spill_fail=0.3,
                     nan_logits=0.01)
    eng = _paged_engine(tiny_lm, faults=plan, max_queue=6)
    reqs = _chaos_reqs(cfg)
    shed = [r.rid for r in reqs if not eng.try_submit(r)]
    assert shed                              # overload actually shed
    cancelled = False
    for _ in range(2000):
        if eng.scheduler.idle:
            break
        eng.step()
        eng.check_invariants()
        if not cancelled and eng.decode_steps >= 3:
            live = eng.scheduler.active_slots()
            if live:
                rid = eng.scheduler.slots[live[-1]].request.rid
                assert eng.cancel(rid)
                cancelled = rid
                eng.check_invariants()
    assert eng.scheduler.idle
    out, eng._done = {r.rid: r for r in eng._done}, []
    assert set(out) == {r.rid for r in reqs}     # every request terminal
    terminal = {s.value for s in RequestStatus} - {"length", "eos"}
    for rid, r in out.items():
        assert r.status in terminal, (rid, r.status)
        assert r.finish_reason != ""
        if r.status == "ok":
            assert r.tokens == paged_baseline[rid].tokens, rid
    assert out[cancelled].status == "cancelled"
    assert {rid: out[rid].status for rid in shed} == \
        {rid: "rejected" for rid in shed}
    assert eng.alloc.pages_in_use == 0
    assert len(eng.scheduler.free) == eng.cfg.num_slots
    assert eng.queue_stats()["rejected"] == len(shed)


# ---------------------------------------------------------------------------
# the invariant checker itself
# ---------------------------------------------------------------------------

def test_invariant_checker_catches_seeded_corruption(tiny_lm):
    cfg, _, _ = tiny_lm
    eng = _paged_engine(tiny_lm)
    for r in _requests(cfg, [7, 9], [8, 8]):
        eng.submit(r)
    eng.step()
    assert eng.check_invariants()
    slot = eng.scheduler.active_slots()[0]
    page = eng._slot_pages[slot][0]
    eng.alloc.decref([page])                 # refcount out from under a slot
    with pytest.raises(EngineInvariantError):
        eng.check_invariants()
    # undo the corruption (white-box) and confirm the checker is satisfied
    eng.alloc._free.remove(page)
    eng.alloc._refs[page] = 1
    assert eng.check_invariants()
    eng.run()


def test_invariant_checker_catches_table_drift(tiny_lm):
    cfg, _, _ = tiny_lm
    eng = _paged_engine(tiny_lm)
    for r in _requests(cfg, [7], [8]):
        eng.submit(r)
    eng.step()
    slot = eng.scheduler.active_slots()[0]
    keep = int(eng._table[slot, 0])
    eng._table[slot, 0] = eng.alloc.num_pages    # block table drifts
    with pytest.raises(EngineInvariantError):
        eng.check_invariants()
    eng._table[slot, 0] = keep
    assert eng.check_invariants()
    eng.run()
