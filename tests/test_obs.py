"""Telemetry subsystem: metrics registry semantics (histogram buckets,
label handling, ring-buffer gauge traces, exposition round-trip), request
trace lifecycle invariants for every terminal status, exact span durations
under the FaultPlan virtual clock, and the decode-loop overhead guard
(metrics on vs off: bit-identical tokens, flat compile counts, identical
dispatch counts)."""
import jax
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import build_model
from repro.obs import (LATENCY_BUCKETS, MetricsRegistry, RingBuffer, Trace,
                       TraceError, hist_quantile, parse_exposition,
                       snapshot_series)
from repro.serving import (Engine, EngineConfig, FaultPlan,
                           GenerationRequest, RequestStatus, SamplingParams)

# ---------------------------------------------------------------------------
# registry unit tests (no model, no jax dispatch)
# ---------------------------------------------------------------------------

def test_counter_labels_and_kind_mismatch():
    reg = MetricsRegistry()
    fam = reg.counter("widgets_total", "w", labelnames=("kind",))
    fam.labels(kind="a").inc()
    fam.labels(kind="a").inc(2)
    fam.labels(kind="b").inc(5)
    snap = reg.snapshot()
    assert snapshot_series(snap, "counters", "widgets_total",
                           {"kind": "a"})["value"] == 3
    assert snapshot_series(snap, "counters", "widgets_total",
                           {"kind": "b"})["value"] == 5
    # get-or-create returns the same family; a kind clash raises
    assert reg.counter("widgets_total", "w", labelnames=("kind",)) is fam
    with pytest.raises((ValueError, TypeError)):
        reg.gauge("widgets_total", "w", labelnames=("kind",))
    # the labelname set is validated exactly
    with pytest.raises((ValueError, KeyError, TypeError)):
        fam.labels(wrong="a")
    with pytest.raises((ValueError, KeyError, TypeError)):
        fam.labels()


def test_gauge_peak_mean_and_ring_trace():
    reg = MetricsRegistry()
    g = reg.gauge("depth", "d", labelnames=(), trace_capacity=3).labels()
    for v in (1, 5, 2, 4):
        g.set(v)
    assert g.peak == 5
    assert g.mean == pytest.approx(3.0)
    assert g.samples == 4
    assert [int(v) for v in g.trace_values()] == [5, 2, 4]   # ring keeps tail
    assert g.trace_dropped == 1
    # set_value refreshes value/peak without recording a sample
    g.set_value(9)
    assert g.peak == 9 and g.samples == 4


def test_ring_buffer_keeps_most_recent():
    rb = RingBuffer(2)
    for v in range(5):
        rb.append(v)
    assert list(rb.values()) == [3, 4]
    assert rb.dropped == 3


def test_histogram_bucket_semantics_and_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "l", buckets=(0.1, 1.0, 10.0),
                      labelnames=()).labels()
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):                    # 50 overflows
        h.observe(v)
    snap = reg.snapshot()
    e = snapshot_series(snap, "histograms", "lat")
    assert e["le"] == [0.1, 1.0, 10.0]
    assert e["counts"] == [1, 2, 1, 1]                       # +overflow slot
    assert e["count"] == 5
    assert e["sum"] == pytest.approx(56.05)
    assert e["min"] == 0.05 and e["max"] == 50.0
    # quantiles interpolate within buckets, clamp to observed extremes
    assert hist_quantile(e, 0.0) >= e["min"]
    assert hist_quantile(e, 1.0) == e["max"]                 # overflow -> max
    assert e["min"] <= hist_quantile(e, 0.5) <= 1.0


def test_latency_buckets_are_log_spaced_and_cover_serving_range():
    assert LATENCY_BUCKETS[0] == pytest.approx(1e-4)
    assert LATENCY_BUCKETS[-1] >= 1000
    ratios = [b / a for a, b in zip(LATENCY_BUCKETS, LATENCY_BUCKETS[1:])]
    assert all(r == pytest.approx(10 ** (1 / 3), rel=1e-4) for r in ratios)


def test_disabled_registry_skips_histograms_but_counts():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("c_total", "c", labelnames=()).labels()
    h = reg.histogram("h", "h", buckets=(1.0,), labelnames=()).labels()
    c.inc(3)
    h.observe(0.5)
    snap = reg.snapshot()
    assert snapshot_series(snap, "counters", "c_total")["value"] == 3
    assert snapshot_series(snap, "histograms", "h")["count"] == 0


def test_exposition_round_trip():
    reg = MetricsRegistry()
    reg.counter("req_total", "r", labelnames=("status",)) \
       .labels(status="ok").inc(7)
    g = reg.gauge("depth", "d", labelnames=()).labels()
    g.set(4)
    h = reg.histogram("lat", "l", buckets=(1.0, 10.0), labelnames=()).labels()
    h.observe(0.5)
    h.observe(5.0)
    text = reg.to_prometheus()
    parsed = parse_exposition(text)
    assert parsed["req_total"][(("status", "ok"),)] == 7
    assert parsed["depth"][()] == 4
    # histogram buckets are cumulative with a +Inf terminal
    b = parsed["lat_bucket"]
    assert b[(("le", "1"),)] == 1                   # compact float format
    assert b[(("le", "10"),)] == 2
    assert b[(("le", "+Inf"),)] == 2
    assert parsed["lat_count"][()] == 2
    assert parsed["lat_sum"][()] == pytest.approx(5.5)


# ---------------------------------------------------------------------------
# trace lifecycle invariants (no model)
# ---------------------------------------------------------------------------

def _trace(events, rid=0):
    tr = Trace(rid, events[0][1])
    assert events[0][0] == "submit"
    for name, t in events[1:]:
        tr.stamp(name, t)
    return tr


def test_trace_valid_shapes_for_every_terminal_status():
    shapes = {
        "ok": [("submit", 0), ("admitted", 1), ("first_token", 1),
               ("end:ok", 3)],
        "length": [("submit", 0), ("admitted", 1), ("first_token", 2),
                   ("end:length", 4)],
        "eos": [("submit", 0), ("admitted", 1), ("first_token", 1),
                ("end:eos", 2)],
        "cancelled": [("submit", 0), ("end:cancelled", 1)],
        "deadline": [("submit", 0), ("admitted", 1), ("end:deadline", 3)],
        "rejected": [("submit", 0), ("end:rejected", 0)],
        "error": [("submit", 0), ("admitted", 1), ("first_token", 1),
                  ("preempt", 2), ("resume", 3), ("end:error", 4)],
    }
    assert set(shapes) == {s.value for s in RequestStatus}
    for status, events in shapes.items():
        tr = _trace(events)
        assert tr.validate() and tr.done and tr.status == status
        spans = tr.spans()
        # spans are ordered and sit inside the trace window
        starts = [s.start for s in spans]
        assert starts == sorted(starts)
        for s in spans:
            assert events[0][1] <= s.start <= s.end <= events[-1][1]
    pre = _trace(shapes["error"]).spans()
    assert [s.name for s in pre] == ["queued", "prefill", "decode",
                                    "preempted"]
    assert pre[-1].duration == 1


def test_trace_preempt_without_resume_closes_at_end():
    tr = _trace([("submit", 0), ("admitted", 1), ("first_token", 1),
                 ("preempt", 2), ("end:cancelled", 5)])
    assert tr.validate()
    pspan = [s for s in tr.spans() if s.name == "preempted"]
    assert len(pspan) == 1 and pspan[0].duration == 3


def test_trace_invariant_violations_raise():
    bad = [
        [("admitted", 0), ("end:ok", 1)],                    # no submit
        [("submit", 0), ("first_token", 1), ("end:ok", 2)],  # no admitted
        [("submit", 0), ("admitted", 2), ("first_token", 1),
         ("end:ok", 3)],                                     # not monotone
        [("submit", 0), ("admitted", 1), ("end:ok", 2)],     # ok w/o token
        [("submit", 0), ("admitted", 1), ("end:rejected", 2)],
        [("submit", 0), ("preempt", 1), ("end:cancelled", 2)],
        [("submit", 0), ("admitted", 1), ("first_token", 1),
         ("preempt", 2), ("preempt", 3), ("end:error", 4)],  # nested
        [("submit", 0), ("admitted", 1), ("first_token", 1),
         ("resume", 2), ("end:error", 3)],                   # dangling resume
        [("submit", 0), ("end:ok", 1), ("end:ok", 2)],       # two terminals
        [("submit", 0), ("submit", 1), ("end:cancelled", 2)],
        [("submit", 0), ("end:wat", 1)],                     # unknown status
        [("submit", 0)],                                     # never terminal
    ]
    for events in bad:
        tr = Trace(0, events[0][1])
        tr.events[0] = (events[0][0], float(events[0][1]))
        for name, t in events[1:]:
            tr.stamp(name, t)
        with pytest.raises(TraceError):
            tr.validate()


# ---------------------------------------------------------------------------
# engine integration (shared tiny model; compiles dominate the cost)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_tiny_config("llama32-1b")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, lens, gens, base=0, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [GenerationRequest(
                rid=base + i,
                prompt=rng.integers(1, cfg.vocab_size, size=l).astype(np.int32),
                max_new_tokens=g,
                sampling=SamplingParams(seed=100 + i), **kw)
            for i, (l, g) in enumerate(zip(lens, gens))]


def test_virtual_clock_span_durations_are_exact(tiny_lm):
    """Satellite check for the deterministic-trace contract: under
    ``FaultPlan(slow_step_s=1.0)`` every stamp lands on the virtual step
    clock, so span durations are EXACT integers — queued ends at the
    admitting step, prefill is zero-width (admit and first token happen in
    one dispatch), decode spans len(tokens)-2 steps (the prefill step
    emits the first token; the final step observes the last)."""
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=1, max_len=32))
    eng.warmup(_requests(cfg, [6], [2]))
    eng.set_faults(FaultPlan(slow_step_s=1.0))
    t0 = eng._now()
    for r in _requests(cfg, [6, 6], [4, 3]):
        eng.submit(r)
    out = {r.rid: r for r in eng.run()}
    ev0 = [(n, t - t0) for n, t in out[0].trace.events]
    ev1 = [(n, t - t0) for n, t in out[1].trace.events]
    assert ev0 == [("submit", 0.0), ("admitted", 1.0),
                   ("first_token", 1.0), ("end:ok", 3.0)]
    # the slot frees at step 3; the queued request admits the next step
    assert ev1 == [("submit", 0.0), ("admitted", 4.0),
                   ("first_token", 4.0), ("end:ok", 5.0)]
    for res, gen in ((out[0], 4), (out[1], 3)):
        assert res.trace.validate()
        spans = {s.name: s.duration for s in res.trace.spans()}
        assert spans["prefill"] == 0.0
        assert spans["decode"] == float(gen - 2)
        # result-level timings are the trace's, to the same clock
        assert res.latency == res.trace.events[-1][1] - res.trace.events[0][1]
        assert res.ttft == spans["queued"] + spans["prefill"]
        assert res.queue_time == spans["queued"]
    # histograms saw the same exact values
    snap = eng.metrics_snapshot()
    ttft = snapshot_series(snap, "histograms", "request_ttft_seconds")
    assert ttft["count"] == 2
    assert ttft["sum"] == pytest.approx(1.0 + 4.0)


def test_engine_traces_cover_every_terminal_status(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=1, max_len=32,
                                             max_queue=2))
    eng.warmup(_requests(cfg, [6], [4]))
    ok, queued, shed = _requests(cfg, [6, 6, 6], [4, 8, 4])
    eng.submit(ok)
    eng.submit(queued)
    assert eng.try_submit(shed) is False            # queue full -> rejected
    eng.step()
    assert eng.cancel(1)                            # queued request
    out = {r.rid: r for r in eng.run()}
    assert out[0].status == "ok"
    assert out[1].status == "cancelled"
    assert out[2].status == "rejected"
    for res in out.values():
        assert res.trace is not None and res.trace.validate()
        assert res.trace.status == res.status
    assert res_names(out[2]) == ["submit", "end:rejected"]
    assert "admitted" not in res_names(out[1])
    # per-status registry counts match the results
    snap = eng.metrics_snapshot()
    for status in ("ok", "cancelled", "rejected"):
        s = snapshot_series(snap, "counters", "engine_requests_total",
                            {"status": status})
        assert s["value"] == 1, status


def res_names(res):
    return [n for n, _ in res.trace.events]


def test_engine_trace_deadline_and_error(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=1, max_len=32))
    eng.warmup(_requests(cfg, [6], [4]))
    eng.set_faults(FaultPlan(slow_step_s=1.0))      # deterministic clock
    late = _requests(cfg, [6], [20], base=60, deadline_s=5.0)[0]
    eng.submit(late)
    out = {r.rid: r for r in eng.run()}
    # fresh scripted plan: poison rid 61's logits on its third step
    eng.set_faults(FaultPlan(slow_step_s=1.0,
                             script=((3, "nan_logits", 61),)))
    poisoned = _requests(cfg, [6], [20], base=61, seed=2)[0]
    eng.submit(poisoned)
    out.update({r.rid: r for r in eng.run()})
    assert out[60].status == "deadline"
    assert out[61].status == "error"
    for res in out.values():
        assert res.trace.validate()
        assert res.trace.status == res.status
    # the deadline fired mid-decode: the trace got a first token first
    assert "first_token" in res_names(out[60])


def test_engine_trace_preempt_resume_spans(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(
        num_slots=3, max_len=48, kv_layout="paged", page_size=8,
        num_pages=9, prefix_caching=False))
    # overload-shaped trace: 3 slots x 5 pages each vs a 9-page pool, so
    # decode extension must preempt (mirrors the bench overload scenario)
    reqs = _requests(cfg, [28, 29, 30, 31, 28, 29],
                     [12, 12, 12, 12, 12, 12], seed=1)
    eng.warmup(reqs[:2])
    for r in reqs:
        eng.submit(r)
    out = {r.rid: r for r in eng.run()}
    assert eng.preemptions > 0 and eng.resumes > 0
    preempted = [r for r in out.values()
                 if "preempt" in res_names(r)]
    assert preempted, "overloaded pool must preempt at least one request"
    for res in out.values():
        assert res.status == "ok"
        assert res.trace.validate()
    resumed = [r for r in preempted if "resume" in res_names(r)]
    assert resumed
    spans = resumed[0].trace.spans()
    pre = [s for s in spans if s.name == "preempted"]
    assert pre and all(s.duration > 0 for s in pre)
    # preempted spans nest inside the decode span
    decode = next(s for s in spans if s.name == "decode")
    for s in pre:
        assert decode.start <= s.start and s.end <= decode.end


def test_queue_trace_ring_reports_dropped(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=1, max_len=32,
                                             queue_trace_samples=4))
    eng.warmup(_requests(cfg, [6], [4]))
    for r in _requests(cfg, [6, 6, 6], [6, 6, 6]):
        eng.submit(r)
    eng.run()
    qs = eng.queue_stats()
    assert len(qs["trace"]) == 4                    # ring capacity
    assert qs["dropped"] > 0                        # early samples displaced
    assert qs["samples"] == len(qs["trace"]) + qs["dropped"]
    assert qs["peak"] >= 2


def test_metrics_off_engine_behavior_identical(tiny_lm, assert_flat_compiles):
    """The overhead contract: a disabled registry must not change engine
    behavior — greedy tokens bit-identical, post-warmup compile counts
    flat and equal, and every dispatch counter identical (same number of
    prefill/chunk/decode programs ran). Also covers 'metrics on adds no
    dispatches': both runs execute the same device work."""
    cfg, model, params = tiny_lm
    reqs = _requests(cfg, [6, 9, 7, 11], [6, 4, 8, 5], seed=3)

    def drive(registry):
        eng = Engine(model, params,
                     EngineConfig(num_slots=2, max_len=32),
                     registry=registry)
        warm = eng.warmup(reqs)
        with assert_flat_compiles(eng, warm):        # post-warmup recompile
            for r in reqs:
                eng.submit(r)
            out = {r.rid: r.tokens for r in eng.run()}
        compiled = eng.compile_counts()
        dispatch = {
            "prefill_dispatches": eng.prefill_dispatches,
            "chunk_dispatches": eng.chunk_dispatches,
            "decode_steps": eng.decode_steps,
            "active_slot_steps": eng.active_slot_steps,
        }
        return out, compiled, dispatch, eng

    out_on, compiled_on, dispatch_on, eng_on = drive(None)
    out_off, compiled_off, dispatch_off, eng_off = drive(
        MetricsRegistry(enabled=False))
    assert out_on == out_off                        # bit-identical greedy
    assert compiled_on == compiled_off
    assert dispatch_on == dispatch_off
    # disabled registry: no traces, no histogram samples — but the
    # counters (the engine's own bookkeeping) still count
    res_off = eng_off._done if eng_off._done else []
    snap_off = eng_off.metrics_snapshot()
    ttft = snapshot_series(snap_off, "histograms", "request_ttft_seconds")
    assert ttft is None or ttft["count"] == 0
    ok = snapshot_series(snap_off, "counters", "engine_requests_total",
                         {"status": "ok"})
    assert ok["value"] == len(reqs)
    snap_on = eng_on.metrics_snapshot()
    assert snapshot_series(snap_on, "histograms",
                           "request_ttft_seconds")["count"] == len(reqs)


def test_engine_snapshot_exposition_round_trip(tiny_lm):
    cfg, model, params = tiny_lm
    eng = Engine(model, params, EngineConfig(num_slots=2, max_len=32))
    eng.warmup(_requests(cfg, [6], [4]))
    for r in _requests(cfg, [6, 8], [4, 4], seed=5):
        eng.submit(r)
    eng.run()
    snap = eng.metrics_snapshot()
    parsed = parse_exposition(eng.metrics.to_prometheus())
    for fam in ("engine_decode_steps_total", "engine_requests_total",
                "request_ttft_seconds_bucket", "engine_queue_depth",
                "engine_slots_active"):
        assert fam in parsed, fam
    dec = snapshot_series(snap, "counters", "engine_decode_steps_total")
    assert dec["value"] == eng.decode_steps > 0
    (key, val), = [kv for kv in parsed["engine_decode_steps_total"].items()]
    assert val == eng.decode_steps
