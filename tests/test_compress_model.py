"""Whole-model compression driver across families and methods."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.core.compress import (CompressionConfig, compress_model,
                                 effective_group, get_linear)
from repro.models import build_model, make_batch

FAMILY_ARCHS = ["granite-8b", "qwen3-moe-235b-a22b", "falcon-mamba-7b",
                "zamba2-1.2b", "internvl2-1b", "musicgen-medium"]


def _setup(arch, n_batches=2):
    cfg = get_tiny_config(arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batches = [make_batch(cfg, jax.random.PRNGKey(i), 2, 24)
               for i in range(n_batches)]
    return cfg, model, params, batches


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_compress_every_family(arch):
    cfg, model, params, batches = _setup(arch)
    ccfg = CompressionConfig(method="awp_prune", ratio=0.5)
    cp, reports = compress_model(model, params, batches, ccfg)
    assert len(reports) > 0
    mean_sp = np.mean([r.sparsity for r in reports])
    assert mean_sp > 0.4, (arch, mean_sp)
    loss, _ = jax.jit(model.loss)(cp, batches[0])
    assert not bool(jnp.isnan(loss))


@pytest.mark.parametrize("method", ["magnitude", "wanda", "awp_prune",
                                    "rtn", "awq", "awp_quant",
                                    "awp_quant_scaled", "awp_joint",
                                    "wanda_awq", "awq_wanda",
                                    "awp_prune_nm"])
def test_all_methods_on_dense(method):
    cfg, model, params, batches = _setup("granite-8b", 1)
    ccfg = CompressionConfig(method=method, ratio=0.5, bits=4, group_size=32)
    cp, reports = compress_model(model, params, batches, ccfg)
    loss, _ = jax.jit(model.loss)(cp, batches[0])
    assert not bool(jnp.isnan(loss)), method
    assert all(np.isfinite(r.loss_after) for r in reports)


@pytest.mark.parametrize("method", ["sparsegpt", "gptq"])
def test_obs_baselines_on_dense(method):
    """numpy-path baselines (slower): single block subset via skip list."""
    cfg, model, params, batches = _setup("granite-8b", 1)
    ccfg = CompressionConfig(method=method, ratio=0.5, bits=4, group_size=32,
                             skip=("wq", "wk", "wv", "wo"))
    cp, reports = compress_model(model, params, batches, ccfg)
    loss, _ = jax.jit(model.loss)(cp, batches[0])
    assert not bool(jnp.isnan(loss)), method


def test_moe_per_expert_calibration():
    """Every routed expert gets its own covariance; never-routed experts are
    left dense (stat.n == 0 guard)."""
    cfg, model, params, batches = _setup("qwen3-moe-235b-a22b")
    ccfg = CompressionConfig(method="wanda", ratio=0.5)
    cp, reports = compress_model(model, params, batches, ccfg)
    moe_reports = [r for r in reports if r.name.startswith("moe_")]
    assert len(moe_reports) > 0
    for r in moe_reports:
        assert r.sparsity > 0.4


def test_zamba2_shared_block_compressed_once():
    cfg, model, params, batches = _setup("zamba2-1.2b")
    ccfg = CompressionConfig(method="magnitude", ratio=0.5)
    cp, reports = compress_model(model, params, batches, ccfg)
    shared = [r for r in reports if r.block == cfg.num_layers]
    assert len(shared) >= 6          # wq wk wv wo (+wg) wu wd


def test_effective_group():
    assert effective_group(256, 128) == 128
    assert effective_group(96, 128) == 96
    assert effective_group(100, 64) == 50
    # odd / prime fan-ins (O(√d) divisor search, not linear descent)
    assert effective_group(97, 64) == 1          # prime: only trivial divisor
    assert effective_group(99, 64) == 33
    assert effective_group(81, 27) == 27
    assert effective_group(1, 128) == 1
    for d_in in (7, 30, 97, 99, 128, 121, 1009):
        for g in (1, 2, 32, 64, 128):
            got = effective_group(d_in, g)
            assert d_in % got == 0 and got <= max(g, 1)
            # matches the reference linear descent
            ref = min(g, d_in)
            while d_in % ref:
                ref -= 1
            assert got == ref, (d_in, g, got, ref)


def test_get_linear_orientation():
    cfg, model, params, _ = _setup("granite-8b", 1)
    w = get_linear(params, ("blocks", "mlp", "wu"), 0)
    assert w.shape == (cfg.d_ff, cfg.d_model)      # paper orientation
