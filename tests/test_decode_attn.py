"""Fused flash-decode attention kernel: parity matrix vs the reference
dequant-then-attend path over slot/paged layouts, dense/INT8 storage,
head-group sizes, and ragged per-slot lengths (length-0 and full-cache
slots included) — plus the engine-level greedy bit-parity contract for the
``use_fused_decode`` escape hatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.kernels import ops
from repro.models import build_model
from repro.models.layers import decode_attention
from repro.serving import Engine, EngineConfig, GenerationRequest, \
    SamplingParams
from repro.serving.kv_cache import QuantizedKV, fused_decode_attn, \
    kv_quantize

D = 16
T = 40
LENS = [0, 1, 17, 23, 40]        # parked, single-token, ragged, full cache


def _qkv(rng, b, t, hk, g, dtype=jnp.float32):
    h = hk * g
    q = jnp.asarray(rng.normal(size=(b, h, D)), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, hk, D)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, hk, D)), dtype)
    return q, k, v


def _paged_pool(rng, k, v, lens, page):
    """Scatter contiguous (B, T, Hk, D) rows into a shuffled page pool;
    unused table entries carry the sentinel (== num_pages)."""
    b, t = k.shape[0], k.shape[1]
    npg = -(-t // page)
    num_pages = b * npg + 3                     # spare pages stay garbage
    pool_k = np.asarray(rng.normal(size=(num_pages, page) + k.shape[2:]),
                        np.float32)
    pool_v = np.asarray(rng.normal(size=pool_k.shape), np.float32)
    table = np.full((b, npg), num_pages, np.int32)
    order = rng.permutation(num_pages)
    nxt = 0
    for row in range(b):
        for c in range(-(-int(lens[row]) // page)):
            p = int(order[nxt]); nxt += 1
            table[row, c] = p
            n = min(page, int(lens[row]) - c * page)
            pool_k[p, :n] = np.asarray(k[row, c * page:c * page + n])
            pool_v[p, :n] = np.asarray(v[row, c * page:c * page + n])
    return (jnp.asarray(pool_k), jnp.asarray(pool_v), jnp.asarray(table),
            num_pages)


# ---------------------------------------------------------------------------
# slot layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hk,g", [(1, 1), (2, 1), (2, 4)])
def test_slot_dense_parity_ragged_lengths(hk, g):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, len(LENS), T, hk, g)
    lens = jnp.asarray(LENS, jnp.int32)
    ref = ops.decode_attn(q, k, v, lens, use_pallas=False)
    fused = ops.decode_attn(q, k, v, lens, block_t=16)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert np.all(np.asarray(fused[0]) == 0.0)       # length-0 → exact zeros
    # the reference path itself must agree with decode_attention (the
    # pre-fusion masked-softmax read) on live rows
    live = [i for i, l in enumerate(LENS) if l > 0]
    pos = (lens - 1)[jnp.asarray(live)][:, None]
    da = decode_attention(q[jnp.asarray(live)][:, None], k[jnp.asarray(live)],
                          v[jnp.asarray(live)], pos)[:, 0]
    np.testing.assert_allclose(np.asarray(ref)[live], np.asarray(da),
                               atol=1e-5, rtol=1e-5)


def test_slot_single_tile_is_bit_identical():
    """With one K tile covering the whole cache the online softmax visits
    every key in one pass — fused output is bit-identical to the oracle."""
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, len(LENS), T, 2, 2)
    lens = jnp.asarray(LENS, jnp.int32)
    ref = ops.decode_attn(q, k, v, lens, use_pallas=False)
    fused = ops.decode_attn(q, k, v, lens, block_t=T)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))


def test_slot_tile_size_invariance():
    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, len(LENS), T, 2, 2)
    lens = jnp.asarray(LENS, jnp.int32)
    outs = [ops.decode_attn(q, k, v, lens, block_t=bt)
            for bt in (7, 16, 40, 512)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("group", [D, D // 2])
def test_slot_int8_parity(group):
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, len(LENS), T, 2, 3)
    qk, qv = kv_quantize(k, group), kv_quantize(v, group)
    lens = jnp.asarray(LENS, jnp.int32)
    args = (q, qk.codes, qv.codes, lens, qk.scale, qk.zero, qv.scale,
            qv.zero)
    ref = ops.decode_attn(*args, group_size=group, use_pallas=False)
    fused = ops.decode_attn(*args, group_size=group, block_t=16)
    # same in-tile dequant numerics as the reference expansion → float-tight
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert np.all(np.asarray(fused[0]) == 0.0)


# ---------------------------------------------------------------------------
# paged layout
# ---------------------------------------------------------------------------

def test_paged_dense_parity_and_slot_equivalence():
    rng = np.random.default_rng(4)
    q, k, v = _qkv(rng, len(LENS), T, 2, 2)
    lens = jnp.asarray(LENS, jnp.int32)
    pool_k, pool_v, table, _ = _paged_pool(rng, k, v, LENS, page=8)
    ref = ops.decode_attn_paged(q, pool_k, pool_v, table, lens,
                                use_pallas=False)
    fused = ops.decode_attn_paged(q, pool_k, pool_v, table, lens)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    # the paged read attends the same written tokens as the slot read
    slot = ops.decode_attn(q, k, v, lens, use_pallas=False)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(slot),
                               atol=1e-5, rtol=1e-5)
    assert np.all(np.asarray(fused[0]) == 0.0)       # all-sentinel row


def test_paged_int8_parity():
    rng = np.random.default_rng(5)
    group = D // 2
    q, k, v = _qkv(rng, len(LENS), T, 2, 2)
    lens = jnp.asarray(LENS, jnp.int32)
    page = 8
    pool_k, pool_v, table, _ = _paged_pool(rng, k, v, LENS, page=page)
    qk, qv = kv_quantize(pool_k, group), kv_quantize(pool_v, group)
    args = (q, qk.codes, qv.codes, table, lens, qk.scale, qk.zero,
            qv.scale, qv.zero)
    ref = ops.decode_attn_paged(*args, group_size=group, use_pallas=False)
    fused = ops.decode_attn_paged(*args, group_size=group)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# the serving-facing wrapper + failure semantics
# ---------------------------------------------------------------------------

def test_fused_decode_attn_wrapper_shapes_and_dtype():
    rng = np.random.default_rng(6)
    q, k, v = _qkv(rng, len(LENS), T, 2, 2, dtype=jnp.bfloat16)
    positions = (jnp.asarray(LENS, jnp.int32) - 1)[:, None]
    out = fused_decode_attn(q[:, None], k, v, positions)
    assert out.shape == (len(LENS), 1, 4, D) and out.dtype == jnp.bfloat16
    qk, qv = kv_quantize(k, D), kv_quantize(v, D)
    out_q = fused_decode_attn(q[:, None], qk, qv, positions)
    assert out_q.shape == out.shape and out_q.dtype == jnp.bfloat16
    assert isinstance(qk, QuantizedKV)


def test_nan_rows_propagate_to_output():
    """Poisoned cache rows must surface as non-finite attention output —
    the engine's decode guard fails the slot on it (never silently zero)."""
    rng = np.random.default_rng(7)
    q, k, v = _qkv(rng, 3, T, 2, 2)
    k = k.at[1].set(jnp.nan)
    lens = jnp.asarray([T, T, 0], jnp.int32)
    fused = ops.decode_attn(q, k, v, lens, block_t=16)
    assert bool(jnp.all(jnp.isfinite(fused[0])))
    assert not bool(jnp.all(jnp.isfinite(fused[1])))
    assert np.all(np.asarray(fused[2]) == 0.0)       # parked row unaffected


# ---------------------------------------------------------------------------
# engine-level greedy bit-parity: use_fused_decode on vs off
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_tiny_config("llama32-1b")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _run(model, params, cfg, **ecfg_kw):
    rng = np.random.default_rng(11)
    reqs = [GenerationRequest(
                rid=i,
                prompt=rng.integers(1, cfg.vocab_size,
                                    size=int(l)).astype(np.int32),
                max_new_tokens=int(g), sampling=SamplingParams())
            for i, (l, g) in enumerate(zip([5, 11, 3, 8], [6, 4, 8, 5]))]
    eng = Engine(model, params, EngineConfig(num_slots=3, max_len=24,
                                             **ecfg_kw))
    for r in reqs:
        eng.submit(r)
    return {r.rid: r.tokens for r in eng.run()}


@pytest.mark.parametrize("storage", [
    dict(kv_dtype=jnp.float32),
    dict(kv_dtype=jnp.bfloat16, kv_quantized=True),
    dict(kv_dtype=jnp.float32, kv_layout="paged", page_size=8),
    dict(kv_dtype=jnp.bfloat16, kv_quantized=True, kv_layout="paged",
         page_size=8),
])
def test_engine_greedy_bit_parity_fused_vs_reference(tiny_lm, storage):
    cfg, model, params = tiny_lm
    fused = _run(model, params, cfg, use_fused_decode=True, **storage)
    ref = _run(model, params, cfg, use_fused_decode=False, **storage)
    assert fused == ref
