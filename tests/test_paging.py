"""Paged KV-cache subsystem: allocator/refcount invariants, prefix-cache
match/insert/evict semantics, resume-ticket ordering, paged-vs-slot greedy
bit-parity (dense + INT8), copy-on-write stability of shared-prefix pages,
and preempt-then-resume parity under page-pool pressure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.models import build_model
from repro.serving import (Engine, EngineConfig, GenerationRequest,
                           PageAllocator, PrefixCache, SamplingParams,
                           Scheduler, pow2_at_least)
from repro.serving.scheduler import ResumeTicket


# ---------------------------------------------------------------------------
# shared tiny model (compiles are the dominant test cost)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_tiny_config("llama32-1b")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, lens, gens, rng=None, **sampling):
    rng = rng or np.random.default_rng(0)
    return [GenerationRequest(
                rid=i,
                prompt=rng.integers(1, cfg.vocab_size, size=l).astype(np.int32),
                max_new_tokens=g,
                sampling=SamplingParams(seed=100 + i, **sampling))
            for i, (l, g) in enumerate(zip(lens, gens))]


def _run(engine, reqs):
    engine.warmup(reqs)
    for r in reqs:
        engine.submit(r)
    results = engine.run()
    return {r.rid: r.tokens for r in results}


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------

def test_pow2_at_least():
    assert [pow2_at_least(n) for n in (1, 2, 3, 4, 5, 9)] == [1, 2, 4, 4, 8, 16]


def test_page_allocator_refcounts_and_oom():
    a = PageAllocator(4)
    assert a.num_free == 4 and a.pages_in_use == 0
    pages = a.alloc(3)
    assert len(pages) == 3 and len(set(pages)) == 3
    assert a.pages_in_use == 3 and a.peak_in_use == 3
    assert a.alloc(2) is None                  # OOM: all-or-nothing, no leak
    assert a.pages_in_use == 3                 # failed alloc left state alone
    a.incref([pages[0]])
    assert a.refcount(pages[0]) == 2
    assert a.decref([pages[0]]) == 0           # still referenced: not freed
    assert a.decref(pages) == 3                # drops all to zero
    assert a.pages_in_use == 0 and a.num_free == 4
    assert a.peak_in_use == 3                  # high-water mark sticks


def test_page_allocator_double_free_and_incref_on_free_raise():
    a = PageAllocator(2)
    (p,) = a.alloc(1)
    a.decref([p])
    with pytest.raises(ValueError):
        a.decref([p])                          # double free
    with pytest.raises(ValueError):
        a.incref([p])                          # resurrecting a freed page


# ---------------------------------------------------------------------------
# prefix cache
# ---------------------------------------------------------------------------

def test_prefix_cache_match_insert_and_lru_evict():
    a = PageAllocator(8)
    pc = PrefixCache(page_size=4, allocator=a)
    toks = np.arange(12, dtype=np.int32)

    assert pc.match(toks) == ([], 0)           # cold: no pages
    pages = a.alloc(3)
    pc.insert(toks, pages)                     # caches pages 0,1,2 (12 // 4)
    assert a.refcount(pages[0]) == 2           # cache holds its own ref
    a.decref(pages)                            # requester done: cache keeps 1

    # full-prefix match is capped at (len-1)//page_size: the last token
    # must run through prefill so the request samples its first output
    hit, n = pc.match(toks)
    assert hit == pages[:2] and n == 8
    assert a.refcount(pages[0]) == 2           # match increfs for the caller
    a.decref(hit)

    # divergence mid-prefix only matches the shared pages
    fork = toks.copy()
    fork[5] = 99
    hit, n = pc.match(fork)
    assert hit == pages[:1] and n == 4
    a.decref(hit)

    # eviction walks LRU order but only takes refcount-1 (unshared) pages
    held = pc.match(toks)[0]                   # pin pages 0,1
    assert pc.evict(need=3) >= 1               # page 2 is evictable
    assert a.refcount(pages[2]) == 0
    assert a.refcount(pages[0]) == 2           # pinned pages survived
    a.decref(held)

    pc.clear()
    assert a.pages_in_use == 0


def test_prefix_cache_insert_is_idempotent_on_shared_pages():
    a = PageAllocator(8)
    pc = PrefixCache(page_size=4, allocator=a)
    toks = np.arange(12, dtype=np.int32)
    pages = a.alloc(3)
    assert pc.insert(toks, pages) == 3
    a.decref(pages)
    assert a.refcount(pages[0]) == 1           # cache's own reference
    # a second request with the same prompt re-inserts the same hashes:
    # existing entries are touched, not re-counted
    hit, _ = pc.match(toks)                    # pages[:2], +1 ref each
    assert pc.insert(toks, hit + pages[2:]) == 0
    assert a.refcount(pages[0]) == 2           # cache(1) + match(1), no creep
    assert a.refcount(pages[2]) == 1           # cache only (match was capped)
    a.decref(hit)
    pc.clear()
    assert a.pages_in_use == 0


# ---------------------------------------------------------------------------
# scheduler: resume tickets
# ---------------------------------------------------------------------------

def test_resume_ticket_ordering_and_admission():
    s = Scheduler(num_slots=1, max_len=64)
    reqs = [GenerationRequest(rid=i, prompt=np.ones(4, np.int32),
                              max_new_tokens=4) for i in range(3)]
    for r in reqs:
        s.submit(r)
    slot, r0 = s.admit()
    s.slots[slot].generated = 2
    t0 = ResumeTicket(request=r0, generated=2, last_token=7, pos=5, n_pages=1)
    s.preempt(slot, t0)
    # the ticket outranks every never-admitted request (r0.seq is oldest)
    assert s.peek() is t0
    # batched admission never pops a ticket — the engine must restore pages
    assert s.admit_batch() is None
    slot, head = s.admit_head()
    assert head is t0
    assert s.slots[slot].generated == 2        # decode progress survives

    # a second, younger ticket queues BEHIND the older one
    t1 = ResumeTicket(request=reqs[1], generated=1, last_token=3, pos=5,
                      n_pages=1)
    s.preempt(slot, t0)                        # r0 back at the head
    s.requeue(t1)
    assert s.peek() is t0 and s.queue[1] is t1
    assert isinstance(s.queue[2], GenerationRequest)


# ---------------------------------------------------------------------------
# engine parity: paged vs slot
# ---------------------------------------------------------------------------

def test_paged_matches_slot_greedy_dense(tiny_lm, assert_flat_compiles):
    """Acceptance: mixed-length greedy trace through the paged engine is
    bit-identical to the slot engine (page_size divides max_len)."""
    cfg, model, params = tiny_lm
    max_len = 64
    reqs = _requests(cfg, lens=[5, 13, 8, 21, 3, 16], gens=[6, 3, 9, 4, 8, 5])
    slot = Engine(model, params, EngineConfig(num_slots=4, max_len=max_len))
    want = _run(slot, reqs)
    paged = Engine(model, params, EngineConfig(
        num_slots=4, max_len=max_len, kv_layout="paged", page_size=8))
    compiled = paged.warmup(reqs)
    with assert_flat_compiles(paged, compiled):  # no recompilation after warmup
        for r in reqs:
            paged.submit(r)
        got = {r.rid: r.tokens for r in paged.run()}
    for req in reqs:
        assert got[req.rid] == want[req.rid], req.rid
    assert paged.alloc.pages_in_use == paged.page_stats()["prefix_cached_pages"]
    assert paged.scheduler.idle


def test_paged_int8_matches_slot_int8(tiny_lm):
    """The paged pool with per-page INT8 scales reproduces the slot
    engine's INT8 outputs exactly — same quantizer, same group shapes."""
    cfg, model, params = tiny_lm
    max_len = 32
    reqs = _requests(cfg, lens=[6, 11, 9], gens=[5, 4, 6])
    slot = Engine(model, params, EngineConfig(
        num_slots=2, max_len=max_len, kv_quantized=True))
    want = _run(slot, reqs)
    paged = Engine(model, params, EngineConfig(
        num_slots=2, max_len=max_len, kv_quantized=True,
        kv_layout="paged", page_size=8))
    got = _run(paged, reqs)
    for req in reqs:
        assert got[req.rid] == want[req.rid], req.rid


def test_paged_prefix_hit_reuses_pages_copy_free(tiny_lm):
    """Requests sharing a prompt prefix reuse the cached pages (no copy):
    hits are counted, reused tokens skip prefill, the shared pages' bytes
    are untouched by the diverging request (CoW by construction), and
    outputs stay bit-identical to the slot engine."""
    cfg, model, params = tiny_lm
    max_len, pg = 64, 8
    rng = np.random.default_rng(3)
    prefix = rng.integers(1, cfg.vocab_size, size=24).astype(np.int32)
    reqs = []
    for i in range(4):
        tail = rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)
        reqs.append(GenerationRequest(rid=i,
                                      prompt=np.concatenate([prefix, tail]),
                                      max_new_tokens=5,
                                      sampling=SamplingParams(seed=100 + i)))
    slot = Engine(model, params, EngineConfig(num_slots=2, max_len=max_len))
    want = _run(slot, reqs)

    paged = Engine(model, params, EngineConfig(
        num_slots=2, max_len=max_len, kv_layout="paged", page_size=pg))
    paged.warmup(reqs)
    paged.submit(reqs[0])
    first = {r.rid: r.tokens for r in paged.run()}
    # the finished request left its full-page prefix in the cache
    shared, n_tok = paged.prefix.match(reqs[1].prompt)
    assert n_tok >= len(prefix) - pg           # ≥ the shared full pages
    snap = np.asarray(paged.kv["k"][:, shared])
    paged.alloc.decref(shared)

    for r in reqs[1:]:
        paged.submit(r)
    rest = {r.rid: r.tokens for r in paged.run()}
    stats = paged.page_stats()
    assert stats["prefix_hits"] == 3 and stats["prefix_misses"] == 1
    assert stats["prefix_hit_tokens"] >= 3 * (len(prefix) - pg)
    # shared pages byte-stable: diverging requests wrote only fresh pages
    np.testing.assert_array_equal(np.asarray(paged.kv["k"][:, shared]), snap)
    got = {**first, **rest}
    for req in reqs:
        assert got[req.rid] == want[req.rid], req.rid


def test_paged_preempt_then_resume_matches_slot(tiny_lm):
    """Acceptance: an oversubscribed pool (num_pages < slots*pages_per_slot)
    forces preemption mid-decode; spilled requests resume from restored
    pages and still produce bit-identical greedy output."""
    cfg, model, params = tiny_lm
    max_len = 48                               # 6 pages/slot at page_size 8
    reqs = _requests(cfg, lens=[30, 29, 31, 28], gens=[12, 12, 12, 12])
    slot = Engine(model, params, EngineConfig(num_slots=3, max_len=max_len))
    want = _run(slot, reqs)
    paged = Engine(model, params, EngineConfig(
        num_slots=3, max_len=max_len, kv_layout="paged", page_size=8,
        num_pages=9, prefix_caching=False))    # 9 < 3*6: decode must evict
    got = _run(paged, reqs)
    stats = paged.page_stats()
    assert stats["preemptions"] > 0 and stats["resumes"] > 0
    assert stats["pages_spilled"] > 0
    assert stats["peak_pages_in_use"] <= 9
    for req in reqs:
        assert got[req.rid] == want[req.rid], req.rid
    assert paged.alloc.pages_in_use == 0       # everything returned


def test_paged_pool_must_fit_one_request(tiny_lm):
    cfg, model, params = tiny_lm
    with pytest.raises(ValueError):
        Engine(model, params, EngineConfig(
            num_slots=2, max_len=64, kv_layout="paged", page_size=8,
            num_pages=7))                      # < pages_per_slot (8)


# ---------------------------------------------------------------------------
# mixed-bucket admission
# ---------------------------------------------------------------------------

def test_mixed_admission_one_dispatch_same_tokens(tiny_lm):
    """mixed=True admits a short/long interleave in ONE right-padded
    prefill dispatch (vs one per bucket flip) with bit-identical output."""
    cfg, model, params = tiny_lm
    max_len = 64
    lens = [5, 13, 6, 20]                      # buckets 8,16,8,32: 4 flips
    reqs = _requests(cfg, lens=lens, gens=[4, 5, 6, 3])
    plain = Engine(model, params, EngineConfig(num_slots=4, max_len=max_len))
    want = _run(plain, reqs)
    assert plain.prefill_dispatches == 4       # one per bucket flip
    mixed = Engine(model, params, EngineConfig(
        num_slots=4, max_len=max_len, mixed_admission=True))
    got = _run(mixed, reqs)
    assert mixed.prefill_dispatches == 1       # the whole head-run at once
    for req in reqs:
        assert got[req.rid] == want[req.rid], req.rid


# ---------------------------------------------------------------------------
# preemption edge cases (lifecycle hardening)
# ---------------------------------------------------------------------------

def test_preempt_sole_active_request_resumes_bit_identically(tiny_lm):
    """Preempting the ONLY active request (impossible organically — the
    oldest is never victimized — but reachable via operator action) must
    fully round-trip: ticket queued, pool drained, then resumed onto a
    fresh slot with bit-identical continuation."""
    cfg, model, params = tiny_lm
    req = _requests(cfg, lens=[13], gens=[10])[0]
    slot = Engine(model, params, EngineConfig(num_slots=2, max_len=32))
    want = _run(slot, req and [req])[0]

    paged = Engine(model, params, EngineConfig(
        num_slots=2, max_len=32, kv_layout="paged", page_size=8,
        prefix_caching=False))
    paged.warmup([req])
    paged.submit(req)
    paged.step()
    paged.step()                                # prefill + a decode step
    live = paged.scheduler.active_slots()
    assert live == [0] or len(live) == 1
    paged._preempt(live[0])                     # white-box: sole survivor
    assert paged.alloc.pages_in_use == 0        # everything spilled out
    assert paged.scheduler.num_active == 0
    paged.check_invariants()
    got = {r.rid: r for r in paged.run()}[0]
    assert got.status == "ok" and got.tokens == want
    assert paged.preemptions == 1 and paged.resumes == 1
    assert paged.alloc.pages_in_use == 0


def test_resume_waits_until_pool_frees_pages(tiny_lm):
    """A ticket whose pages no longer fit the free pool must WAIT (never
    preempt a strictly-older request) and resume bit-identically once the
    older request retires and frees its pages."""
    cfg, model, params = tiny_lm
    # pg=4, max_len=16 → 4 pages/slot; pool of 6 pages, 2 slots. Both
    # requests grow from 2 to 4 pages, so A's extension at pos 12 must
    # preempt B, and B's 3-page ticket can't fit until A retires.
    reqs = _requests(cfg, lens=[7, 7], gens=[9, 9])
    slot = Engine(model, params, EngineConfig(num_slots=2, max_len=16))
    want = _run(slot, reqs)

    paged = Engine(model, params, EngineConfig(
        num_slots=2, max_len=16, kv_layout="paged", page_size=4,
        num_pages=6, prefix_caching=False))
    paged.warmup(reqs)
    for r in reqs:
        paged.submit(r)
    waited = 0
    got = {}
    for _ in range(200):
        if paged.scheduler.idle:
            break
        paged.step()
        paged.check_invariants()
        head = paged.scheduler.peek()
        if isinstance(head, ResumeTicket) and paged.scheduler.num_active:
            waited += 1                         # ticket parked behind elder
    assert paged.scheduler.idle
    got = {r.rid: r.tokens for r in paged._done}
    assert paged.preemptions >= 1 and paged.resumes >= 1
    assert waited >= 1                          # the wait actually happened
    for r in reqs:
        assert got[r.rid] == want[r.rid], r.rid
    assert paged.alloc.pages_in_use == 0


def test_cancel_while_spilled_frees_ticket_and_payload(tiny_lm):
    """Cancelling a preempted (spilled) request drops its ticket from the
    queue, emits its pre-preemption partial tokens, and leaves the pool
    clean — the host payload dies with the ticket."""
    cfg, model, params = tiny_lm
    reqs = _requests(cfg, lens=[13, 13], gens=[8, 8])
    paged = Engine(model, params, EngineConfig(
        num_slots=2, max_len=32, kv_layout="paged", page_size=8,
        prefix_caching=False))
    paged.warmup(reqs)
    for r in reqs:
        paged.submit(r)
    paged.step()
    paged.step()
    victim = paged.scheduler.active_slots()[-1]
    rid = paged.scheduler.slots[victim].request.rid
    pre_tokens = list(paged._results[rid].tokens)
    paged._preempt(victim)                      # spill to a host ticket
    assert isinstance(paged.scheduler.peek(), ResumeTicket)
    assert paged.cancel(rid)
    assert paged.scheduler.peek() is None or not isinstance(
        paged.scheduler.peek(), ResumeTicket)   # ticket gone from the queue
    paged.check_invariants()
    out = {r.rid: r for r in paged.run()}
    assert out[rid].status == "cancelled"
    assert out[rid].tokens == pre_tokens        # partial tokens survive
    other = [r for r in out.values() if r.rid != rid][0]
    assert other.status == "ok" and len(other.tokens) == 8
    assert paged.alloc.pages_in_use == 0
    assert paged.resumes == 0                   # the ticket never resumed
