"""Batched compression engine: bucketing rules, batched-vs-sequential
parity on dense + MoE models, fallback for methods without a batched
implementation, and the batched PGD core against the per-layer reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.core import awp, batched, calibration as calib, registry
from repro.core.compress import CompressionConfig, compress_model
from repro.core.specs import Policy, PruneSpec, QuantSpec
from repro.models import build_model, make_batch


def _setup(arch, n_batches=2):
    cfg = get_tiny_config(arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batches = [make_batch(cfg, jax.random.PRNGKey(i), 2, 24)
               for i in range(n_batches)]
    return cfg, model, params, batches


def _both_engines(model, params, batches, policy):
    cp_s, rep_s = compress_model(model, params, batches, policy,
                                 engine="sequential")
    cp_b, rep_b = compress_model(model, params, batches, policy,
                                 engine="batched")
    return (cp_s, rep_s), (cp_b, rep_b)


def _assert_parity(seq, bat, atol=1e-5):
    (cp_s, rep_s), (cp_b, rep_b) = seq, bat
    ls = {r.qualname: r.loss_after for r in rep_s}
    lb = {r.qualname: r.loss_after for r in rep_b}
    assert set(ls) == set(lb)
    for k in ls:
        assert abs(ls[k] - lb[k]) <= atol, (k, ls[k], lb[k])
    for a, b in zip(jax.tree.leaves(cp_s), jax.tree.leaves(cp_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)


# ---------------------------------------------------------------------------
# bucketing rules
# ---------------------------------------------------------------------------

def _work(name, w, spec, d_in=None):
    d_in = d_in or w.shape[1]
    return batched.LayerWork(name, name, ("blocks", name), 0, spec,
                             calib.init(d_in), w)


def test_bucket_key_groups_same_shape_same_spec(rng):
    spec = PruneSpec(ratio=0.5)
    w1 = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
    works = [_work("a", w1, spec), _work("b", w3, spec), _work("c", w2, spec),
             _work("d", w1, PruneSpec(ratio=0.25)),
             _work("e", w2, spec)]
    buckets = batched.bucket_works(works)
    assert list(buckets.values()) == [[0, 2, 4], [1], [3]]


def test_moe_experts_land_in_one_bucket():
    """All E experts of wu/wg (and separately wd) share a bucket; q/k/v
    bucket by their (possibly GQA-distinct) shapes."""
    cfg, model, params, batches = _setup("qwen3-moe-235b-a22b", 1)
    from repro.core.compress import _block_works, _fold_captures, as_policy
    pol = as_policy(CompressionConfig(method="wanda", ratio=0.5))
    stats = {}
    hs = [model.embed(params, b) for b in batches]
    _, caps = model.block_apply_one(params, 0, hs[0], capture=True)
    _fold_captures(stats, caps, cfg.num_experts)
    works = _block_works(model, params, 0, stats, pol)
    sizes = sorted(len(v) for v in batched.bucket_works(works).values())
    e = cfg.num_experts
    # {wk,wv} (GQA kv dim), {wq,wo} (d×d), {wd}, {wg,wu}
    assert sizes == [2, 2, e, 2 * e]


# ---------------------------------------------------------------------------
# engine parity (the acceptance bar: ≤1e-5 on params and per-layer losses)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,kw", [
    ("awp_prune", dict(ratio=0.5)),
    ("awp_quant", dict(bits=4, group_size=32)),
    ("wanda", dict(ratio=0.5)),
])
def test_parity_on_moe(method, kw):
    cfg, model, params, batches = _setup("qwen3-moe-235b-a22b")
    ccfg = CompressionConfig(method=method, **kw)
    seq, bat = _both_engines(model, params, batches, ccfg)
    assert len(seq[1]) == len(bat[1]) > 0
    _assert_parity(seq, bat)


@pytest.mark.parametrize("method,kw", [
    ("awp_prune", dict(ratio=0.5)),
    ("awp_joint", dict(ratio=0.5, bits=4, group_size=32)),
    ("magnitude", dict(ratio=0.5)),
])
def test_parity_on_dense(method, kw):
    cfg, model, params, batches = _setup("granite-8b", 1)
    ccfg = CompressionConfig(method=method, **kw)
    seq, bat = _both_engines(model, params, batches, ccfg)
    _assert_parity(seq, bat)


def test_parity_mixed_policy_on_moe():
    """Mixed per-layer policy: buckets must split on spec, not just shape."""
    cfg, model, params, batches = _setup("qwen3-moe-235b-a22b", 1)
    pol = Policy({"*.attn.*": QuantSpec(bits=8, group_size=32)},
                 default=PruneSpec(ratio=0.5))
    seq, bat = _both_engines(model, params, batches, pol)
    _assert_parity(seq, bat)
    methods = {r.qualname: r.method for r in bat[1]}
    assert methods["blocks.0.attn.wq"] == "awp_quant"
    assert methods["blocks.0.moe.wu.0"] == "awp_prune"


def test_batched_fallback_for_unbatched_method():
    """A method with no batched implementation runs per-item inside the
    bucket loop with identical results."""
    @registry.register("test_negate", spec_cls=QuantSpec)
    def _negate(w, stats, spec):
        return registry.CompressResult(theta=-w)

    assert registry.get_batched("test_negate") is None
    cfg, model, params, batches = _setup("granite-8b", 1)
    cp, report = compress_model(model, params, batches,
                                QuantSpec(method="test_negate"),
                                engine="batched")
    assert len(report) > 0
    np.testing.assert_allclose(
        np.asarray(cp["blocks"]["attn"]["wq"][0]),
        -np.asarray(params["blocks"]["attn"]["wq"][0]), rtol=1e-6)


def test_batched_packed_artifacts_bit_exact():
    """Batched quant packing must keep dequant(codes) == written weight."""
    cfg, model, params, batches = _setup("qwen3-moe-235b-a22b", 1)
    cp, report = compress_model(model, params, batches,
                                QuantSpec(bits=4, group_size=32),
                                engine="batched")
    from repro.core.compress import get_linear
    for name, art in report.artifacts.items():
        qt = art.result.qtensor
        assert qt is not None
        w = get_linear(cp, art.path, art.layer)
        np.testing.assert_array_equal(np.asarray(qt.dequant()),
                                      np.asarray(w))


# ---------------------------------------------------------------------------
# batched PGD core vs per-layer reference
# ---------------------------------------------------------------------------

def test_pgd_batched_matches_sequential_per_item(rng):
    """Per-item convergence masking: every item of the stack must stop at
    its own iteration count with the exact sequential trajectory."""
    b, d_out, d_in, k = 5, 12, 16, 8
    w_b = jnp.asarray(rng.normal(size=(b, d_out, d_in)), jnp.float32)
    x = rng.normal(size=(b, 128, d_in)).astype(np.float32)
    # mixed conditioning → convergence counts differ across items: a
    # near-zero covariance and a low-rank one converge in O(1) iterations,
    # the rest run to the cap
    x[1] *= 1e-4
    x[3][:, 6:] = 0.0
    c_b = jnp.asarray(np.einsum("bti,btj->bij", x, x) / 128)

    res_b = batched.prune_batched(w_b, c_b, k, use_pallas=False)
    iters = np.asarray(res_b.iters)
    for i in range(b):
        res_i = awp.prune(w_b[i], c_b[i], k)
        assert int(res_i.iters) == iters[i]
        np.testing.assert_allclose(np.asarray(res_b.theta[i]),
                                   np.asarray(res_i.theta),
                                   rtol=1e-5, atol=1e-5)
    assert len(set(iters.tolist())) > 1, "want distinct convergence counts"


@pytest.mark.parametrize("nm", [None, (2, 4)])
def test_prune_batched_compacted_matches_monolithic(rng, nm):
    """Chunked PGD with between-chunk compaction of converged items must be
    bit-identical to the monolithic batched run: the projection is
    step-index-free, so restarting the loop per chunk (and re-stacking the
    survivors) leaves every item's trajectory untouched."""
    b, d_out, d_in, k = 5, 12, 16, 8
    w_b = jnp.asarray(rng.normal(size=(b, d_out, d_in)), jnp.float32)
    x = rng.normal(size=(b, 128, d_in)).astype(np.float32)
    x[1] *= 1e-4                   # converges in O(1) iters → compacted out
    x[3][:, 6:] = 0.0              # low-rank: also retires early
    c_b = jnp.asarray(np.einsum("bti,btj->bij", x, x) / 128)

    ref = batched.prune_batched(w_b, c_b, k, nm=nm, use_pallas=False)
    got = batched.prune_batched_compacted(w_b, c_b, k, nm=nm,
                                          chunk_iters=25, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got.theta), np.asarray(ref.theta))
    np.testing.assert_array_equal(np.asarray(got.iters), np.asarray(ref.iters))
    np.testing.assert_array_equal(np.asarray(got.grad_norm),
                                  np.asarray(ref.grad_norm))
    assert len(set(np.asarray(got.iters).tolist())) > 1


def test_quantize_batched_matches_sequential(rng):
    b, d_out, d_in = 4, 8, 32
    w_b = jnp.asarray(rng.normal(size=(b, d_out, d_in)), jnp.float32)
    x = rng.normal(size=(b, 64, d_in)).astype(np.float32)
    c_b = jnp.asarray(np.einsum("bti,btj->bij", x, x) / 64)
    res_b = batched.quantize_batched(w_b, c_b, 4, group_size=16,
                                     use_pallas=False)
    for i in range(b):
        res_i = awp.quantize(w_b[i], c_b[i], 4, group_size=16)
        np.testing.assert_allclose(np.asarray(res_b.theta[i]),
                                   np.asarray(res_i.theta),
                                   rtol=1e-5, atol=1e-5)
