"""Append the final regenerated roofline table to EXPERIMENTS.md and write
results/roofline_final.csv."""
import io
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline_report import load, fmt_row

HEADER = ("arch,shape,mesh,chips,t_compute_s,t_memory_s,t_collective_s,"
          "bottleneck,useful_flops_frac,roofline_frac,mem_GB_per_dev")


def main():
    rows = []
    for mesh in ("single", "multi"):
        for c in load(mesh):
            rows.append(fmt_row(c))
    csv = HEADER + "\n" + "\n".join(rows) + "\n"
    with open("results/roofline_final.csv", "w") as f:
        f.write(csv)

    md = ["\n### Appended final table (generated "
          "by scripts/finalize_roofline.py)\n", "```"]
    md.append(HEADER.replace(",", " | "))
    for r in rows:
        md.append(r.replace(",", " | "))
    md.append("```\n")
    text = open("EXPERIMENTS.md").read()
    marker = "### Appended final table"
    if marker in text:
        text = text[:text.index(marker) - 1]
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text + "\n".join(md))
    print(f"appended {len(rows)} rows")


if __name__ == "__main__":
    main()
