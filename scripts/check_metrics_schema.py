#!/usr/bin/env python
"""Validate a MetricsRegistry snapshot JSON against the checked-in schema.

CI runs this on the ``--metrics-json`` artifacts that ``repro.launch.serve``
and ``repro.launch.compress`` emit, so the snapshot shape is a contract:
dashboards and downstream tooling can rely on it across PRs.

    python scripts/check_metrics_schema.py SNAP.json \
        [--schema scripts/metrics_schema.json] \
        [--check-families] \
        [--require counters:engine_requests_total ...] \
        [--prom SNAP.json.prom --prom-require engine_requests_total ...]

The validator is a dependency-free subset of JSON Schema — ``type``,
``required``, ``properties``, ``additionalProperties`` (false or a schema),
``items``, ``enum``, ``minimum`` — which is all the schema file uses. On
top of the shape check it enforces histogram semantics the schema language
can't express: ``len(counts) == len(le) + 1`` (overflow slot) and
``count == sum(counts)``.  ``--require KIND:NAME`` asserts a metric family
is present; ``--prom-require NAME`` greps the text exposition for a family.

``--check-families`` validates every family NAME in the snapshot against
the schema's ``families`` contract — the same list the ``metrics-contract``
lint (``repro.lint``) keeps bidirectionally in sync with the code, shared
via ``repro.lint.contracts.load_schema_families`` so the runtime and
static checkers can never drift apart.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.lint.contracts import load_schema_families  # noqa: E402

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "integer": int,
    "number": (int, float),
    "boolean": bool,
    "null": type(None),
}


def validate(value, schema, path="$"):
    """Yield 'path: problem' strings; empty == valid."""
    typ = schema.get("type")
    if typ is not None:
        expected = _TYPES[typ]
        ok = isinstance(value, expected)
        if ok and typ in ("integer", "number") and isinstance(value, bool):
            ok = False                       # bool is not a JSON number here
        if typ == "number" and isinstance(value, bool):
            ok = False
        if not ok:
            yield f"{path}: expected {typ}, got {type(value).__name__}"
            return
    if "enum" in schema and value not in schema["enum"]:
        yield f"{path}: {value!r} not in enum {schema['enum']}"
    if "minimum" in schema and isinstance(value, (int, float)) \
            and not isinstance(value, bool) and value < schema["minimum"]:
        yield f"{path}: {value} < minimum {schema['minimum']}"
    if isinstance(value, dict):
        props = schema.get("properties", {})
        for key in schema.get("required", ()):
            if key not in value:
                yield f"{path}: missing required key {key!r}"
        addl = schema.get("additionalProperties", True)
        for key, sub in value.items():
            if key in props:
                yield from validate(sub, props[key], f"{path}.{key}")
            elif addl is False:
                yield f"{path}: unexpected key {key!r}"
            elif isinstance(addl, dict):
                yield from validate(sub, addl, f"{path}.{key}")
    if isinstance(value, list) and "items" in schema:
        for i, item in enumerate(value):
            yield from validate(item, schema["items"], f"{path}[{i}]")


def histogram_semantics(snap):
    for name, fam in snap.get("histograms", {}).items():
        for i, s in enumerate(fam.get("series", ())):
            where = f"$.histograms.{name}.series[{i}]"
            if len(s["counts"]) != len(s["le"]) + 1:
                yield (f"{where}: {len(s['counts'])} count slots for "
                       f"{len(s['le'])} bounds (need bounds+1)")
            if sum(s["counts"]) != s["count"]:
                yield f"{where}: count {s['count']} != sum(counts)"
            if list(s["le"]) != sorted(s["le"]):
                yield f"{where}: bucket bounds not sorted"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("snapshot", help="metrics snapshot JSON to validate")
    ap.add_argument("--schema", default="scripts/metrics_schema.json")
    ap.add_argument("--check-families", action="store_true",
                    help="every family name in the snapshot must be "
                         "declared in the schema's 'families' contract")
    ap.add_argument("--require", action="append", default=[],
                    metavar="KIND:NAME",
                    help="assert a family exists, e.g. "
                         "counters:engine_requests_total")
    ap.add_argument("--prom", default="",
                    help="also check a Prometheus text exposition file")
    ap.add_argument("--prom-require", action="append", default=[],
                    metavar="NAME", help="family that must appear in --prom")
    args = ap.parse_args()

    with open(args.schema) as f:
        schema = json.load(f)
    with open(args.snapshot) as f:
        snap = json.load(f)

    errors = list(validate(snap, schema))
    errors += list(histogram_semantics(snap))
    if args.check_families:
        declared = load_schema_families(args.schema)
        for kind in ("counters", "gauges", "histograms"):
            for name in snap.get(kind, {}):
                if name not in declared.get(kind, []):
                    errors.append(
                        f"snapshot family {kind}:{name} is not declared in "
                        f"{args.schema} families.{kind} — add it there (the "
                        "metrics-contract lint keeps that list in sync "
                        "with the code)")
    for req in args.require:
        kind, _, name = req.partition(":")
        if name not in snap.get(kind, {}):
            errors.append(f"missing required family {kind}:{name}")
        elif not snap[kind][name].get("series"):
            errors.append(f"required family {kind}:{name} has no series")

    if args.prom:
        with open(args.prom) as f:
            text = f.read()
        families = {line.split()[2] for line in text.splitlines()
                    if line.startswith("# TYPE ")}
        for name in args.prom_require:
            if name not in families:
                errors.append(f"exposition {args.prom}: missing family "
                              f"{name} (have {sorted(families)})")

    if errors:
        for e in errors:
            print(f"[metrics-schema] FAIL {e}", file=sys.stderr)
        sys.exit(1)
    n_fams = sum(len(snap.get(k, {}))
                 for k in ("counters", "gauges", "histograms"))
    print(f"[metrics-schema] OK {args.snapshot}: {n_fams} families, "
          f"{len(args.require)} required present"
          + (f", exposition {args.prom} ok" if args.prom else ""))


if __name__ == "__main__":
    main()
