#!/usr/bin/env python
"""Run the repro.lint static analyzer over the tree.

Usage:
    python scripts/run_lint.py [paths...] [--baseline F] [--update-baseline]
                               [--format=text|json] [--output F]
                               [--rule RULE ...] [--list-rules]

Exit status: 0 when every finding is covered by the baseline (and no
baseline entry is stale), 1 when new findings exist, 2 on usage errors.
``--update-baseline`` rewrites the baseline from the current run and
exits 0 — review the diff; a growing baseline is a code review smell.

The default config scans ``src/repro/**/*.py``; pass explicit paths to
lint a subset (pre-commit style). ``--format=json`` emits the structured
report the CI job uploads as an artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.lint import core as lint  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: config include globs)")
    ap.add_argument("--baseline",
                    default=os.path.join("scripts", "lint_baseline.json"),
                    help="baseline file, repo-relative "
                         "(default: scripts/lint_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run and exit 0")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--output", default=None,
                    help="write the report here instead of stdout")
    ap.add_argument("--rule", action="append", default=None,
                    help="run only these rule ids (repeatable)")
    ap.add_argument("--root", default=_ROOT, help=argparse.SUPPRESS)
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for entry in lint.rule_entries():
            print(f"{entry.rule_id:20s} {entry.severity:8s} "
                  f"[{entry.scope}] {entry.help}")
        return 0

    config = lint.LintConfig()
    findings = lint.run_lint(args.root, config, paths=args.paths or None,
                             rules=args.rule)

    baseline_path = os.path.join(args.root, args.baseline)
    if args.update_baseline:
        lint.save_baseline(baseline_path, findings)
        print(f"baseline updated: {args.baseline} "
              f"({len(findings)} finding(s))")
        return 0

    baseline = {} if args.no_baseline else lint.load_baseline(baseline_path)
    new, old, stale = lint.partition(findings, baseline)
    # a partial run (explicit paths / --rule) legitimately misses baseline
    # entries; only a full default run treats them as stale
    partial = bool(args.paths or args.rule)

    if args.format == "json":
        report = {
            "new": [f.to_dict() for f in new],
            "baselined": [f.to_dict() for f in old],
            "stale_baseline": [] if partial else sorted(stale),
            "counts": {"new": len(new), "baselined": len(old),
                       "stale": 0 if partial else len(stale)},
        }
        text = json.dumps(report, indent=2) + "\n"
    else:
        lines = []
        for f in new:
            lines.append(f.render())
        if old:
            lines.append(f"# {len(old)} baselined finding(s) suppressed "
                         f"(see {args.baseline})")
        if stale and not partial:
            lines.append(f"# {len(stale)} stale baseline entr(ies) — the "
                         "code they matched is gone; rerun with "
                         "--update-baseline to retire them")
        if not new:
            lines.append("lint: clean" + (
                "" if not old else " (modulo baseline)"))
        text = "\n".join(lines) + "\n"

    if args.output:
        out_path = args.output if os.path.isabs(args.output) else \
            os.path.join(args.root, args.output)
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w", encoding="utf-8") as fh:
            fh.write(text)
        print(f"report written to {args.output} "
              f"({len(new)} new, {len(old)} baselined)")
    else:
        sys.stdout.write(text)

    if new:
        return 1
    if stale and not partial:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
