"""E2E serving driver: compress a small LM with AWP INT4 + pack the weights
into int4 QTensors + serve a batch of requests, comparing dense vs packed
dequant-matmul decode (the deployment payoff of the paper's method).

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny_config
from repro.core.compress import CompressionConfig, compress_model
from repro.data import DataConfig, ZipfMarkov, calibration_batches
from repro.kernels import ops
from repro.models import build_model
from repro.quant import QTensor

cfg = get_tiny_config("llama32-1b")
model = build_model(cfg, remat=False)
params = model.init(jax.random.PRNGKey(0))
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=48, global_batch=8)
calib = [{"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
         for t, l in calibration_batches(dc, 2)]

print("AWP INT4-quantizing the model (layer-wise PGD) ...")
cp, reports = compress_model(
    model, params, calib,
    CompressionConfig(method="awp_quant", bits=4, group_size=64))
print(f"  {len(reports)} linears quantized, "
      f"mean recon loss {np.mean([r.loss_after for r in reports]):.4f}")

# pack every block linear into int4 QTensors
packed, dense_bytes, packed_bytes = {}, 0, 0
for i in range(model.num_blocks()):
    for name, path, _ in model.block_linears(i):
        from repro.core.compress import get_linear
        w = get_linear(cp, path, i)
        qt = QTensor.from_dense(jnp.asarray(w), 4, 64)
        packed[(i, name)] = qt
        dense_bytes += w.size * 4
        packed_bytes += qt.nbytes()
print(f"  weight bytes: {dense_bytes/1e6:.1f}MB dense -> "
      f"{packed_bytes/1e6:.1f}MB packed ({dense_bytes/packed_bytes:.1f}x)")

# serve a batch of requests with the compressed model
B, PROMPT, GEN = 8, 32, 16
gen = ZipfMarkov(dc)
prompts, _ = gen.batch(0)
prompts = jnp.asarray(prompts[:, :PROMPT])
cache = model.init_cache(B, PROMPT + GEN, jnp.float32)
prefill = jax.jit(model.prefill)
decode = jax.jit(model.decode_step, donate_argnums=2)

logits, cache = prefill(cp, {"tokens": prompts}, cache)
tok = jnp.argmax(logits[:, -1], -1)[:, None]
t0 = time.time()
outs = [tok]
for _ in range(GEN - 1):
    logits, cache = decode(cp, tok, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    outs.append(tok)
jax.block_until_ready(tok)
dt = time.time() - t0
print(f"  served {B} requests x {GEN} tokens: "
      f"{B * (GEN - 1) / dt:.0f} tok/s decode")

# spot-check: the packed dequant-matmul path agrees with the dense weights
w = np.asarray(cp["blocks"]["mlp"]["wu"][0]).T
qt = QTensor.from_dense(jnp.asarray(w), 4, 64)
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, w.shape[1])), jnp.float32)
y_kernel = ops.dequant_matmul(x, qt.packed, qt.scale, qt.zero, 64)
err = float(jnp.abs(y_kernel - x @ jnp.asarray(w).T).max())
print(f"  packed-kernel vs dense matmul max err: {err:.2e}  "
      f"(int4 path exact up to grid)")
print("done.")
