"""E2E serving driver: compress a small LM with a MIXED-PRECISION policy
(8-bit attention / 4-bit MLP, block 0 left dense), write the packed QTensor
checkpoint, and serve a batch of requests straight from the packed codes —
the deployment payoff of the paper's method.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_packed_checkpoint, save_packed_checkpoint
from repro.configs import get_tiny_config
from repro.core.compress import compress_model
from repro.core.specs import Policy, QuantSpec
from repro.data import DataConfig, ZipfMarkov, calibration_batches
from repro.models import build_model

cfg = get_tiny_config("llama32-1b")
model = build_model(cfg, remat=False)
params = model.init(jax.random.PRNGKey(0))
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=48, global_batch=8)
calib = [{"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
         for t, l in calibration_batches(dc, 2)]

policy = Policy({
    "blocks.0.*": None,                          # most-sensitive block: dense
    "*.attn.*": QuantSpec(bits=8, group_size=64),
    "*.mlp.*": QuantSpec(bits=4, group_size=64),
})
print("AWP-quantizing with mixed-precision policy (8b attn / 4b mlp, "
      "block 0 dense) ...")
cp, report = compress_model(model, params, calib, policy)
print("  " + report.summary().replace("\n", "\n  "))

dense_bytes = sum(int(np.prod(a.result.qtensor.shape)) * 4
                  for a in report.packed_layers().values())
packed_bytes = sum(a.result.qtensor.nbytes()
                   for a in report.packed_layers().values())
print(f"  quantized-layer bytes: {dense_bytes/1e6:.1f}MB dense -> "
      f"{packed_bytes/1e6:.1f}MB packed ({dense_bytes/packed_bytes:.1f}x)")

# write the packed checkpoint, then serve FROM it (no re-quantization)
tmp = tempfile.mkdtemp(prefix="awp_packed_")
path = save_packed_checkpoint(tmp, 0, cp, report)
served_params, qts, _ = load_packed_checkpoint(path, params)
print(f"  packed checkpoint: {path} ({len(qts)} QTensor layers)")

# the packed load reproduces the compressed model bit-for-bit
B, PROMPT, GEN = 8, 32, 16
gen = ZipfMarkov(dc)
prompts, _ = gen.batch(0)
prompts = jnp.asarray(prompts[:, :PROMPT])
cache = model.init_cache(B, PROMPT + GEN, jnp.float32)
prefill = jax.jit(model.prefill)
decode = jax.jit(model.decode_step, donate_argnums=2)

logits_ref, _ = prefill(cp, {"tokens": prompts},
                        model.init_cache(B, PROMPT + GEN, jnp.float32))
logits, cache = prefill(served_params, {"tokens": prompts}, cache)
err = float(jnp.abs(logits - logits_ref).max())
print(f"  packed-checkpoint logits vs dequantized reference: "
      f"max err {err:.2e}")
assert err == 0.0

tok = jnp.argmax(logits[:, -1], -1)[:, None]
t0 = time.time()
outs = [tok]
for _ in range(GEN - 1):
    logits, cache = decode(served_params, tok, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    outs.append(tok)
jax.block_until_ready(tok)
dt = time.time() - t0
print(f"  served {B} requests x {GEN} tokens: "
      f"{B * (GEN - 1) / dt:.0f} tok/s decode")

# spot-check: the fused Pallas kernel path (int4 nibble-packed layers only;
# kernel_matmul falls back to reference dequant for other layouts) agrees
# with the reference dequant-matmul
name, art = next((n, a) for n, a in report.packed_layers().items()
                 if a.result.qtensor.bits == 4)
qt = art.result.qtensor
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, qt.shape[1])),
                jnp.float32)
err = float(jnp.abs(qt.kernel_matmul(x) - qt.matmul(x)).max())
print(f"  fused kernel vs reference dequant-matmul on {name}: "
      f"max err {err:.2e}")
print("done.")
