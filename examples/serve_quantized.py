"""E2E serving driver: compress a small LM with a MIXED-PRECISION policy
(8-bit attention / 4-bit MLP), write the packed QTensor checkpoint, and
serve a batch of requests with the weights STILL PACKED — quantized layers
come back as stacked ``QTensor`` leaves of the param tree and the jitted
forward pass reads their integer codes directly (the deployment payoff of
the paper's method: ~4 bits/weight of HBM traffic instead of 32).

    PYTHONPATH=src python examples/serve_quantized.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_packed_checkpoint, save_packed_checkpoint
from repro.configs import get_tiny_config
from repro.core.compress import compress_model
from repro.core.specs import Policy, QuantSpec
from repro.data import DataConfig, ZipfMarkov, calibration_batches
from repro.launch.serve import qtensor_leaves
from repro.models import build_model
from repro.quant import QTensor

cfg = get_tiny_config("llama32-1b")
model = build_model(cfg, remat=False)
params = model.init(jax.random.PRNGKey(0))
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=48, global_batch=8)
calib = [{"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
         for t, l in calibration_batches(dc, 2)]

policy = Policy({
    "*.attn.*": QuantSpec(bits=8, group_size=64),
    "*.mlp.*": QuantSpec(bits=4, group_size=64),
})
print("AWP-quantizing with mixed-precision policy (8b attn / 4b mlp) ...")
cp, report = compress_model(model, params, calib, policy)
print("  " + report.summary().replace("\n", "\n  "))

dense_bytes = sum(int(np.prod(a.result.qtensor.shape)) * 4
                  for a in report.packed_layers().values())
packed_bytes = sum(a.result.qtensor.nbytes()
                   for a in report.packed_layers().values())
print(f"  quantized-layer bytes: {dense_bytes/1e6:.1f}MB dense -> "
      f"{packed_bytes/1e6:.1f}MB packed ({dense_bytes/packed_bytes:.1f}x)")

# write the packed checkpoint, then serve FROM it: the quantized slots come
# back as stacked QTensor LEAVES (no dense float is materialized for them)
tmp = tempfile.mkdtemp(prefix="awp_packed_")
path = save_packed_checkpoint(tmp, 0, cp, report)
served_params, qts, _ = load_packed_checkpoint(path, params)
n_qleaves = len(qtensor_leaves(served_params))
print(f"  packed checkpoint: {path} ({len(qts)} quantized layers as "
      f"{n_qleaves} stacked QTensor leaves)")
assert isinstance(served_params["blocks"]["attn"]["wq"], QTensor)
assert served_params["blocks"]["attn"]["wq"].bits == 8
assert served_params["blocks"]["mlp"]["wu"].bits == 4

# packed-native serving reproduces the compressed model's logits
B, PROMPT, GEN = 8, 32, 16
gen = ZipfMarkov(dc)
prompts, _ = gen.batch(0)
prompts = jnp.asarray(prompts[:, :PROMPT])
cache = model.init_cache(B, PROMPT + GEN, jnp.float32)
prefill = jax.jit(model.prefill)
decode = jax.jit(model.decode_step, donate_argnums=2)

logits_ref, _ = prefill(cp, {"tokens": prompts},
                        model.init_cache(B, PROMPT + GEN, jnp.float32))
logits, cache = prefill(served_params, {"tokens": prompts}, cache)
err = float(jnp.abs(logits - logits_ref).max())
print(f"  packed-native logits vs dequantized reference: max err {err:.2e}")
assert err < 1e-5

tok = jnp.argmax(logits[:, -1], -1)[:, None]
t0 = time.time()
outs = [tok]
for _ in range(GEN - 1):
    logits, cache = decode(served_params, tok, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    outs.append(tok)
jax.block_until_ready(tok)
dt = time.time() - t0
print(f"  served {B} requests x {GEN} tokens from packed weights: "
      f"{B * (GEN - 1) / dt:.0f} tok/s decode")

# spot-check: the fused Pallas kernel path (nibble-packed int4, col_scale
# handled by pre-scaling x) agrees with the reference dequant-matmul
name, art = next((n, a) for n, a in report.packed_layers().items()
                 if a.result.qtensor.bits == 4)
qt = art.result.qtensor
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, qt.shape[1])),
                jnp.float32)
err = float(jnp.abs(qt.kernel_matmul(x) - qt.matmul(x)).max())
print(f"  fused kernel vs reference dequant-matmul on {name}: "
      f"max err {err:.2e}")
print("done.")
