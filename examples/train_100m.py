"""Train a ~100M-parameter LM for a few hundred steps (e2e training driver).

On this CPU container a full run takes hours; default is a 20-step smoke.
The full reproduction command (a few hundred steps, as the deliverable
describes) is:

    PYTHONPATH=src python examples/train_100m.py --steps 300 --batch 8

On a TPU slice, add sharding via repro.launch.train --mesh single.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data import DataConfig, ZipfMarkov
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.training.train_loop import TrainConfig, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=20)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

# ~100M params: 12L, d=768, llama-style (GPT-2-small-ish footprint)
cfg = ModelConfig(name="repro-100m", family="dense", num_layers=12,
                  d_model=768, num_heads=12, num_kv_heads=12, d_ff=2048,
                  vocab_size=32000, head_dim=64, mlp_act="silu")
print(f"params: {cfg.param_count()/1e6:.1f}M")

model = build_model(cfg, remat=False)
tcfg = TrainConfig(optimizer=OptimizerConfig(lr=3e-4, warmup_steps=20,
                                             total_steps=max(args.steps, 100)))
step_fn, opt_init = make_train_step(model, tcfg)
params = model.init(jax.random.PRNGKey(0))
state = {"params": params, "opt": opt_init(params),
         "step": jnp.zeros((), jnp.int32)}
gen = ZipfMarkov(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            global_batch=args.batch))
jstep = jax.jit(step_fn, donate_argnums=0)
t0 = time.time()
for i in range(args.steps):
    toks, labels = gen.batch(i)
    state, m = jstep(state, {"tokens": jnp.asarray(toks),
                             "labels": jnp.asarray(labels)})
    if i % 5 == 0 or i + 1 == args.steps:
        print(f"step {i}: loss {float(m['loss']):.4f} "
              f"({args.batch*args.seq*(i+1)/(time.time()-t0):.0f} tok/s)")
print("done.")
