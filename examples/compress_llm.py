"""End-to-end compression driver: train a small LM → compress it with AWP
and every baseline → compare perplexities (paper Tables 1-4 pipeline).

    PYTHONPATH=src python examples/compress_llm.py [--steps 200] [--ratio 0.6]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny_config
from repro.core import metrics
from repro.core.compress import compress_model
from repro.core.specs import JointSpec, PruneSpec
from repro.data import DataConfig, ZipfMarkov, calibration_batches
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.training.train_loop import TrainConfig, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--ratio", type=float, default=0.6)
args = ap.parse_args()

cfg = get_tiny_config("llama2-7b")
model = build_model(cfg, remat=False)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=16)
gen = ZipfMarkov(dc)

print(f"training tiny llama2 ({cfg.num_layers}L d={cfg.d_model}) "
      f"for {args.steps} steps ...")
tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=20,
                                             total_steps=args.steps))
step_fn, opt_init = make_train_step(model, tcfg)
params = model.init(jax.random.PRNGKey(0))
state = {"params": params, "opt": opt_init(params),
         "step": jnp.zeros((), jnp.int32)}
jstep = jax.jit(step_fn, donate_argnums=0)
for i in range(args.steps):
    t, l = gen.batch(i)
    state, m = jstep(state, {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)})
    if i % 50 == 0:
        print(f"  step {i}: loss {float(m['loss']):.3f}")
params = state["params"]

calib = [{"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
         for t, l in calibration_batches(dc, 4)]
eval_batches = [gen.batch(7000 + i) for i in range(4)]

def ppl(p):
    def loss_fn(p, t, l):
        _, m = jax.jit(model.loss)(p, {"tokens": t, "labels": l})
        return m["sum_nll"], m["tokens"]
    return metrics.perplexity(loss_fn, p, [
        (jnp.asarray(t), jnp.asarray(l)) for t, l in eval_batches])

print(f"\ndense perplexity: {ppl(params):.3f}")
print(f"pruning to {args.ratio:.0%}:")
for method in ("magnitude", "wanda", "awp_prune"):
    cp, _ = compress_model(model, params, calib,
                           PruneSpec(method=method, ratio=args.ratio))
    print(f"  {method:12s} ppl: {ppl(cp):.3f}")
print("joint prune+INT4:")
for method in ("awq_wanda", "wanda_awq", "awp_joint"):
    cp, _ = compress_model(model, params, calib,
                           JointSpec(method=method, ratio=args.ratio,
                                     bits=4, group_size=64))
    print(f"  {method:12s} ppl: {ppl(cp):.3f}")
