"""Quickstart: AWP on a single layer in ~30 lines (paper Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import awp, calibration as calib
from repro.core.baselines import magnitude, wanda

rng = np.random.default_rng(0)

# a "layer": weight W and calibration activations X with outlier channels
d_in, d_out, n_tokens = 256, 128, 2048
channel_scale = np.where(rng.random(d_in) < 0.1, 8.0, 1.0)
X = rng.normal(size=(n_tokens, d_in)).astype(np.float32) * channel_scale
W = jnp.asarray(rng.normal(size=(d_out, d_in)), jnp.float32)

# C = (1/n) XᵀX — the only statistic AWP needs
stats = calib.update(calib.init(d_in), jnp.asarray(X))
C = calib.covariance(stats)

# prune 70%: keep k = 0.3·d_in per row
k = int(0.3 * d_in)
result = awp.prune(W, C, k)          # η=2/‖C‖_F, Wanda init, ≤200 iters

loss = lambda t: float(awp.activation_loss(W, t, C))
print(f"magnitude  loss: {loss(magnitude.prune_weight(W, k)):.4f}")
print(f"wanda      loss: {loss(wanda.prune_weight(W, C, k)):.4f}")
print(f"AWP        loss: {loss(result.theta):.4f}   "
      f"(iters={int(result.iters)}, ‖∇‖/‖W‖={float(result.grad_norm):.2e})")

# quantize to INT4 instead (10 iters, RTN init) — same Algorithm 1
q = awp.quantize(W, C, bits=4, group_size=128)
print(f"AWP-INT4   loss: {loss(q.theta):.4f}")

# or both at once (§4.3 joint recipe)
j = awp.joint(W, C, k, bits=4, group_size=128)
sparsity = float((np.asarray(j.theta) == 0).mean())
print(f"AWP joint  loss: {loss(j.theta):.4f}   (sparsity={sparsity:.2f}, INT4)")
