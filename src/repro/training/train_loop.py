"""Training loop substrate: train_step factory with microbatched gradient
accumulation, mixed precision, donation, and an explicit-DP (shard_map)
variant with compressed gradient all-reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.optim.optimizers import OptimizerConfig, make_optimizer
from repro.optim import grad_compress
from repro.sharding import ShardingRules, NO_RULES


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    microbatches: int = 1          # gradient-accumulation steps per update
    compute_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32  # grad-accumulation carry dtype
    unroll_accum: bool = False      # python-loop accumulation (cost lowering
                                    # only: exposes per-microbatch collectives
                                    # to HloCostAnalysis; see launch/dryrun.py)


def init_train_state(model, key) -> Dict[str, Any]:
    params = model.init(key)
    opt_init, _ = make_optimizer(TrainConfig().optimizer)
    return {"params": params, "opt": opt_init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(model, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    With microbatches > 1 the batch's leading dim is split and gradients are
    accumulated in a lax.scan (each microbatch is rematerialized in the
    backward pass — memory = one microbatch's activations)."""
    opt_init, opt_update = make_optimizer(tcfg.optimizer)

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, grads, metrics

    def accumulated(params, batch):
        n = tcfg.microbatches
        def split(x):
            # (B, ...) -> (n_micro, B/n, ...) with the *second* axis carrying
            # the data-parallel sharding: element (i, j) = batch[j*n + i], so
            # each microbatch spans all DP shards (j maps to devices).
            b = x.shape[0]
            return x.reshape(b // n, n, *x.shape[1:]).swapaxes(0, 1)
        mbs = jax.tree.map(split, batch)
        # scan-based accumulation: the carry is double-buffered by XLA, so
        # the accumulator dtype is configurable — f32 by default, bf16 on
        # the 100B+ dry-run configs where 2×params-f32 of temp won't fit
        # (tradeoff note in EXPERIMENTS.md §Dry-run).
        acc_t = tcfg.accum_dtype
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_t), params)

        def body(carry, mb):
            loss_acc, gacc = carry
            loss, grads, _ = single(params, mb)
            gacc = jax.tree.map(
                lambda a, g: (a.astype(jnp.float32)
                              + g.astype(jnp.float32) / n).astype(acc_t),
                gacc, grads)
            return (loss_acc + loss / n, gacc), None

        if tcfg.unroll_accum:
            carry = (jnp.float32(0.0), zero)
            for i in range(n):
                carry, _ = body(carry, jax.tree.map(lambda x: x[i], mbs))
            loss, grads = carry
            return loss, grads, {}
        (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero), mbs)
        return loss, grads, {}

    def train_step(state, batch):
        if tcfg.microbatches > 1:
            loss, grads, _ = accumulated(state["params"], batch)
        else:
            loss, grads, _ = single(state["params"], batch)
        params, opt, om = opt_update(grads, state["opt"], state["params"])
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return train_step, opt_init


def make_train_step_ddp(model, tcfg: TrainConfig, rules: ShardingRules, *,
                        compress: Optional[str] = None, topk_frac: float = 0.01):
    """Explicit data-parallel train step under shard_map: params replicated
    across the DP axes, per-device gradients synced with a compressed
    all-reduce (compress = None | 'int8' | 'topk_ef').

    This variant exposes the gradient-sync collective so volume-reduction
    tricks are real (they appear in the lowered HLO and in the §Roofline
    collective term). It composes with the pjit TP sharding of everything
    else only at small TP degree; the flagship production path remains the
    pjit step — this is the distributed-optimization testbed.
    """
    assert rules.mesh is not None
    dp_axes = rules.batch_axes
    opt_init, opt_update = make_optimizer(tcfg.optimizer)
    from jax.sharding import PartitionSpec as P

    def local_grads(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch)[0])(params)
        return loss, grads

    def step_fn(state, batch):
        def shard_body(params, opt, step, local_batch):
            loss, grads = local_grads(params, local_batch)
            ax = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            if compress == "int8":
                grads = jax.tree.map(
                    lambda g: grad_compress.int8_psum(g, ax), grads)
            else:
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g, ax), grads)
            loss = jax.lax.pmean(loss, ax)
            params2, opt2, om = opt_update(grads, opt, params)
            return params2, opt2, step + 1, loss, om["grad_norm"]

        batch_spec = jax.tree.map(lambda _: P(dp_axes), batch)
        rep = jax.tree.map(lambda _: P(), state["params"])
        opt_spec = jax.tree.map(lambda _: P(), state["opt"])
        params2, opt2, step2, loss, gn = compat.shard_map(
            shard_body, mesh=rules.mesh,
            in_specs=(rep, opt_spec, P(), batch_spec),
            out_specs=(rep, opt_spec, P(), P(), P()),
            check_vma=False,
        )(state["params"], state["opt"], state["step"], batch)
        return ({"params": params2, "opt": opt2, "step": step2},
                {"loss": loss, "grad_norm": gn})

    return step_fn, opt_init


__all__ = ["TrainConfig", "make_train_step", "make_train_step_ddp",
           "init_train_state"]
