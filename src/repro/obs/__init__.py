"""repro.obs — process-local telemetry: metrics, traces, profiling hooks.

Pure host-side Python (no jax imports at module load); safe to import from
the scheduler, benches, and scripts alike.
"""

from repro.obs.metrics import (
    ITER_BUCKETS,
    LATENCY_BUCKETS,
    RESIDUAL_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    RingBuffer,
    default_registry,
    hist_quantile,
    parse_exposition,
    snapshot_series,
)
from repro.obs.profiling import StepTraceWindow, trace_window
from repro.obs.trace import TERMINAL_STATUSES, Span, Trace, TraceError

__all__ = [
    "ITER_BUCKETS",
    "LATENCY_BUCKETS",
    "RESIDUAL_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "RingBuffer",
    "default_registry",
    "hist_quantile",
    "parse_exposition",
    "snapshot_series",
    "StepTraceWindow",
    "trace_window",
    "TERMINAL_STATUSES",
    "Span",
    "Trace",
    "TraceError",
]
