"""Request-lifecycle traces: ordered event stamps -> derived spans.

A ``Trace`` is a list of ``(event, t)`` stamps recorded at existing engine
step boundaries via ``Engine._now`` (so ``FaultPlan``'s virtual clock makes
them deterministic).  No per-decode-tick events are recorded — the decode
phase is a single derived span — so tracing adds zero device syncs and O(1)
host work per request per lifecycle transition.

Event vocabulary::

    submit        request accepted into the engine (queue or direct admit)
    admitted      first dispatched as part of a prefill/chunk program
    first_token   first generated token observed on host
    preempt       evicted mid-decode (paged engine under page pressure)
    resume        re-admitted after a preemption
    end:<status>  terminal; <status> is the RequestStatus string

Derived spans (``Trace.spans()``): ``queued`` (submit -> admitted or end),
``prefill`` (admitted -> first_token), ``decode`` (first_token -> end) and one
``preempted`` span per preempt -> resume (or end) pair.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

__all__ = ["Span", "Trace", "TraceError", "TERMINAL_STATUSES"]

# Matches repro.serving.scheduler.RequestStatus values; kept as literals so
# obs stays import-free of the serving package.
TERMINAL_STATUSES = ("ok", "length", "eos", "cancelled", "deadline", "rejected", "error")


class TraceError(AssertionError):
    """Raised by Trace.validate() when lifecycle invariants are violated."""


@dataclass(frozen=True)
class Span:
    name: str
    start: float
    end: float

    @property
    def duration(self) -> float:
        return self.end - self.start


class Trace:
    __slots__ = ("rid", "events")

    def __init__(self, rid: int, t_submit: float):
        self.rid = rid
        self.events: List[Tuple[str, float]] = [("submit", float(t_submit))]

    def stamp(self, event: str, t: float) -> None:
        self.events.append((event, float(t)))

    def finish(self, status: str, t: float) -> None:
        self.stamp(f"end:{status}", t)

    # -- derived views ------------------------------------------------------

    @property
    def status(self) -> Optional[str]:
        name, _ = self.events[-1]
        return name[4:] if name.startswith("end:") else None

    @property
    def done(self) -> bool:
        return self.status is not None

    def first(self, event: str) -> Optional[float]:
        for name, t in self.events:
            if name == event:
                return t
        return None

    def spans(self) -> List[Span]:
        t_submit = self.events[0][1]
        t_admit = self.first("admitted")
        t_first = self.first("first_token")
        t_end = self.events[-1][1] if self.done else None

        spans: List[Span] = []
        queued_end = t_admit if t_admit is not None else t_end
        if queued_end is not None:
            spans.append(Span("queued", t_submit, queued_end))
        if t_admit is not None and t_first is not None:
            spans.append(Span("prefill", t_admit, t_first))
        if t_first is not None and t_end is not None:
            spans.append(Span("decode", t_first, t_end))

        open_preempt: Optional[float] = None
        for name, t in self.events:
            if name == "preempt":
                open_preempt = t
            elif name == "resume" and open_preempt is not None:
                spans.append(Span("preempted", open_preempt, t))
                open_preempt = None
        if open_preempt is not None and t_end is not None:
            spans.append(Span("preempted", open_preempt, t_end))

        spans.sort(key=lambda s: (s.start, s.end))
        return spans

    def validate(self) -> bool:
        """Check lifecycle invariants; raises TraceError on violation."""
        ev = self.events
        if not ev or ev[0][0] != "submit":
            raise TraceError(f"rid {self.rid}: trace must start with submit: {ev[:1]}")
        times = [t for _, t in ev]
        if any(b < a for a, b in zip(times, times[1:])):
            raise TraceError(f"rid {self.rid}: timestamps not monotone: {ev}")
        terminals = [i for i, (n, _) in enumerate(ev) if n.startswith("end:")]
        if len(terminals) != 1 or terminals[0] != len(ev) - 1:
            raise TraceError(f"rid {self.rid}: exactly one terminal event, last: {ev}")
        status = self.status
        if status not in TERMINAL_STATUSES:
            raise TraceError(f"rid {self.rid}: unknown terminal status {status!r}")

        names = [n for n, _ in ev]
        if names.count("submit") != 1:
            raise TraceError(f"rid {self.rid}: duplicate submit: {ev}")
        if names.count("admitted") > 1:
            raise TraceError(f"rid {self.rid}: duplicate admitted: {ev}")
        if names.count("first_token") > 1:
            raise TraceError(f"rid {self.rid}: duplicate first_token: {ev}")

        admitted_at = names.index("admitted") if "admitted" in names else None
        first_at = names.index("first_token") if "first_token" in names else None
        if first_at is not None and (admitted_at is None or admitted_at > first_at):
            raise TraceError(f"rid {self.rid}: first_token before admitted: {ev}")

        depth = 0
        for n in names:
            if n == "preempt":
                if admitted_at is None:
                    raise TraceError(f"rid {self.rid}: preempt before admitted: {ev}")
                depth += 1
                if depth > 1:
                    raise TraceError(f"rid {self.rid}: nested preempt: {ev}")
            elif n == "resume":
                depth -= 1
                if depth < 0:
                    raise TraceError(f"rid {self.rid}: resume without preempt: {ev}")

        if status in ("ok", "length", "eos") and first_at is None:
            raise TraceError(f"rid {self.rid}: {status} without first_token: {ev}")
        if status == "rejected" and admitted_at is not None:
            raise TraceError(f"rid {self.rid}: rejected after admission: {ev}")
        return True

    def asdict(self) -> dict:
        return {"rid": self.rid, "events": [[n, t] for n, t in self.events]}

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        body = ", ".join(f"{n}@{t:.6g}" for n, t in self.events)
        return f"Trace(rid={self.rid}, [{body}])"
