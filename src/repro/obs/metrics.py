"""Process-local metrics registry: counters, gauges, histograms.

Design notes
------------
* Pure host-side Python; nothing here touches jax or the device.  Recording a
  sample is a dict lookup plus a float add, so the engine can stamp metrics
  inside its step loop without perturbing dispatch behaviour.
* A *family* (``Counter``/``Gauge``/``Histogram``) owns a set of label names;
  ``family.labels(k=v, ...)`` returns a bound *child* that does the actual
  counting.  Children are cached, so hot paths bind once and hold the child.
* ``MetricsRegistry(enabled=False)`` freezes observation-grade collection
  (histogram observations become no-ops; the engine also skips building
  request traces).  Counters and gauges always count because engine
  bookkeeping (``queue_stats``/``page_stats``/dispatch counters) is a thin
  view over them.
* Export: ``snapshot()`` (plain dict, JSON-serialisable), ``to_prometheus()``
  (text exposition), ``append_jsonl(path)`` (one snapshot per line).
"""

from __future__ import annotations

import json
import math
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "LATENCY_BUCKETS",
    "ITER_BUCKETS",
    "RESIDUAL_BUCKETS",
    "RingBuffer",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "parse_exposition",
    "snapshot_series",
    "hist_quantile",
]

# Fixed log-spaced latency buckets: 3 per decade from 100 us to ~4600 s.
# Shared by every *_seconds histogram so exposition stays mergeable.
LATENCY_BUCKETS: Tuple[float, ...] = tuple(
    round(10.0 ** (i / 3.0 - 4.0), 10) for i in range(22)
)

# Power-of-two buckets for iteration counts (PGD steps per layer).
ITER_BUCKETS: Tuple[float, ...] = tuple(float(2 ** i) for i in range(11))  # 1..1024

# Log-spaced buckets for reconstruction residuals (relative Frobenius loss).
RESIDUAL_BUCKETS: Tuple[float, ...] = tuple(
    round(10.0 ** (i / 2.0 - 6.0), 12) for i in range(17)
)  # 1e-6 .. 1e2


class RingBuffer:
    """Fixed-capacity ring keeping the most recent samples.

    Unlike the old list-with-cap it never silently stops recording: once full
    the oldest sample is overwritten and ``dropped`` is incremented, so
    consumers can tell a truncated trace from a complete one.
    """

    __slots__ = ("capacity", "_buf", "_start", "_len", "dropped")

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"RingBuffer capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._buf: List[float] = [0.0] * self.capacity
        self._start = 0
        self._len = 0
        self.dropped = 0

    def append(self, value: float) -> None:
        if self._len < self.capacity:
            self._buf[(self._start + self._len) % self.capacity] = value
            self._len += 1
        else:
            self._buf[self._start] = value
            self._start = (self._start + 1) % self.capacity
            self.dropped += 1

    def values(self) -> List[float]:
        return [self._buf[(self._start + i) % self.capacity] for i in range(self._len)]

    def clear(self) -> None:
        self._start = 0
        self._len = 0
        self.dropped = 0

    def __len__(self) -> int:
        return self._len


def _label_key(labels: Mapping[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _CounterChild:
    __slots__ = ("labels", "_value")

    def __init__(self, labels: Dict[str, str]):
        self.labels = labels
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        self._value = 0.0


class _GaugeChild:
    """Gauge child with running peak/sum/samples and an optional ring trace."""

    __slots__ = ("labels", "_value", "peak", "sum", "samples", "ring")

    def __init__(self, labels: Dict[str, str], trace_capacity: int = 0):
        self.labels = labels
        self._value = 0.0
        self.peak = 0.0
        self.sum = 0.0
        self.samples = 0
        self.ring: Optional[RingBuffer] = (
            RingBuffer(trace_capacity) if trace_capacity > 0 else None
        )

    def set(self, value: float) -> None:
        self._value = value
        if value > self.peak:
            self.peak = value
        self.sum += value
        self.samples += 1
        if self.ring is not None:
            self.ring.append(value)

    def set_value(self, value: float) -> None:
        """Refresh the instantaneous value without recording a sample
        (keeps snapshot-time refreshes out of the per-step mean/trace)."""
        self._value = value
        if value > self.peak:
            self.peak = value

    @property
    def value(self) -> float:
        return self._value

    @property
    def mean(self) -> float:
        return self.sum / self.samples if self.samples else 0.0

    def trace_values(self) -> List[float]:
        return self.ring.values() if self.ring is not None else []

    @property
    def trace_dropped(self) -> int:
        return self.ring.dropped if self.ring is not None else 0

    def reset(self) -> None:
        self._value = 0.0
        self.peak = 0.0
        self.sum = 0.0
        self.samples = 0
        if self.ring is not None:
            self.ring.clear()


class _HistogramChild:
    __slots__ = ("labels", "bounds", "counts", "sum", "count", "min", "max", "_registry")

    def __init__(self, labels: Dict[str, str], bounds: Tuple[float, ...], registry):
        self.labels = labels
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # last slot is the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf
        self._registry = registry

    def observe(self, value: float) -> None:
        if self._registry is not None and not self._registry.enabled:
            return
        # First bucket whose upper bound is >= value (le semantics).
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if value <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.sum += value
        self.count += 1
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile by linear interpolation within buckets."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                if i >= len(self.bounds):  # overflow bucket: best guess is max
                    return self.max
                hi = self.bounds[i]
                lo = self.bounds[i - 1] if i > 0 else min(self.min, hi)
                frac = (target - cum) / c
                est = lo + (hi - lo) * frac
                return min(max(est, self.min), self.max)
            cum += c
        return self.max

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf


class _Family:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...], unit: str):
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self.unit = unit
        self._children: Dict[Tuple[Tuple[str, str], ...], object] = {}

    def _make_child(self, labels: Dict[str, str]):
        raise NotImplementedError

    def labels(self, **labels: str):
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {sorted(self.labelnames)}, "
                f"got {sorted(labels)}"
            )
        labels = {k: str(v) for k, v in labels.items()}
        key = _label_key(labels)
        child = self._children.get(key)
        if child is None:
            child = self._make_child(labels)
            self._children[key] = child
        return child

    def children(self) -> Iterable[object]:
        return self._children.values()

    def reset(self) -> None:
        for child in self._children.values():
            child.reset()


class Counter(_Family):
    kind = "counter"

    def _make_child(self, labels: Dict[str, str]) -> _CounterChild:
        return _CounterChild(labels)

    def inc(self, amount: float = 1.0) -> None:
        self.labels().inc(amount)  # type: ignore[call-arg]

    @property
    def value(self) -> float:
        return sum(c.value for c in self._children.values())


class Gauge(_Family):
    kind = "gauge"

    def __init__(self, name, help, labelnames, unit, trace_capacity: int = 0):
        super().__init__(name, help, labelnames, unit)
        self.trace_capacity = trace_capacity

    def _make_child(self, labels: Dict[str, str]) -> _GaugeChild:
        return _GaugeChild(labels, self.trace_capacity)

    def set(self, value: float) -> None:
        self.labels().set(value)  # type: ignore[call-arg]


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name, help, labelnames, unit, buckets: Sequence[float], registry):
        super().__init__(name, help, labelnames, unit)
        bounds = tuple(float(b) for b in buckets)
        if list(bounds) != sorted(set(bounds)):
            raise ValueError(f"{name}: histogram buckets must be strictly increasing")
        self.bounds = bounds
        self._registry = registry

    def _make_child(self, labels: Dict[str, str]) -> _HistogramChild:
        return _HistogramChild(labels, self.bounds, self._registry)

    def observe(self, value: float) -> None:
        self.labels().observe(value)  # type: ignore[call-arg]


class MetricsRegistry:
    """Container of metric families; the unit of snapshot/exposition.

    ``enabled=False`` disables histogram observations (and is the flag the
    engine consults before building request traces); counters and gauges keep
    counting so engine bookkeeping views stay correct either way.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()

    # -- family constructors (get-or-create; definitions must agree) --------

    def _get_or_create(self, name: str, kind: str, make):
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind}, not {kind}"
                    )
                return fam
            fam = make()
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", labelnames: Sequence[str] = (),
                unit: str = "") -> Counter:
        return self._get_or_create(
            name, "counter", lambda: Counter(name, help, tuple(labelnames), unit)
        )

    def gauge(self, name: str, help: str = "", labelnames: Sequence[str] = (),
              unit: str = "", trace_capacity: int = 0) -> Gauge:
        return self._get_or_create(
            name, "gauge",
            lambda: Gauge(name, help, tuple(labelnames), unit, trace_capacity),
        )

    def histogram(self, name: str, help: str = "", labelnames: Sequence[str] = (),
                  unit: str = "", buckets: Sequence[float] = LATENCY_BUCKETS,
                  ) -> Histogram:
        return self._get_or_create(
            name, "histogram",
            lambda: Histogram(name, help, tuple(labelnames), unit, buckets, self),
        )

    def get(self, name: str) -> Optional[_Family]:
        return self._families.get(name)

    def families(self) -> List[_Family]:
        return list(self._families.values())

    def reset(self) -> None:
        for fam in self._families.values():
            fam.reset()

    # -- export -------------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-dict snapshot, JSON-serialisable, schema-checked in CI."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for fam in self._families.values():
            if fam.kind == "counter":
                out["counters"][fam.name] = {
                    "help": fam.help,
                    "unit": fam.unit,
                    "series": [
                        {"labels": dict(c.labels), "value": c.value}
                        for c in fam.children()
                    ],
                }
            elif fam.kind == "gauge":
                series = []
                for c in fam.children():
                    entry = {
                        "labels": dict(c.labels),
                        "value": c.value,
                        "peak": c.peak,
                        "mean": c.mean,
                        "samples": c.samples,
                    }
                    if c.ring is not None:
                        entry["trace"] = c.trace_values()
                        entry["dropped"] = c.trace_dropped
                    series.append(entry)
                out["gauges"][fam.name] = {
                    "help": fam.help, "unit": fam.unit, "series": series,
                }
            else:  # histogram
                out["histograms"][fam.name] = {
                    "help": fam.help,
                    "unit": fam.unit,
                    "series": [
                        {
                            "labels": dict(c.labels),
                            "le": list(c.bounds),
                            "counts": list(c.counts),
                            "sum": c.sum,
                            "count": c.count,
                            "min": c.min if c.count else 0.0,
                            "max": c.max if c.count else 0.0,
                        }
                        for c in fam.children()
                    ],
                }
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4 subset)."""
        lines: List[str] = []
        for fam in self._families.values():
            lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for c in fam.children():
                base = _fmt_labels(c.labels)
                if fam.kind in ("counter", "gauge"):
                    lines.append(f"{fam.name}{base} {_fmt_value(c.value)}")
                else:
                    cum = 0
                    for bound, n in zip(c.bounds, c.counts):
                        cum += n
                        le = _fmt_labels(dict(c.labels, le=_fmt_value(bound)))
                        lines.append(f"{fam.name}_bucket{le} {cum}")
                    cum += c.counts[-1]
                    le = _fmt_labels(dict(c.labels, le="+Inf"))
                    lines.append(f"{fam.name}_bucket{le} {cum}")
                    lines.append(f"{fam.name}_sum{base} {_fmt_value(c.sum)}")
                    lines.append(f"{fam.name}_count{base} {c.count}")
        return "\n".join(lines) + "\n"

    def append_jsonl(self, path: str, extra: Optional[dict] = None) -> None:
        """Append one snapshot as a single JSON line."""
        record = dict(extra or {})
        record["snapshot"] = self.snapshot()
        with open(path, "a") as f:
            f.write(json.dumps(record) + "\n")

    def dump_json(self, path: str, meta: Optional[dict] = None) -> dict:
        """Write the snapshot (plus optional ``meta`` key) as pretty JSON."""
        snap = self.snapshot()
        if meta:
            snap["meta"] = meta
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
            f.write("\n")
        return snap


_DEFAULT: Optional[MetricsRegistry] = None


def default_registry() -> MetricsRegistry:
    """Process-global registry, used by compression when none is passed."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _fmt_labels(labels: Mapping[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{_escape(str(v))}"' for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def parse_exposition(text: str) -> Dict[str, Dict[Tuple[Tuple[str, str], ...], float]]:
    """Parse the text exposition back into {metric: {label-key: value}}.

    Used by the round-trip tests; handles the subset ``to_prometheus`` emits
    (histograms appear under their ``_bucket``/``_sum``/``_count`` names).
    """
    out: Dict[str, Dict[Tuple[Tuple[str, str], ...], float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name_part, _, value_part = line.rpartition(" ")
        labels: Dict[str, str] = {}
        if "{" in name_part:
            name, _, rest = name_part.partition("{")
            body = rest.rsplit("}", 1)[0]
            for item in _split_labels(body):
                k, _, v = item.partition("=")
                labels[k] = _unescape(v.strip('"'))
        else:
            name = name_part
        value = math.inf if value_part == "+Inf" else float(value_part)
        out.setdefault(name, {})[_label_key(labels)] = value
    return out


def _split_labels(body: str) -> List[str]:
    items, depth, cur = [], False, []
    for ch in body:
        if ch == '"':
            depth = not depth
            cur.append(ch)
        elif ch == "," and not depth:
            items.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        items.append("".join(cur))
    return items


def _unescape(v: str) -> str:
    return v.replace("\\n", "\n").replace('\\"', '"').replace("\\\\", "\\")


def snapshot_series(snap: dict, kind: str, name: str,
                    labels: Optional[Mapping[str, str]] = None) -> Optional[dict]:
    """Find one series entry in a snapshot by family name + label subset."""
    fam = snap.get(kind, {}).get(name)
    if fam is None:
        return None
    matches = [
        s for s in fam["series"]
        if labels is None or all(s["labels"].get(k) == str(v) for k, v in labels.items())
    ]
    if not matches:
        return None
    if len(matches) > 1:
        raise ValueError(f"{name}: {len(matches)} series match labels {labels}")
    return matches[0]


def hist_quantile(entry: Mapping, q: float) -> float:
    """Quantile estimate from a snapshot histogram series entry."""
    count = entry["count"]
    if count == 0:
        return 0.0
    bounds, counts = entry["le"], entry["counts"]
    target = q * count
    cum = 0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if cum + c >= target:
            if i >= len(bounds):
                return entry["max"]
            hi = bounds[i]
            lo = bounds[i - 1] if i > 0 else min(entry["min"], hi)
            est = lo + (hi - lo) * (target - cum) / c
            return min(max(est, entry["min"]), entry["max"])
        cum += c
    return entry["max"]
