"""Opt-in jax.profiler trace windows.

Two shapes:

* ``trace_window(log_dir)`` — context manager around one block of work
  (used by ``compress_model(profile_block=...)``).
* ``StepTraceWindow(log_dir, steps)`` — start/step/stop object for wrapping
  the first N engine steps (used by ``serve.py --profile-steps``); its
  ``on_step`` method plugs into ``Engine.run(step_hook=...)``.

Both degrade to no-ops when the directory is empty or the profiler is
unavailable, so telemetry never takes the serving path down.
"""

from __future__ import annotations

import contextlib
import sys

__all__ = ["trace_window", "StepTraceWindow"]


def _start(log_dir: str) -> bool:
    try:
        import jax

        jax.profiler.start_trace(log_dir)
        return True
    except Exception as e:  # pragma: no cover - environment dependent
        print(f"[obs] profiler start failed ({e!r}); continuing unprofiled",
              file=sys.stderr)
        return False


def _stop() -> None:
    try:
        import jax

        jax.profiler.stop_trace()
    except Exception as e:  # pragma: no cover - environment dependent
        print(f"[obs] profiler stop failed ({e!r})", file=sys.stderr)


@contextlib.contextmanager
def trace_window(log_dir: str):
    """Profile the enclosed block into ``log_dir``; no-op if dir is empty."""
    if not log_dir:
        yield False
        return
    started = _start(log_dir)
    try:
        yield started
    finally:
        if started:
            _stop()


class StepTraceWindow:
    """Profile the first ``steps`` engine steps after ``start()``."""

    def __init__(self, log_dir: str, steps: int):
        self.log_dir = log_dir
        self.steps = steps
        self._remaining = 0
        self._active = False

    @property
    def enabled(self) -> bool:
        return bool(self.log_dir) and self.steps > 0

    def start(self) -> None:
        if not self.enabled or self._active:
            return
        self._active = _start(self.log_dir)
        self._remaining = self.steps

    def on_step(self, engine=None) -> None:
        if not self._active:
            return
        self._remaining -= 1
        if self._remaining <= 0:
            self.stop()

    def stop(self) -> None:
        if self._active:
            _stop()
            self._active = False
