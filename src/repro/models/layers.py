"""Shared model building blocks, pure JAX.

Conventions:
- Linear weights are stored ``(d_in, d_out)`` (activation @ weight). The
  compression library uses the paper orientation ``(d_out, d_in)``; the
  driver transposes at the boundary.
- ``capture`` dicts collect pre-matmul activations for calibration; they are
  only populated on the (non-scanned) per-block capture path.
- All blocks take a ShardingRules (``rules``) and place logical-axis
  constraints; with rules=NO_RULES they are no-ops.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant import QTensor
from repro.serving.kv_cache import (QuantizedKV, fused_decode_attn,
                                    kv_dequantize, kv_update, kv_quantize,
                                    paged_view)
from repro.sharding import ShardingRules, NO_RULES, hint


# ---------------------------------------------------------------------------
# linear dispatch: dense array or packed QTensor, one entry point
# ---------------------------------------------------------------------------

def linear_apply(w, x: jax.Array) -> jax.Array:
    """y = x @ w — the single dispatch every model linear routes through.

    ``w`` is either a dense ``(d_in, d_out)`` array (stored orientation) or
    a packed :class:`~repro.quant.QTensor` in paper orientation
    ``(d_out, d_in)``, whose matmul contracts against dequantized rows —
    the same product, read at ~4 bits/weight. QTensor execution follows
    ``repro.quant.matmul_impl``: fused Pallas dequant-matmul on TPU,
    reference dequant elsewhere, ``"kernel"`` (interpret mode) for tests.
    """
    if isinstance(w, QTensor):
        lead = x.shape[:-1]
        y = w.matmul_dispatch(x.reshape(-1, x.shape[-1]))
        return y.reshape(*lead, y.shape[-1]).astype(x.dtype)
    return x @ w


def expert_apply(w, x: jax.Array, *, per_expert: bool = False) -> jax.Array:
    """Batched per-expert linear: x (T, d) → (T, E, f), or per-expert
    inputs x (E, T, d) → (E, T, f) with ``per_expert=True``.

    ``w`` is a stacked dense ``(E, d, f)`` expert weight or a QTensor leaf
    whose children carry a leading expert dim (aux shape stays the
    per-expert ``(f, d)``); the QTensor path vmaps the dequant-matmul over
    the expert axis — experts stay packed in HBM on the decode path. This
    is the single per-expert dispatch site (the MoE down-proj feeds its
    per-expert activations through ``per_expert=True``)."""
    if isinstance(w, QTensor):
        y = jax.vmap(lambda qt, xe: qt.matmul_dispatch(xe),
                     in_axes=(0, 0 if per_expert else None))(w, x)
        return (y if per_expert else y.transpose(1, 0, 2)).astype(x.dtype)
    if per_expert:
        return jnp.einsum("etd,edf->etf", x, w)
    return jnp.einsum("td,edf->tef", x, w)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope / activations
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, D) with even D; positions: (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mlp_act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":                      # nemotron squared-ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# chunked causal flash attention (online softmax; never materializes S×S)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_offset=0,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    p_dtype=jnp.float32, kv_pages=None) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, Hk, D) with H % Hk == 0.

    Double-chunked online-softmax attention in pure JAX: an outer scan over
    query chunks and an inner scan over KV chunks carrying (m, l, acc). Peak
    memory is O(q_chunk × kv_chunk) per head — required for the 32k-prefill
    and 4k-train shapes at production width (DESIGN.md §4).
    ``q_offset`` is the absolute position of q[0] (decode/prefill continua).

    ``kv_pages=(block_table, page_size)`` switches K/V to the paged layout:
    k, v are per-layer page POOLS — (P, page, Hk, D) dense or a
    :class:`~repro.serving.kv_cache.QuantizedKV` with those leading dims —
    and ``block_table`` (B, n_pages) int32 maps each row's kv positions to
    physical pages. Each inner step gathers only its own kv_chunk worth of
    pages in-tile (quantized pools dequantize the gathered tile), so the
    contiguous (B, Skv) view is never materialized. Sentinel table entries
    (== P) clip to the last physical page; their garbage is strictly beyond
    every live query's causal mask, so outputs are bit-identical to the
    contiguous path over the same written tokens.
    """
    b, sq, h, d = q.shape
    if kv_pages is not None:
        table, page_size = kv_pages
        store = k.codes if isinstance(k, QuantizedKV) else k
        hk = store.shape[-2]
        skv = table.shape[1] * page_size
    else:
        _, skv, hk, _ = k.shape
    assert h % hk == 0
    g = h // hk
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # pad seq dims to multiples of the chunk
    def pad_to(x, mult, axis):
        n = x.shape[axis]
        pad = (-n) % mult
        if pad == 0:
            return x, n
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths), n
    q, sq0 = pad_to(q, q_chunk, 1)
    scale = 1.0 / math.sqrt(d)

    if kv_pages is not None:
        # page-aligned kv chunks: gather pages_per_chunk pages per step
        ppc = max(kv_chunk // page_size, 1)
        kv_chunk = ppc * page_size
        npg = table.shape[1]
        npg_p = -(-npg // ppc) * ppc
        if npg_p != npg:                     # sentinel-pad the table itself
            table = jnp.pad(table, ((0, 0), (0, npg_p - npg)),
                            constant_values=store.shape[0])
        skv0, skv_p = skv, npg_p * page_size

        def fetch(ki):
            pages = jax.lax.dynamic_slice(table, (0, ki * ppc), (b, ppc))
            def grab(pool):
                gt = pool[pages]             # (B, ppc, page, ...)
                return gt.reshape(b, kv_chunk, *pool.shape[2:])
            if isinstance(k, QuantizedKV):
                return (kv_dequantize(QuantizedKV(
                            grab(k.codes), grab(k.scale), grab(k.zero),
                            k.group_size), q.dtype),
                        kv_dequantize(QuantizedKV(
                            grab(v.codes), grab(v.scale), grab(v.zero),
                            v.group_size), q.dtype))
            return grab(k), grab(v)
    else:
        k, skv0 = pad_to(k, kv_chunk, 1)
        v, _ = pad_to(v, kv_chunk, 1)
        skv_p = k.shape[1]
        kg = k.reshape(b, skv_p // kv_chunk, kv_chunk, hk, d)
        vg = v.reshape(b, skv_p // kv_chunk, kv_chunk, hk, d)

        def fetch(ki):
            return kg[:, ki], vg[:, ki]

    sq_p = q.shape[1]
    nq, nk = sq_p // q_chunk, skv_p // kv_chunk
    qg = q.reshape(b, nq, q_chunk, h, d)

    q_pos = (jnp.arange(sq_p) + q_offset).reshape(nq, q_chunk)
    k_pos = jnp.arange(skv_p).reshape(nk, kv_chunk)
    kv_valid = (jnp.arange(skv_p) < skv0).reshape(nk, kv_chunk)

    def q_step(_, qi):
        qc = qg[:, qi]                              # (B, qc, H, D)
        qpos = q_pos[qi]

        @jax.checkpoint   # recompute P in backward: true flash-attention
        def kv_step(carry, ki):                     # memory (no saved scores)
            m, l, acc = carry
            kc, vc = fetch(ki)                      # (B, kc, Hk, D)
            s = _scores(qc, kc, g) * scale          # (B, H, qc, kc)
            mask = kv_valid[ki][None, None, None, :]
            if causal:
                mask = mask & (k_pos[ki][None, None, None, :] <=
                               qpos[None, None, :, None])
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard rows with no valid keys yet
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask, p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            pv = _pv(p.astype(p_dtype), vc, g)      # (B, H, qc, D)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 2, 1, 3)      # (B, qc, H, D)

    _, chunks = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = chunks.transpose(1, 0, 2, 3, 4).reshape(b, sq_p, h, d)
    return out[:, :sq0].astype(q.dtype)


def _scores(qc: jax.Array, kc: jax.Array, g: int) -> jax.Array:
    """(B,qc,H,D) x (B,kc,Hk,D) -> (B,H,qc,kc) with GQA group expansion."""
    b, qn, h, d = qc.shape
    kn, hk = kc.shape[1], kc.shape[2]
    qh = qc.reshape(b, qn, hk, g, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bnkd->bkgqn", qh, kc.astype(jnp.float32))
    return s.reshape(b, h, qn, kn)


def _pv(p: jax.Array, vc: jax.Array, g: int) -> jax.Array:
    """(B,H,qc,kc) x (B,kc,Hk,D) -> (B,H,qc,D), f32 accumulation.

    p may be bf16 (the TPU-flash convention: f32 softmax statistics, bf16
    probabilities into the MXU) — §Perf 'bf16 P·V' iteration."""
    b, h, qn, kn = p.shape
    hk = h // g
    pg = p.reshape(b, hk, g, qn, kn)
    out = jnp.einsum("bkgqn,bnkd->bkgqd", pg, vc.astype(p.dtype),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, qn, -1)


def paged_write(entry, table, pos, new, page_size: int):
    """Scatter ``new`` (B, s, Hk, D) tokens into a per-layer page pool
    through the block table.

    ``pos`` vector (B,) with s == 1 (the engine decode path: each row
    writes at its own position) or scalar with s >= 1 (the chunked
    prefill: s consecutive positions from ``pos``). A position landing on
    a sentinel table entry — a parked slot's row, or a final chunk's
    padded tail past the request's allocated pages — is dropped, never
    written (in particular nothing ever lands in another request's page)."""
    b, s = new.shape[0], new.shape[1]
    num_pages = (entry.codes if isinstance(entry, QuantizedKV)
                 else entry).shape[0]
    npg = table.shape[1]
    if getattr(pos, "ndim", 0) == 1:
        assert s == 1, "per-slot paged writes are one token per step"
        cols = pos[:, None]                               # (B, 1)
    else:
        cols = jnp.broadcast_to((pos + jnp.arange(s))[None, :], (b, s))
    valid = cols < npg * page_size
    pidx = jnp.clip(cols // page_size, 0, npg - 1)
    pages = jnp.take_along_axis(table, pidx, axis=1)      # (B, s)
    pages = jnp.where(valid, pages, num_pages)            # OOB → dropped
    offs = cols % page_size
    if isinstance(entry, QuantizedKV):
        qn = kv_quantize(new, entry.group_size)
        return QuantizedKV(entry.codes.at[pages, offs].set(qn.codes),
                           entry.scale.at[pages, offs].set(qn.scale),
                           entry.zero.at[pages, offs].set(qn.zero),
                           entry.group_size)
    return entry.at[pages, offs].set(new.astype(entry.dtype))


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     q_positions: jax.Array, rules: ShardingRules = NO_RULES,
                     p_dtype=jnp.float32) -> jax.Array:
    """Attention of new tokens against a (possibly seq-sharded) KV cache.

    q: (B, S, H, D); caches: (B, Smax, Hk, D); q_positions: (B, S) absolute
    positions (causal mask: key index ≤ query position — the new tokens'
    K/V must already be written into the cache).
    Plain einsum + masked softmax: with the cache seq axis sharded over
    'model', XLA lowers max/sum to the flash-decoding all-reduce pattern.
    """
    b, sq, h, d = q.shape
    hk = k_cache.shape[2]
    g = h // hk
    s = _scores(q, k_cache, g)                       # (B, H, Sq, Smax)
    s = s / math.sqrt(d)
    k_idx = jnp.arange(k_cache.shape[1])
    valid = k_idx[None, None, :] <= q_positions[:, :, None]   # (B, Sq, Smax)
    s = jnp.where(valid[:, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = _pv(p.astype(p_dtype), v_cache, g)         # (B, H, Sq, D)
    return out.transpose(0, 2, 1, 3).astype(q.dtype) # (B, Sq, H, D)


# ---------------------------------------------------------------------------
# attention + MLP blocks (dense family)
# ---------------------------------------------------------------------------

def attn_params(key, cfg, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.num_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, d, dtype),
        "norm": jnp.ones((d,), dtype),
    }


def mlp_params(key, cfg, dtype=jnp.float32, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wu": dense_init(ks[0], d, f, dtype),
         "wd": dense_init(ks[1], f, d, dtype),
         "norm": jnp.ones((d,), dtype)}
    if cfg.mlp_act == "silu":                        # gated
        p["wg"] = dense_init(ks[2], d, f, dtype)
    return p


def attn_apply(p, x, cfg, rules: ShardingRules = NO_RULES, *,
               positions=None, capture=None,
               kv_cache=None, cache_pos=None, attend_cache: bool = False,
               block_table=None, fused_decode: bool = False,
               attn_chunk: int = 1024, attn_p_dtype=jnp.float32):
    """Pre-norm attention block (residual added by caller).

    Returns (out, new_kv): new_kv is (k, v) of this call when kv_cache is
    None (training / prefill cache fill) or the updated (k_cache, v_cache)
    for decode. ``cache_pos`` is the write position: a scalar (uniform
    across the batch — the static serving path) or a per-row (B,) vector
    (the continuous-batching engine, where each slot decodes at its own
    position; requires s == 1). Cache entries may be dense arrays or
    INT8 :class:`~repro.serving.kv_cache.QuantizedKV` storage — quantized
    caches quantize on write and dequantize on the attention read.

    ``attend_cache=True`` is the chunked-prefill contract: for s > 1 with a
    scalar ``cache_pos``, this chunk's K/V is written at
    [cache_pos, cache_pos + s) first (token columns past the cache edge —
    a final chunk's padded tail — are dropped, never shifted), then the
    queries attend the CACHE rows under the offset causal mask
    (key index <= cache_pos + query offset) instead of only the fresh
    chunk, so earlier chunks of the same prompt are visible. Quantized
    caches attend the dequantized rows, including this chunk's own
    (quantize-rounded) keys.

    ``block_table`` (B, n_pages) int32 switches the cache to the PAGED
    layout: cache entries are per-layer page pools (P, page, Hk, D) —
    ``page`` is read off the pool shape — and every position routes
    through the table (writes via :func:`paged_write`, decode reads via a
    page gather, chunked-prefill reads via the in-tile paged flash path).
    Gathered views hold the same written values at the same positions as a
    slot-cache row (everything else is causally masked), so paged greedy
    output is bit-identical to the slot path, dense and INT8 alike.

    ``fused_decode=True`` routes the s == 1 decode read (slot and paged)
    through the fused Pallas flash-decode kernel
    (:func:`~repro.serving.kv_cache.fused_decode_attn`): INT8 codes
    dequantize in-tile, per-row lengths bound the K loop, and the paged
    gather happens in the kernel's index maps — no materialized dense KV.
    False (the default) keeps the dequant-then-attend reference path.
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, hk = cfg.num_heads, cfg.num_kv_heads
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    if capture is not None:
        capture["attn_in"] = xn
    q = linear_apply(p["wq"], xn).reshape(b, s, h, hd)
    k = linear_apply(p["wk"], xn).reshape(b, s, hk, hd)
    v = linear_apply(p["wv"], xn).reshape(b, s, hk, hd)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = hint(q, rules, ("batch", None, "tp", None))
    k = hint(k, rules, ("batch", None, None, None))

    if kv_cache is None:
        out = flash_attention(q, k, v, causal=True, q_chunk=attn_chunk,
                              kv_chunk=attn_chunk, p_dtype=attn_p_dtype)
        new_kv = (k, v)
    elif block_table is not None:
        k_cache, v_cache = kv_cache                  # pools (P, page, Hk, D)
        page = (k_cache.codes if isinstance(k_cache, QuantizedKV)
                else k_cache).shape[1]
        k_cache = paged_write(k_cache, block_table, cache_pos, k, page)
        v_cache = paged_write(v_cache, block_table, cache_pos, v, page)
        if s == 1:
            if fused_decode:
                out = fused_decode_attn(q, k_cache, v_cache, positions,
                                        table=block_table)
            else:
                k_r = paged_view(k_cache, block_table)
                v_r = paged_view(v_cache, block_table)
                if isinstance(k_r, QuantizedKV):
                    k_r = kv_dequantize(k_r, q.dtype)
                    v_r = kv_dequantize(v_r, q.dtype)
                out = decode_attention(q, k_r, v_r, positions, rules,
                                       p_dtype=attn_p_dtype)
        else:
            assert attend_cache, \
                "paged s > 1 is the chunked-prefill contract (batched " \
                "prefill fills a dense mini-cache, then write_pages)"
            out = flash_attention(q, k_cache, v_cache, causal=True,
                                  q_offset=cache_pos, q_chunk=attn_chunk,
                                  kv_chunk=attn_chunk, p_dtype=attn_p_dtype,
                                  kv_pages=(block_table, page))
        new_kv = (k_cache, v_cache)
    else:
        k_cache, v_cache = kv_cache                  # (B, Smax, Hk, D)
        if isinstance(k_cache, QuantizedKV):
            k_cache = kv_update(k_cache, k, cache_pos)
            v_cache = kv_update(v_cache, v, cache_pos)
        elif getattr(cache_pos, "ndim", 0) == 1:     # per-slot positions
            assert s == 1, "per-slot cache writes are one token per step"
            rows = jnp.arange(b)
            k_cache = k_cache.at[rows, cache_pos].set(
                k[:, 0].astype(k_cache.dtype))
            v_cache = v_cache.at[rows, cache_pos].set(
                v[:, 0].astype(v_cache.dtype))
        elif attend_cache:
            # chunked prefill: per-column scatter so a final chunk's padded
            # tail past the cache edge is dropped, never shifted back onto
            # live rows like dynamic_update_slice would
            cols = cache_pos + jnp.arange(s)
            k_cache = k_cache.at[:, cols].set(k.astype(k_cache.dtype))
            v_cache = v_cache.at[:, cols].set(v.astype(v_cache.dtype))
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), cache_pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), cache_pos, axis=1)
        if s > 1 and attend_cache:
            # chunked prefill: attend the cache rows (which now include
            # this chunk's K/V) under the offset causal mask — flash with
            # q_offset keeps per-query numerics bit-compatible with the
            # fresh-prefill path, so chunked greedy output matches the
            # static path exactly on dense f32 caches
            if isinstance(k_cache, QuantizedKV):
                k_r = kv_dequantize(k_cache, q.dtype)
                v_r = kv_dequantize(v_cache, q.dtype)
            else:
                k_r, v_r = k_cache, v_cache
            out = flash_attention(q, k_r, v_r, causal=True,
                                  q_offset=cache_pos, q_chunk=attn_chunk,
                                  kv_chunk=attn_chunk, p_dtype=attn_p_dtype)
        elif s > 1:
            # prefill: flash attention over the new tokens (assumes
            # cache_pos == 0 — the serving manager's convention);
            # decode_attention here would materialize (B,H,S,Smax) scores.
            out = flash_attention(q, k, v, causal=True, q_chunk=attn_chunk,
                                  kv_chunk=attn_chunk, p_dtype=attn_p_dtype)
        elif fused_decode:
            out = fused_decode_attn(q, k_cache, v_cache, positions)
        else:
            if isinstance(k_cache, QuantizedKV):
                k_r = kv_dequantize(k_cache, q.dtype)
                v_r = kv_dequantize(v_cache, q.dtype)
            else:
                k_r, v_r = k_cache, v_cache
            out = decode_attention(q, k_r, v_r, positions, rules,
                                   p_dtype=attn_p_dtype)
        new_kv = (k_cache, v_cache)

    out = hint(out, rules, ("batch", None, "tp", None))
    if capture is not None:
        capture["attn_out_in"] = out.reshape(b, s, h * hd)
    y = linear_apply(p["wo"], out.reshape(b, s, h * hd))
    return y.astype(x.dtype), new_kv


def mlp_apply(p, x, cfg, rules: ShardingRules = NO_RULES, *, capture=None):
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    if capture is not None:
        capture["mlp_in"] = xn
    if cfg.mlp_act == "silu":
        hdn = mlp_act(linear_apply(p["wg"], xn), "silu") * linear_apply(p["wu"], xn)
    else:
        hdn = mlp_act(linear_apply(p["wu"], xn), cfg.mlp_act)
    hdn = hint(hdn, rules, ("batch", None, "tp"))
    if capture is not None:
        capture["mlp_down_in"] = hdn
    return linear_apply(p["wd"], hdn).astype(x.dtype)


__all__ = ["dense_init", "embed_init", "rmsnorm", "rope", "mlp_act",
           "flash_attention", "decode_attention", "paged_write",
           "attn_params", "mlp_params",
           "attn_apply", "mlp_apply", "linear_apply", "expert_apply"]
