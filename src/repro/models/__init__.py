from repro.models.model import build_model, batch_specs, make_batch

__all__ = ["build_model", "batch_specs", "make_batch"]
