"""Mamba-1 selective SSM ([ssm] falcon-mamba-7b).

Training/prefill use a *chunked* selective scan: lax.scan over sequence
chunks carrying the SSM state, with an inner associative_scan inside each
chunk — O(L · d_inner · N) memory per chunk instead of per sequence, and the
cross-chunk carry keeps the recurrence exact. Decode is the O(1) per-token
recurrence (the reason this family runs the long_500k shape).

d_inner is tensor-parallel: the recurrence is elementwise over d_inner so the
scan itself is collective-free; only in/out projections communicate.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.sharding import ShardingRules, NO_RULES, hint


def mamba_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 6)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba's init)
    u = jax.random.uniform(ks[4], (di,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))        # inverse softplus
    return {
        "norm": jnp.ones((d,), dtype),
        "in_proj": L.dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": L.dense_init(ks[2], di, dt_rank + 2 * n, dtype),
        "dt_proj": L.dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": dt_bias.astype(dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": L.dense_init(ks[5], di, d, dtype),
    }


def mamba_logical_axes(cfg: ModelConfig):
    return {"norm": (None, None),
            "in_proj": (None, "fsdp", "tp"),
            "conv_w": (None, None, "tp"),
            "conv_b": (None, "tp"),
            "x_proj": (None, "tp", None),
            "dt_proj": (None, None, "tp"),
            "dt_bias": (None, "tp"),
            "A_log": (None, "tp", None),
            "D": (None, "tp"),
            "out_proj": (None, "tp", "fsdp")}


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds. x: (B, L, di); w: (K, di)."""
    k = w.shape[0]
    y = x * w[-1]
    for j in range(1, k):
        y = y + jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j] * w[k - 1 - j]
    return y + b


def _ssm_coeffs(p, xc, cfg):
    """xc: (B, L, di) post-conv. Returns a, bu, cc: the recurrence inputs."""
    n = cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    x_dbl = xc @ p["x_proj"]                          # (B, L, R+2N)
    dt = jax.nn.softplus(x_dbl[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    bc = x_dbl[..., dt_rank:dt_rank + n]              # (B, L, N)
    cc = x_dbl[..., dt_rank + n:]                     # (B, L, N)
    a_mat = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, N)
    dt = dt.astype(jnp.float32)                       # f32 recurrence state
    a = jnp.exp(dt[..., None] * a_mat)                # (B, L, di, N)
    bu = ((dt * xc.astype(jnp.float32))[..., None]
          * bc.astype(jnp.float32)[:, :, None, :])    # (B, L, di, N)
    return a, bu, cc


def _chunk_scan(a, bu, h0, chunk: int):
    """Exact selective scan via chunked associative scan.
    a, bu: (B, L, di, N); h0: (B, di, N) → h: (B, L, di, N), h_last.
    (Reference path; the fused memory-lean path is :func:`_mamba_scan_y`.)"""
    b, l, di, n = a.shape
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bu = jnp.pad(bu, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = a.shape[1] // chunk
    ac = a.reshape(b, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    bc = bu.reshape(b, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)

    def op(e1, e2):
        p1, s1 = e1
        p2, s2 = e2
        return p1 * p2, s1 * p2 + s2

    def body(h, xs):
        a_i, b_i = xs                                 # (B, chunk, di, N)
        pref_p, pref_s = jax.lax.associative_scan(op, (a_i, b_i), axis=1)
        h_all = pref_s + pref_p * h[:, None]
        return h_all[:, -1], h_all

    h_last, hs = jax.lax.scan(body, h0, (ac, bc))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, di, n)
    return hs[:, :l], h_last


def _mamba_scan_y(p, xc, cfg, h0, chunk: int):
    """Memory-lean selective scan: the (B, L, di, N)-sized recurrence inputs
    a/bu AND the state trajectory are built per-chunk *inside* the scan body
    and contracted with C_t immediately, so only (B, chunk, di, N) is ever
    resident (§Perf falcon-mamba iteration: 3×(B,L,di,N) → 3×/nc).
    xc: (B, L, di) post-conv. Returns y: (B, L, di), h_last."""
    b, l, di = xc.shape
    n = cfg.ssm_state
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    nc = xc.shape[1] // chunk
    xg = xc.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)

    def op(e1, e2):
        p1, s1 = e1
        p2, s2 = e2
        return p1 * p2, s1 * p2 + s2

    @jax.checkpoint   # recompute coeffs+scan in bwd: residual = h carry only
    def body(h, x_i):                                 # x_i: (B, chunk, di)
        a_i, bu_i, cc_i = _ssm_coeffs(p, x_i, cfg)
        pref_p, pref_s = jax.lax.associative_scan(op, (a_i, bu_i), axis=1)
        h_all = pref_s + pref_p * h[:, None]
        y_i = jnp.einsum("bldn,bln->bld", h_all, cc_i.astype(jnp.float32))
        return h_all[:, -1], y_i

    h_last, ys = jax.lax.scan(body, h0, xg)
    y = ys.transpose(1, 0, 2, 3).reshape(b, nc * chunk, di)
    return y[:, :l], h_last


def mamba_apply(p, x, cfg: ModelConfig, rules: ShardingRules = NO_RULES, *,
                capture=None, state=None, chunk: int = 256):
    """Full-sequence Mamba-1 block (residual added by caller).

    state: None for training; {"conv": (B,K-1,di), "ssm": (B,di,N)} for
    decode/continuation — returns (y, new_state) in that case.
    """
    xn = L.rmsnorm(x, p["norm"], cfg.norm_eps)
    if capture is not None:
        capture["mamba_in"] = xn
    xz = L.linear_apply(p["in_proj"], xn)
    xs, z = jnp.split(xz, 2, axis=-1)                 # (B, L, di) each
    xs = hint(xs, rules, ("batch", None, "tp"))

    if state is not None:
        hist = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
        new_conv = hist[:, -(cfg.ssm_conv - 1):]
        k = p["conv_w"].shape[0]
        xc = _causal_conv(hist, p["conv_w"], p["conv_b"])[:, k - 1:][:, -xs.shape[1]:]
        xc = jax.nn.silu(xc)
        y_ssm, h_last = _mamba_scan_y(p, xc, cfg, state["ssm"], chunk)
        new_state = {"conv": new_conv, "ssm": h_last}
    else:
        xc = jax.nn.silu(_causal_conv(xs, p["conv_w"], p["conv_b"]))
        h0 = jnp.zeros((x.shape[0], cfg.d_inner, cfg.ssm_state), jnp.float32)
        y_ssm, _ = _mamba_scan_y(p, xc, cfg, h0, chunk)
        new_state = None

    y = y_ssm.astype(xc.dtype) + p["D"] * xc
    y = y * jax.nn.silu(z)
    if capture is not None:
        capture["mamba_out_in"] = y
    out = L.linear_apply(p["out_proj"], y).astype(x.dtype)
    return out, new_state


@dataclasses.dataclass
class MambaModel(T.DenseModel):
    """Attention-free Mamba-1 LM (falcon-mamba-7b)."""
    scan_chunk: int = 256

    def init(self, key):
        cfg = self.cfg
        k_emb, k_blk, k_head = jax.random.split(key, 3)
        blocks = jax.vmap(lambda k: mamba_params(k, cfg, self.param_dtype))(
            jax.random.split(k_blk, cfg.num_layers))
        params = {"embed": L.embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                        self.param_dtype),
                  "blocks": blocks,
                  "final_norm": jnp.ones((cfg.d_model,), self.param_dtype)}
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(k_head, cfg.d_model,
                                             cfg.padded_vocab, self.param_dtype)
        return params

    def param_logical_axes(self):
        ax = {"embed": (None, "tp"), "final_norm": (None,),
              "blocks": mamba_logical_axes(self.cfg)}
        if not self.cfg.tie_embeddings:
            ax["lm_head"] = ("fsdp", "tp")
        return ax

    def _block_scan(self, params, h, positions):
        cfg, rules = self.cfg, self.rules
        chunk = h.shape[1] if self.unroll else self.scan_chunk
        def body(carry, layer_p):
            y, _ = mamba_apply(layer_p, carry, cfg, rules, chunk=chunk)
            # carry sharded on d_model, NOT seq: the selective scan's time
            # axis must stay local (sharded seq ⇒ serialized cross-device
            # recurrence); channels are elementwise ⇒ free to shard
            return hint(carry + y, rules, ("batch", None, "tp")), None
        if self.unroll:
            for i in range(cfg.num_layers):
                h, _ = body(h, self.block_slice(params, i))
            return h
        body_fn = jax.checkpoint(body) if self.remat else body
        h, _ = jax.lax.scan(body_fn, h, params["blocks"])
        return h

    # -- serving: recurrent state cache -------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        conv = jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1,
                          cfg.d_inner), dtype)
        ssm = jnp.zeros((cfg.num_layers, batch, cfg.d_inner, cfg.ssm_state),
                        jnp.float32)
        conv = hint(conv, self.rules, (None, "batch", None, "tp"))
        ssm = hint(ssm, self.rules, (None, "batch", "tp", None))
        return {"conv": conv, "ssm": ssm, "pos": jnp.zeros((), jnp.int32)}

    def cache_logical_axes(self):
        return {"conv": (None, "batch", None, "tp"),
                "ssm": (None, "batch", "tp", None),
                "pos": ()}

    def _cached_scan(self, params, h, cache, positions):
        cfg, rules = self.cfg, self.rules
        chunk = max(h.shape[1], 1) if self.unroll else self.scan_chunk
        def body(x, scanned):
            layer_p, conv, ssm = scanned
            y, st = mamba_apply(layer_p, x, cfg, rules, chunk=chunk,
                                state={"conv": conv, "ssm": ssm})
            return x + y, (st["conv"], st["ssm"])
        if self.unroll:
            cs, ss = [], []
            for i in range(cfg.num_layers):
                h, (cv, sv) = body(h, (self.block_slice(params, i),
                                       cache["conv"][i], cache["ssm"][i]))
                cs.append(cv)
                ss.append(sv)
            conv_new, ssm_new = jnp.stack(cs), jnp.stack(ss)
        else:
            h, (conv_new, ssm_new) = jax.lax.scan(
                body, h, (params["blocks"], cache["conv"], cache["ssm"]))
        return h, {"conv": conv_new, "ssm": ssm_new,
                   "pos": cache["pos"] + positions.shape[1]}

    def block_linears(self, i):
        return [
            ("in_proj", ("blocks", "in_proj"), "mamba_in"),
            ("out_proj", ("blocks", "out_proj"), "mamba_out_in"),
        ]

    def block_apply_one(self, params, i, h, *, capture=False):
        bp = self.block_slice(params, i)
        cap = {} if capture else None
        y, _ = mamba_apply(bp, h, self.cfg, self.rules, capture=cap,
                           chunk=self.scan_chunk)
        return h + y, (cap or {})


__all__ = ["mamba_params", "mamba_logical_axes", "mamba_apply", "MambaModel"]
