"""Dense-family decoder LM (covers [dense], [audio], [vlm] archs).

- lax.scan over stacked per-layer params (compile time ~ one layer).
- jax.checkpoint (remat) around each block for training.
- Chunked softmax-xent so full (B, S, V) logits are never materialized.
- [audio]: input is precomputed frame embeddings (EnCodec frontend stub).
- [vlm]: precomputed patch embeddings are prepended to token embeddings
  (InternViT frontend stub); loss is masked to text positions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.quant import QTensor
from repro.sharding import ShardingRules, NO_RULES, hint  # noqa: F401 (re-export)

Params = Dict[str, Any]


def block_params(key, cfg: ModelConfig, dtype=jnp.float32):
    ka, km = jax.random.split(key)
    return {"attn": L.attn_params(ka, cfg, dtype),
            "mlp": L.mlp_params(km, cfg, dtype)}


def block_apply(p, x, cfg, rules=NO_RULES, *, positions=None, capture=None,
                kv_cache=None, cache_pos=None, attend_cache: bool = False,
                block_table=None, fused_decode: bool = False,
                attn_chunk: int = 1024, attn_p_dtype=jnp.float32):
    a, new_kv = L.attn_apply(p["attn"], x, cfg, rules, positions=positions,
                             capture=capture, kv_cache=kv_cache,
                             cache_pos=cache_pos, attend_cache=attend_cache,
                             block_table=block_table,
                             fused_decode=fused_decode,
                             attn_chunk=attn_chunk,
                             attn_p_dtype=attn_p_dtype)
    x = x + a
    x = x + L.mlp_apply(p["mlp"], x, cfg, rules, capture=capture)
    return x, new_kv


@dataclasses.dataclass
class DenseModel:
    cfg: ModelConfig
    rules: ShardingRules = NO_RULES
    param_dtype: Any = jnp.float32
    remat: bool = True
    logit_chunk: int = 512
    attn_chunk: int = 1024
    # bf16 P·V (f32 softmax stats, bf16 probs into the MXU): the TPU-flash
    # convention; default f32 so tests compare bit-tight. §Perf iteration.
    attn_p_dtype: Any = jnp.float32
    # unroll=True replaces every lax.scan whose body repeats (layers,
    # microbatches) with a python loop and makes inner chunk scans
    # single-iteration: used by the dry-run COST lowering, where XLA's
    # cost_analysis counts loop bodies once (see analysis/roofline.py).
    unroll: bool = False
    # route s == 1 decode cache reads through the fused Pallas flash-decode
    # kernel (in-tile INT8 dequant, length-bounded K loop) instead of the
    # dequant-then-attend reference. Off by default so the static paths
    # keep their exact numerics; the serving engine flips it per
    # EngineConfig.use_fused_decode.
    use_fused_decode: bool = False

    # -- params ------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        k_emb, k_blk, k_head = jax.random.split(key, 3)
        blocks = jax.vmap(lambda k: block_params(k, cfg, self.param_dtype))(
            jax.random.split(k_blk, cfg.num_layers))
        params = {
            "embed": L.embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                  self.param_dtype),
            "blocks": blocks,
            "final_norm": jnp.ones((cfg.d_model,), self.param_dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(k_head, cfg.d_model,
                                             cfg.padded_vocab, self.param_dtype)
        return params

    def param_logical_axes(self):
        """Logical axis names per param (stacked blocks lead with layer=None)."""
        cfg = self.cfg
        ax = {
            "embed": (None, "tp"),          # vocab replicated, d_model TP
            "final_norm": (None,),
            "blocks": {
                "attn": {"wq": (None, "fsdp", "tp"),
                         "wk": (None, "fsdp", "tp"),
                         "wv": (None, "fsdp", "tp"),
                         "wo": (None, "tp", "fsdp"),
                         "norm": (None, None)},
                "mlp": {"wu": (None, "fsdp", "tp"),
                        "wd": (None, "tp", "fsdp"),
                        "norm": (None, None)},
            },
        }
        if cfg.mlp_act == "silu":
            ax["blocks"]["mlp"]["wg"] = (None, "fsdp", "tp")
        if not cfg.tie_embeddings:
            ax["lm_head"] = ("fsdp", "tp")  # vocab TP for chunked loss
        return ax

    # -- embedding / frontend ------------------------------------------------
    def embed(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            h = batch["frames"].astype(self.param_dtype)   # stub frontend
        else:
            h = jnp.take(params["embed"], batch["tokens"], axis=0)
            if cfg.frontend == "vision_patches":
                patches = batch["patches"].astype(h.dtype)  # stub frontend
                h = jnp.concatenate([patches, h], axis=1)
        return hint(h, self.rules, ("batch", None, None))

    def _block_scan(self, params, h, positions):
        cfg, rules = self.cfg, self.rules
        def body(carry, layer_p):
            x = carry
            y, _ = block_apply(layer_p, x, cfg, rules, positions=positions,
                               attn_chunk=self.attn_chunk,
                               attn_p_dtype=self.attn_p_dtype)
            # sequence-parallel carry: the scan residuals that AD must save
            # are sharded over ('batch', tp-on-seq) — Megatron-SP layout;
            # cuts per-device saved activations by the TP degree (DESIGN §4)
            return hint(y, rules, ("batch", "tp", None)), None
        if self.unroll:
            for i in range(cfg.num_layers):
                h, _ = body(h, self.block_slice(params, i))
            return h
        body_fn = jax.checkpoint(body) if self.remat else body
        h, _ = jax.lax.scan(body_fn, h, params["blocks"])
        return h

    def hidden_states(self, params, batch) -> jax.Array:
        h = self.embed(params, batch)
        b, s = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        h = self._block_scan(params, h, positions)
        return L.rmsnorm(h, params["final_norm"], self.cfg.norm_eps)

    def _head_w(self, params):
        """Dense (d_model, vocab) head weight — or a QTensor leaf when the
        head was quantized; every consumer goes through L.linear_apply."""
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _mask_pad(self, logits: jax.Array) -> jax.Array:
        """-inf the padded vocab columns (padded_vocab > vocab_size)."""
        v = self.cfg.vocab_size
        if logits.shape[-1] == v:
            return logits
        iota = jnp.arange(logits.shape[-1])
        return jnp.where(iota < v, logits, jnp.finfo(logits.dtype).min)

    def logits(self, params, batch) -> jax.Array:
        return self._mask_pad(L.linear_apply(self._head_w(params),
                                             self.hidden_states(params, batch)))

    # -- training loss (chunked xent, full logits never built) -------------
    def loss(self, params, batch) -> tuple:
        h = self.hidden_states(params, batch)
        labels = batch["labels"]
        if self.cfg.frontend == "vision_patches":
            # loss only on text positions (patches occupy the prefix)
            h = h[:, self.cfg.num_patches:, :] if self.cfg.num_patches else h
        nll, cnt = chunked_xent(h, self._head_w(params), labels,
                                chunk=self.logit_chunk, rules=self.rules,
                                vocab=self.cfg.vocab_size)
        return nll / jnp.maximum(cnt, 1.0), {"sum_nll": nll, "tokens": cnt}

    # -- serving ------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads,
                 cfg.resolved_head_dim)
        k = jnp.zeros(shape, dtype)
        k = hint(k, self.rules, (None, "batch", "seq_kv", None, None))
        return {"k": k, "v": k, "pos": jnp.zeros((), jnp.int32)}

    def cache_logical_axes(self):
        return {"k": (None, "batch", "seq_kv", None, None),
                "v": (None, "batch", "seq_kv", None, None),
                "pos": ()}

    def _cached_scan(self, params, h, cache, positions, *,
                     attend_cache: bool = False):
        cfg, rules = self.cfg, self.rules
        # paged layout: cache["table"] (B, n_pages) routes every cache
        # access; it has no layer axis, so it rides into the scan body as a
        # closed-over constant rather than a scanned operand
        table = cache.get("table")
        def body(x, scanned):
            layer_p, kc, vc = scanned
            y, (kc2, vc2) = block_apply(layer_p, x, cfg, rules,
                                        positions=positions,
                                        kv_cache=(kc, vc),
                                        cache_pos=cache["pos"],
                                        attend_cache=attend_cache,
                                        block_table=table,
                                        fused_decode=self.use_fused_decode,
                                        attn_chunk=self.attn_chunk,
                                        attn_p_dtype=self.attn_p_dtype)
            return y, (kc2, vc2)
        if self.unroll:
            kvs = []
            for i in range(cfg.num_layers):
                layer_kv = jax.tree.map(lambda x: x[i],
                                        (cache["k"], cache["v"]))
                h, kv2 = body(h, (self.block_slice(params, i),) + layer_kv)
                kvs.append(kv2)
            k_new, v_new = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
        else:
            h, (k_new, v_new) = jax.lax.scan(
                body, h, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": k_new, "v": v_new,
                     "pos": cache["pos"] + positions.shape[1]}
        if table is not None:
            new_cache["table"] = table
        return h, new_cache

    @staticmethod
    def _base_positions(pos: jax.Array) -> jax.Array:
        """Cache position as a broadcastable base: scalar (static path) or
        per-slot vector (the engine's slot cache) → (B|1, 1)."""
        return pos[:, None] if getattr(pos, "ndim", 0) == 1 else pos

    def prefill(self, params, batch, cache):
        """Teacher-forced pass that fills the cache; returns last logits."""
        h = self.embed(params, batch)
        b, s = h.shape[0], h.shape[1]
        positions = (jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
                     + self._base_positions(cache["pos"]))
        h, cache = self._cached_scan(params, h, cache, positions)
        h_last = L.rmsnorm(h[:, -1:, :], params["final_norm"], self.cfg.norm_eps)
        return self._mask_pad(L.linear_apply(self._head_w(params), h_last)), cache

    def prefill_at(self, params, batch, cache, lengths):
        """Prefill right-padded prompts: per-row true ``lengths`` (B,).

        Same cache fill as :meth:`prefill` (cache rows past a row's length
        hold padding K/V — never attended, the causal mask stops at each
        query's position and decode overwrites them in order), but logits
        are gathered at each row's LAST REAL token (lengths-1) instead of
        the padded tail. The engine's bucketed prefill step drives this.
        """
        h = self.embed(params, batch)
        b, s = h.shape[0], h.shape[1]
        positions = (jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
                     + self._base_positions(cache["pos"]))
        h, cache = self._cached_scan(params, h, cache, positions)
        idx = jnp.clip(lengths - 1, 0, s - 1)
        h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)  # (B,1,d)
        h_last = L.rmsnorm(h_last, params["final_norm"], self.cfg.norm_eps)
        return self._mask_pad(L.linear_apply(self._head_w(params), h_last)), cache

    def prefill_chunk(self, params, batch, cache, lengths):
        """One fixed-width chunk of a longer prompt against a cache that
        already holds the earlier chunks' K/V below ``cache["pos"]``.

        The chunked-prefill contract (the engine's long-prompt path): this
        chunk's K/V is written at [pos, pos + W) of the cache rows, and —
        unlike :meth:`prefill_at` — the queries ATTEND THE CACHE under the
        offset causal mask (key index <= each query's absolute position),
        so earlier chunks of the same prompt are visible and a prompt of
        any length streams through one bucket-width program. Logits are
        gathered at the chunk-local ``lengths - 1`` — meaningful only on
        the final (right-padded) chunk; callers discard earlier chunks'
        samples. A final chunk's padded tail past the cache edge is
        dropped, never written onto live rows.
        """
        h = self.embed(params, batch)
        b, s = h.shape[0], h.shape[1]
        positions = (jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
                     + self._base_positions(cache["pos"]))
        h, cache = self._cached_scan(params, h, cache, positions,
                                     attend_cache=True)
        idx = jnp.clip(lengths - 1, 0, s - 1)
        h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)  # (B,1,d)
        h_last = L.rmsnorm(h_last, params["final_norm"], self.cfg.norm_eps)
        return self._mask_pad(L.linear_apply(self._head_w(params), h_last)), cache

    def decode_step(self, params, tokens, cache):
        """One decode step. tokens: (B, 1) int32. ``cache["pos"]`` is a
        scalar (uniform batch) or a per-slot (B,) vector (engine path)."""
        h = jnp.take(params["embed"], tokens, axis=0)
        b = h.shape[0]
        pos = cache["pos"]
        if getattr(pos, "ndim", 0) == 1:
            positions = pos[:, None]                       # (B, 1) per-slot
        else:
            positions = jnp.broadcast_to(pos[None, None], (b, 1))
        h, cache = self._cached_scan(params, h, cache, positions)
        h = L.rmsnorm(h, params["final_norm"], self.cfg.norm_eps)
        return self._mask_pad(L.linear_apply(self._head_w(params), h)), cache

    # -- compression protocol ------------------------------------------------
    def num_blocks(self) -> int:
        return self.cfg.num_layers

    def block_slice(self, params, i: int):
        return jax.tree.map(lambda x: x[i], params["blocks"])

    def block_apply_one(self, params, i: int, h, *, capture=False):
        cfg = self.cfg
        bp = self.block_slice(params, i)
        cap: Optional[dict] = {} if capture else None
        b, s = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        out, _ = block_apply(bp, h, cfg, self.rules, positions=positions,
                             capture=cap)
        return out, (cap or {})

    def block_linears(self, i: int):
        """(name, param_path, capture_key) — paper orientation obtained by
        transposing the stored (d_in, d_out) weight."""
        specs = [
            ("wq", ("blocks", "attn", "wq"), "attn_in"),
            ("wk", ("blocks", "attn", "wk"), "attn_in"),
            ("wv", ("blocks", "attn", "wv"), "attn_in"),
            ("wo", ("blocks", "attn", "wo"), "attn_out_in"),
            ("wu", ("blocks", "mlp", "wu"), "mlp_in"),
            ("wd", ("blocks", "mlp", "wd"), "mlp_down_in"),
        ]
        if self.cfg.mlp_act == "silu":
            specs.insert(4, ("wg", ("blocks", "mlp", "wg"), "mlp_in"))
        return specs


def chunked_xent(h: jax.Array, w_head: jax.Array, labels: jax.Array, *,
                 chunk: int = 512, rules: ShardingRules = NO_RULES,
                 vocab: int = 0):
    """Σ NLL over (B, S) without materializing (B, S, V) logits.

    Scans over sequence chunks; each chunk's logits are vocab-sharded over
    the TP axis. labels == -1 are ignored (padding); ``vocab`` masks the
    padded head columns (padded_vocab) out of the logsumexp."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = h.shape[1] // chunk
    hc = h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    w32 = w_head if isinstance(w_head, QTensor) else w_head.astype(jnp.float32)

    @jax.checkpoint   # recompute chunk logits in backward (never resident)
    def body(carry, xs):
        nll_acc, cnt_acc = carry
        hx, lx = xs
        logits = hint(L.linear_apply(w32, hx.astype(jnp.float32)),
                      rules, ("batch", None, "tp"))
        vocab_iota = jnp.arange(logits.shape[-1])
        if vocab and logits.shape[-1] != vocab:
            logits = jnp.where(vocab_iota[None, None, :] < vocab,
                               logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.maximum(lx, 0)
        # label-logit via iota-compare-select reduction (fuses; never
        # gathers across the vocab-sharded axis, unlike take_along_axis)
        gold = jnp.sum(jnp.where(vocab_iota[None, None, :] == lab[..., None],
                                 logits, 0.0), axis=-1)
        valid = lx >= 0
        nll = jnp.where(valid, lse - gold, 0.0)
        return (nll_acc + nll.sum(), cnt_acc + valid.sum()), None

    (nll, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)),
                                 (hc, lc))
    return nll, cnt


__all__ = ["DenseModel", "block_params", "block_apply", "chunked_xent"]
