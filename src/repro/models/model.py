"""Model registry: config → model instance, plus dry-run input specs.

``input_specs(cfg, shape, ...)`` returns jax.ShapeDtypeStruct stand-ins for
every model input (and param/cache trees on request) so the launcher can
``jit(...).lower(...)`` with zero allocation — the multi-pod dry-run pattern.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import DenseModel
from repro.models.moe import MoEModel
from repro.models.mamba import MambaModel
from repro.models.mamba2 import Zamba2Model
from repro.sharding import ShardingRules, NO_RULES

_FAMILY = {
    "dense": DenseModel,
    "audio": DenseModel,
    "vlm": DenseModel,
    "moe": MoEModel,
    "ssm": MambaModel,
    "hybrid": Zamba2Model,
}


def build_model(cfg: ModelConfig, rules: ShardingRules = NO_RULES,
                param_dtype=jnp.float32, remat: bool = True):
    cls = _FAMILY[cfg.family]
    return cls(cfg=cfg, rules=rules, param_dtype=param_dtype, remat=remat)


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """ShapeDtypeStructs for one input batch (tokens + frontend stubs)."""
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "audio_frames":
        return {"frames": sds((batch, seq, cfg.d_model), jnp.bfloat16),
                "labels": sds((batch, seq), jnp.int32)}
    out = {"tokens": sds((batch, seq), jnp.int32),
           "labels": sds((batch, seq), jnp.int32)}
    if cfg.frontend == "vision_patches":
        text = max(seq - cfg.num_patches, 1)
        out = {"tokens": sds((batch, text), jnp.int32),
               "patches": sds((batch, cfg.num_patches, cfg.d_model),
                              jnp.bfloat16),
               "labels": sds((batch, text), jnp.int32)}
    return out


def make_batch(cfg: ModelConfig, key, batch: int, seq: int) -> Dict[str, Any]:
    """Concrete random batch matching batch_specs (for smoke tests)."""
    ks = jax.random.split(key, 3)
    specs = batch_specs(cfg, batch, seq)
    out = {}
    for name, s in specs.items():
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(ks[0], s.shape, 0, cfg.vocab_size)
        else:
            out[name] = jax.random.normal(ks[1], s.shape, jnp.float32).astype(s.dtype)
    return out


__all__ = ["build_model", "batch_specs", "make_batch"]
