"""Mamba-2 (SSD) blocks + the Zamba2 hybrid model ([hybrid] zamba2-1.2b).

SSD uses scalar-per-head decay, which turns the selective scan into the
chunked *matmul* algorithm (intra-chunk attention-like matmuls + a cheap
inter-chunk recurrence) — MXU-friendly, unlike Mamba-1's elementwise scan.

Zamba2 = stacked Mamba-2 blocks with ONE shared attention+MLP block
re-invoked every ``attn_every`` blocks (weights shared across call sites;
DESIGN.md §5 notes the simplifications vs. the exact Zamba2 wiring).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.mamba import _causal_conv
from repro.sharding import ShardingRules, NO_RULES, hint


def mamba2_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    ks = jax.random.split(key, 7)
    u = jax.random.uniform(ks[5], (nh,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "norm": jnp.ones((d,), dtype),
        "wz": L.dense_init(ks[0], d, di, dtype),
        "wx": L.dense_init(ks[1], d, di, dtype),
        "wbc": L.dense_init(ks[2], d, 2 * n, dtype),
        "wdt": L.dense_init(ks[3], d, nh, dtype),
        "dt_bias": dt_bias.astype(dtype),
        "conv_w": (jax.random.normal(ks[4], (cfg.ssm_conv, di), jnp.float32)
                   / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "A_log": jnp.zeros((nh,), dtype),
        "D": jnp.ones((nh,), dtype),
        "out_norm": jnp.ones((di,), dtype),
        "out_proj": L.dense_init(ks[6], di, d, dtype),
    }


def mamba2_logical_axes(cfg: ModelConfig):
    return {"norm": (None, None), "wz": (None, "fsdp", "tp"),
            "wx": (None, "fsdp", "tp"), "wbc": (None, "fsdp", None),
            "wdt": (None, "fsdp", None), "dt_bias": (None, None),
            "conv_w": (None, None, "tp"), "conv_b": (None, "tp"),
            "A_log": (None, None), "D": (None, None),
            "out_norm": (None, "tp"), "out_proj": (None, "tp", "fsdp")}


def _ssd_chunked(xh, dtv, a_log, bc, cc, h0, chunk: int):
    """Chunked SSD. xh: (B,L,nh,hd); dtv,a_log: (B,L,nh); bc,cc: (B,L,N).
    h0: (B,nh,hd,N). Returns y (B,L,nh,hd), h_last."""
    b, l, nh, hd = xh.shape
    n = bc.shape[-1]
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        bc = jnp.pad(bc, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // chunk
    xg = xh.reshape(b, nc, chunk, nh, hd)
    dg = dtv.reshape(b, nc, chunk, nh)
    ag = a_log.reshape(b, nc, chunk, nh)
    bg = bc.reshape(b, nc, chunk, n)
    cg = cc.reshape(b, nc, chunk, n)

    la = jnp.cumsum(ag, axis=2)                        # (B,nc,cl,nh)
    # intra-chunk: decay matrix per head, causal
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]  # (B,nc,t,s,nh)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bctn,bcsn->bcts", cg, bg)     # (B,nc,t,s)
    m = scores[..., None] * lmat * dg[:, :, None, :, :]
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", m, xg)

    # chunk states: S_c = Σ_s exp(la_end - la_s)·dt_s·(x_s ⊗ B_s)
    decay_end = jnp.exp(la[:, :, -1:, :] - la)         # (B,nc,cl,nh)
    s_c = jnp.einsum("bcsh,bcsn,bcshp->bchpn", decay_end * dg, bg, xg)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(la[:, :, -1, :])             # (B,nc,nh)
    def body(h, xs):
        dcy, s = xs                                    # (B,nh), (B,nh,hd,N)
        h_new = h * dcy[:, :, None, None] + s
        return h_new, h                                # emit state BEFORE chunk
    h_last, h_prev = jax.lax.scan(
        body, h0, (chunk_decay.transpose(1, 0, 2), s_c.transpose(1, 0, 2, 3, 4)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)           # (B,nc,nh,hd,N)

    y_inter = jnp.einsum("bcth,bctn,bchpn->bcthp",
                         jnp.exp(la), cg, h_prev)
    y = (y_intra + y_inter).reshape(b, nc * chunk, nh, hd)
    return y[:, :l], h_last


def mamba2_apply(p, x, cfg: ModelConfig, rules: ShardingRules = NO_RULES, *,
                 capture=None, state=None, chunk: int = 128):
    di, n = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    xn = L.rmsnorm(x, p["norm"], cfg.norm_eps)
    if capture is not None:
        capture["mamba2_in"] = xn
    z = L.linear_apply(p["wz"], xn)
    xs = L.linear_apply(p["wx"], xn)
    xs = hint(xs, rules, ("batch", None, "tp"))
    bc_all = xn @ p["wbc"]
    bcv, ccv = bc_all[..., :n], bc_all[..., n:]
    dtv = jax.nn.softplus(xn @ p["wdt"] + p["dt_bias"])   # (B,L,nh)
    a_log = dtv * (-jnp.exp(p["A_log"].astype(jnp.float32)))

    if state is not None:
        hist = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
        new_conv = hist[:, -(cfg.ssm_conv - 1):]
        k = p["conv_w"].shape[0]
        xc = _causal_conv(hist, p["conv_w"], p["conv_b"])[:, k - 1:][:, -xs.shape[1]:]
        h0 = state["ssm"]
    else:
        xc = _causal_conv(xs, p["conv_w"], p["conv_b"])
        h0 = jnp.zeros((x.shape[0], nh, hd, n), jnp.float32)
        new_conv = None
    xc = jax.nn.silu(xc)
    xh = xc.reshape(*xc.shape[:2], nh, hd)
    y, h_last = _ssd_chunked(xh, dtv, a_log, bcv, ccv, h0, chunk)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(*x.shape[:2], di)
    # gated RMSNorm (mamba2's norm-before-out-proj)
    y = L.rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    if capture is not None:
        capture["mamba2_out_in"] = y
    out = L.linear_apply(p["out_proj"], y).astype(x.dtype)
    new_state = None if state is None else {"conv": new_conv, "ssm": h_last}
    return out, new_state


# ---------------------------------------------------------------------------
# Zamba2 hybrid model
# ---------------------------------------------------------------------------

def _shared_positions(cfg: ModelConfig):
    """Mamba-block indices before which the shared attn block is invoked."""
    return [i for i in range(cfg.num_layers) if i % cfg.attn_every == 0]


@dataclasses.dataclass
class Zamba2Model(T.DenseModel):
    """Mamba-2 backbone + one shared attention+MLP block (zamba2-1.2b)."""
    scan_chunk: int = 128

    def init(self, key):
        cfg = self.cfg
        k_emb, k_blk, k_sh, k_head = jax.random.split(key, 4)
        blocks = jax.vmap(lambda k: mamba2_params(k, cfg, self.param_dtype))(
            jax.random.split(k_blk, cfg.num_layers))
        params = {"embed": L.embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                        self.param_dtype),
                  "blocks": blocks,
                  "shared": T.block_params(k_sh, cfg, self.param_dtype),
                  "final_norm": jnp.ones((cfg.d_model,), self.param_dtype)}
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(k_head, cfg.d_model,
                                             cfg.padded_vocab, self.param_dtype)
        return params

    def param_logical_axes(self):
        dense_ax = T.DenseModel(self.cfg).param_logical_axes()
        shared = {k: v[1:] for k, v in
                  dense_ax["blocks"]["attn"].items()}   # unstacked
        shared_mlp = {k: v[1:] for k, v in dense_ax["blocks"]["mlp"].items()}
        ax = {"embed": (None, "tp"), "final_norm": (None,),
              "blocks": mamba2_logical_axes(self.cfg),
              "shared": {"attn": shared, "mlp": shared_mlp}}
        if not self.cfg.tie_embeddings:
            ax["lm_head"] = ("fsdp", "tp")
        return ax

    def _groups(self):
        cfg = self.cfg
        pos = _shared_positions(cfg) + [cfg.num_layers]
        return [(pos[i], pos[i + 1]) for i in range(len(pos) - 1)]

    def _block_scan(self, params, h, positions):
        cfg, rules = self.cfg, self.rules
        chunk = h.shape[1] if self.unroll else self.scan_chunk
        def mamba_body(carry, layer_p):
            y, _ = mamba2_apply(layer_p, carry, cfg, rules, chunk=chunk)
            # d_model-sharded carry; seq stays local (see mamba.py note)
            return hint(carry + y, rules, ("batch", None, "tp")), None
        body_fn = jax.checkpoint(mamba_body) if self.remat else mamba_body
        for lo, hi in self._groups():
            h, _ = T.block_apply(params["shared"], h, cfg, rules,
                                 positions=positions,
                                 attn_chunk=self.attn_chunk,
                                 attn_p_dtype=self.attn_p_dtype)
            h = hint(h, rules, ("batch", None, "tp"))
            if self.unroll:
                for i in range(lo, hi):
                    h, _ = mamba_body(h, self.block_slice(params, i))
            else:
                group = jax.tree.map(lambda x: x[lo:hi], params["blocks"])
                h, _ = jax.lax.scan(body_fn, h, group)
        return h

    # -- serving --------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        nh = cfg.d_inner // cfg.ssm_head_dim
        n_shared = len(_shared_positions(cfg))
        conv = jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1,
                          cfg.d_inner), dtype)
        ssm = jnp.zeros((cfg.num_layers, batch, nh, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32)
        kv = jnp.zeros((n_shared, batch, max_len, cfg.num_kv_heads,
                        cfg.resolved_head_dim), dtype)
        kv = hint(kv, self.rules, (None, "batch", "seq_kv", None, None))
        return {"conv": conv, "ssm": ssm, "k": kv, "v": kv,
                "pos": jnp.zeros((), jnp.int32)}

    def cache_logical_axes(self):
        return {"conv": (None, "batch", None, "tp"),
                "ssm": (None, "batch", None, None, None),
                "k": (None, "batch", "seq_kv", None, None),
                "v": (None, "batch", "seq_kv", None, None),
                "pos": ()}

    def _cached_scan(self, params, h, cache, positions):
        cfg, rules = self.cfg, self.rules
        chunk = max(h.shape[1], 1) if self.unroll else self.scan_chunk
        def mamba_body(x, scanned):
            layer_p, conv, ssm = scanned
            y, st = mamba2_apply(layer_p, x, cfg, rules, chunk=chunk,
                                 state={"conv": conv, "ssm": ssm})
            return x + y, (st["conv"], st["ssm"])
        conv_new = jnp.zeros_like(cache["conv"])
        ssm_new = jnp.zeros_like(cache["ssm"])
        k_new = cache["k"]
        v_new = cache["v"]
        for gi, (lo, hi) in enumerate(self._groups()):
            h, (kc, vc) = T.block_apply(params["shared"], h, cfg, rules,
                                        positions=positions,
                                        kv_cache=(cache["k"][gi], cache["v"][gi]),
                                        cache_pos=cache["pos"],
                                        attn_chunk=self.attn_chunk,
                                        attn_p_dtype=self.attn_p_dtype)
            k_new = k_new.at[gi].set(kc)
            v_new = v_new.at[gi].set(vc)
            if self.unroll:
                cs, ss = [], []
                for i in range(lo, hi):
                    h, (cv1, sv1) = mamba_body(
                        h, (self.block_slice(params, i),
                            cache["conv"][i], cache["ssm"][i]))
                    cs.append(cv1)
                    ss.append(sv1)
                cv, sv = jnp.stack(cs), jnp.stack(ss)
            else:
                group = jax.tree.map(lambda x: x[lo:hi], params["blocks"])
                h, (cv, sv) = jax.lax.scan(
                    mamba_body, h, (group, cache["conv"][lo:hi], cache["ssm"][lo:hi]))
            conv_new = jax.lax.dynamic_update_slice_in_dim(conv_new, cv, lo, 0)
            ssm_new = jax.lax.dynamic_update_slice_in_dim(ssm_new, sv, lo, 0)
        return h, {"conv": conv_new, "ssm": ssm_new, "k": k_new, "v": v_new,
                   "pos": cache["pos"] + positions.shape[1]}

    # -- compression protocol: mamba blocks + the shared block (id = L) -----
    def num_blocks(self):
        return self.cfg.num_layers + 1     # + shared block (compressed once)

    def block_apply_one(self, params, i, h, *, capture=False):
        cfg = self.cfg
        cap = {} if capture else None
        if i == cfg.num_layers:            # shared attn+mlp block
            b, s = h.shape[0], h.shape[1]
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
            out, _ = T.block_apply(params["shared"], h, cfg, self.rules,
                                   positions=positions, capture=cap)
            return out, (cap or {})
        bp = self.block_slice(params, i)
        y, _ = mamba2_apply(bp, h, cfg, self.rules, capture=cap,
                            chunk=self.scan_chunk)
        return h + y, (cap or {})

    def block_linears(self, i):
        if i == self.cfg.num_layers:
            specs = [("wq", ("shared", "attn", "wq"), "attn_in"),
                     ("wk", ("shared", "attn", "wk"), "attn_in"),
                     ("wv", ("shared", "attn", "wv"), "attn_in"),
                     ("wo", ("shared", "attn", "wo"), "attn_out_in"),
                     ("wu", ("shared", "mlp", "wu"), "mlp_in"),
                     ("wd", ("shared", "mlp", "wd"), "mlp_down_in")]
            if self.cfg.mlp_act == "silu":
                specs.insert(4, ("wg", ("shared", "mlp", "wg"), "mlp_in"))
            return specs
        return [("wz", ("blocks", "wz"), "mamba2_in"),
                ("wx", ("blocks", "wx"), "mamba2_in"),
                ("out_proj", ("blocks", "out_proj"), "mamba2_out_in")]


__all__ = ["mamba2_params", "mamba2_logical_axes", "mamba2_apply",
           "Zamba2Model"]
