"""Mixture-of-Experts FFN with three execution paths (DESIGN.md §4):

1. ``a2a``     — shard_map expert parallelism for the many-token shapes
                 (train / prefill): tokens flat-sharded over the whole mesh,
                 static per-(source, expert) capacity, explicit
                 ``jax.lax.all_to_all`` dispatch/return over the 'model'
                 axis, experts sharded over 'model'. Zero overcompute.
2. ``dense``   — masked all-expert compute for the few-token shapes
                 (decode): every expert weight is read once regardless of
                 routing, so the *memory* roofline term is identical to
                 ideal routing while avoiding degenerate small-token
                 all-to-alls. decode is memory-bound ⇒ the extra FLOPs sit
                 under the memory term (see EXPERIMENTS.md §Roofline note).
3. capture     — single-device path that additionally returns per-expert
                 routing masks + inputs so the compression driver can build
                 per-expert calibration covariances C_e.

Gating: full softmax over experts, top-k, renormalized (Qwen3/Grok style).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.quant import QTensor
from repro.sharding import ShardingRules, NO_RULES, hint


def moe_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(d)
    scale_out = 1.0 / jnp.sqrt(f)
    p = {
        "router": L.dense_init(ks[0], d, e, dtype),
        "wu": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale_in).astype(dtype),
        "wd": (jax.random.normal(ks[2], (e, f, d), jnp.float32) * scale_out).astype(dtype),
        "norm": jnp.ones((d,), dtype),
    }
    if cfg.mlp_act == "silu":
        p["wg"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32) * scale_in).astype(dtype)
    return p


def moe_logical_axes(cfg: ModelConfig):
    """(L, E, d, f) expert-weight sharding: experts on TP when there are
    enough of them to tile the 16-wide production axis; otherwise (grok-1's
    8 experts) TP moves to the d_ff dim so storage still shards 256-ways
    (replicated expert weights would be 39 GB/device)."""
    e_on_tp = cfg.num_experts >= 16
    if e_on_tp:
        up, down = (None, "tp", "fsdp", None), (None, "tp", None, "fsdp")
    else:
        up, down = (None, None, "fsdp", "tp"), (None, None, "tp", "fsdp")
    ax = {"router": (None, None, None),        # (L, d, E) replicated
          "wu": up, "wd": down, "norm": (None, None)}
    if cfg.mlp_act == "silu":
        ax["wg"] = up
    return ax


def _gates(xn: jax.Array, router_w: jax.Array, k: int):
    """Top-k renormalized softmax gates. xn: (T, d) → (T, k) gates + idx."""
    logits = xn.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i


def _expert_mm(w, xe: jax.Array) -> jax.Array:
    """(E, C, d_in) @ per-expert weight → (E, C, d_out): dense einsum or the
    stacked-QTensor dequant-matmul (packed experts on the a2a path)."""
    if isinstance(w, QTensor):
        return L.expert_apply(w, xe, per_expert=True)
    return jnp.einsum("ecd,edf->ecf", xe, w)


def _expert_ffn(xe: jax.Array, wg, wu, wd, act: str) -> jax.Array:
    """xe: (E, C, d) per-expert token buffers, expert-batched matmuls."""
    up = _expert_mm(wu, xe)
    if wg is not None:
        up = L.mlp_act(_expert_mm(wg, xe), "silu") * up
    else:
        up = L.mlp_act(up, act)
    return _expert_mm(wd, up)


def _slot_factor(cfg: ModelConfig, n_shards: int) -> int:
    """EP×TP slot replication: when the expert count doesn't cover the TP
    axis (grok-1: 8 experts on 16 shards), each expert is split into
    r = n_shards // E slots along d_ff; slot outputs are partial sums that
    the combine step adds back (DESIGN.md §4). r=1 when E % n_shards == 0."""
    e = cfg.num_experts
    if e % n_shards == 0:
        return 1
    assert n_shards % e == 0 and cfg.d_ff % (n_shards // e) == 0, \
        f"experts={e} cannot tile TP axis {n_shards}"
    return n_shards // e


def _slot_weights(p, cfg: ModelConfig, r: int, rules: ShardingRules):
    """Re-layout (E, d, f) expert weights into (E·r, d, f/r) slots (and
    (E·r, f/r, d) for the down proj). Cheap under SPMD: source and target
    are both fully sharded, so the reshard moves ~1/n of the bytes."""
    wu, wd, wg = p["wu"], p["wd"], p.get("wg")
    if r == 1:
        return wg, wu, wd
    assert not any(isinstance(w, QTensor) for w in (wu, wd, wg)), \
        "EP×TP slot re-layout (r>1) reshapes raw weights; packed experts " \
        "take the masked-dense path (moe_apply guards this)"
    e, d, f = wu.shape
    fr = f // r
    def split_up(w):
        w2 = w.reshape(e, d, r, fr).transpose(0, 2, 1, 3).reshape(e * r, d, fr)
        return hint(w2, rules, ("tp", None, None))
    wu2 = split_up(wu)
    wg2 = split_up(wg) if wg is not None else None
    wd2 = hint(wd.reshape(e, r, fr, d).reshape(e * r, fr, d),
               rules, ("tp", None, None))
    return wg2, wu2, wd2


# ---------------------------------------------------------------------------
# Path 2/3: masked all-expert compute (decode / tiny / capture)
# ---------------------------------------------------------------------------

def moe_apply_dense(p, x, cfg: ModelConfig, rules: ShardingRules = NO_RULES,
                    *, capture: Optional[dict] = None) -> jax.Array:
    b, s, d = x.shape
    xn = L.rmsnorm(x, p["norm"], cfg.norm_eps)
    t = b * s
    xf = xn.reshape(t, d)
    gates, idx = _gates(xf, p["router"], cfg.experts_per_token)
    # dense gate matrix (T, E): gate if routed else 0
    ge = jnp.zeros((t, cfg.num_experts), jnp.float32)
    ge = ge.at[jnp.arange(t)[:, None], idx].set(gates)
    ge = hint(ge, rules, ("batch", None))
    # all-expert compute, gather-weighted (decode path: memory-bound, see
    # DESIGN.md §4 — every expert weight is read once regardless of routing;
    # QTensor expert leaves cut that read to ~4 bits/weight). Dense expert
    # weights are TP-sharded on E (many experts) or f (few experts,
    # moe_logical_axes); either way the einsums partition without re-layout.
    up = L.expert_apply(p["wu"], xf)
    if cfg.mlp_act == "silu":
        up = L.mlp_act(L.expert_apply(p["wg"], xf), "silu") * up
    else:
        up = L.mlp_act(up, cfg.mlp_act)
    if capture is not None:
        capture["moe_in"] = xf
        capture["moe_mask"] = ge > 0
        capture["moe_up"] = up          # (T, E, f) pre-down activations
    wd = p["wd"]
    if isinstance(wd, QTensor):         # packed experts: stacked dequant-matmul
        ye = L.expert_apply(wd, up.transpose(1, 0, 2),
                            per_expert=True)         # (E, T, d)
        y = jnp.einsum("etd,te->td", ye, ge.astype(ye.dtype))
    else:
        y = jnp.einsum("tef,efd,te->td", up, wd, ge.astype(up.dtype))
    return y.reshape(b, s, d).astype(x.dtype)


# ---------------------------------------------------------------------------
# Path 1: shard_map all-to-all expert parallelism (train / prefill)
# ---------------------------------------------------------------------------

def moe_apply_a2a(p, x, cfg: ModelConfig, rules: ShardingRules) -> jax.Array:
    """x: (B, S, d) sharded ("batch", "tp"-on-seq, None). Tokens are
    flattened LOCALLY inside shard_map (keeping the existing layout — no
    token resharding, which would otherwise force SPMD to replicate the
    17 GB backward cotangent); dispatch is all_to_all over 'model'."""
    mesh = rules.mesh
    assert mesh is not None and rules.tp_axis is not None
    tp = rules.tp_axis
    n_exp_shards = mesh.shape[tp]
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    r = _slot_factor(cfg, n_exp_shards)   # EP×TP slots (grok: 8e → r=2)
    e_eff = e * r
    e_loc = e_eff // n_exp_shards
    dp = rules.batch_axes
    b_loc = b // rules.axis_size(dp)
    s_loc = s // n_exp_shards
    t_loc = b_loc * s_loc
    cap = max(8, int(t_loc * k / e * cfg.capacity_factor))
    cap = -(-cap // 8) * 8                               # round up to 8

    from jax.sharding import PartitionSpec as P

    xn = L.rmsnorm(x, p["norm"], cfg.norm_eps)
    xn = hint(xn, rules, ("batch", "tp", None))
    wg_w, wu_w, wd_w = _slot_weights(p, cfg, r, rules)
    has_gate = wg_w is not None

    def local(x3, router_w, wu, wd, wg_):
        # x3: (b_loc, s_loc, d); wu/wd/wg_: (e_loc, ...) local expert slots
        xt = x3.reshape(t_loc, d)                        # local flatten
        gates, idx = _gates(xt, router_w, k)             # (t_loc, k)
        flat_e = idx.reshape(-1)                         # (t_loc·k,)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) * onehot - 1    # position within expert
        pos = pos.max(axis=-1)                           # (t_loc·k,)
        keep = pos < cap
        buf = jnp.zeros((e, cap, d), xt.dtype)
        tok_idx = jnp.repeat(jnp.arange(t_loc), k)
        buf = buf.at[flat_e, jnp.where(keep, pos, cap - 1)].add(
            jnp.where(keep[:, None], xt[tok_idx], 0.0))
        if r > 1:                                        # duplicate to slots
            buf = jnp.repeat(buf, r, axis=0)             # (e_eff, cap, d)
        # dispatch: (shards, e_loc, cap, d) → a2a over tp axis
        send = buf.reshape(n_exp_shards, e_loc, cap, d)
        recv = jax.lax.all_to_all(send, tp, split_axis=0, concat_axis=0,
                                  tiled=False)
        # recv: (n_exp_shards, e_loc, cap, d) — tokens from every source
        xe = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_exp_shards * cap, d)
        ye = _expert_ffn(xe, wg_ if has_gate else None, wu, wd,
                         cfg.mlp_act)                    # partial over slots
        back = ye.reshape(e_loc, n_exp_shards, cap, d).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(back, tp, split_axis=0, concat_axis=0,
                                 tiled=False)
        ret = ret.reshape(e_eff, cap, d)                 # source-layout buffers
        if r > 1:                                        # sum slot partials
            ret = ret.reshape(e, r, cap, d).sum(axis=1)
        # combine at source: gather each token's k expert outputs
        out_k = ret[flat_e, jnp.clip(pos, 0, cap - 1)]   # (t_loc·k, d)
        out_k = jnp.where(keep[:, None], out_k, 0.0)
        y = (out_k.reshape(t_loc, k, d) *
             gates[..., None].astype(out_k.dtype)).sum(axis=1)
        return y.reshape(b_loc, s_loc, d)

    x_spec = P(dp, tp, None)

    def ew_spec(w):
        """Expert-weight spec: experts sharded over the tp axis. Stacked
        QTensor leaves shard per child (every child leads with E; col_scale
        may be absent/lower-rank, hence per-leaf ranks)."""
        if isinstance(w, QTensor):
            return jax.tree.map(
                lambda a: P(tp, *([None] * (a.ndim - 1))), w)
        return P(tp, None, None)

    y = compat.shard_map(local, mesh=mesh,
                      in_specs=(x_spec, P(None, None), ew_spec(wu_w),
                                ew_spec(wd_w),
                                ew_spec(wg_w) if has_gate else P()),
                      out_specs=x_spec,
                      check_vma=False)(
        xn, p["router"], wu_w, wd_w,
        wg_w if has_gate else jnp.zeros((), xn.dtype))
    return y.astype(x.dtype)


def moe_apply(p, x, cfg: ModelConfig, rules: ShardingRules = NO_RULES, *,
              capture: Optional[dict] = None, prefer_a2a: bool = True) -> jax.Array:
    """Auto-select the execution path (DESIGN.md §4). Packed QTensor expert
    weights (any of wu/wd/wg) ride the a2a path whenever the expert count
    tiles the TP axis directly (slot factor r == 1 — the stacked
    dequant-matmul shards per expert like a dense stack); only the EP×TP
    slot re-layout (r > 1), which reshapes raw weight arrays, forces
    masked-dense for packed experts."""
    packed = any(isinstance(p.get(k), QTensor) for k in ("wu", "wd", "wg"))
    if rules.mesh is None or capture is not None or not prefer_a2a:
        return moe_apply_dense(p, x, cfg, rules, capture=capture)
    b, s, _ = x.shape
    tp = rules.axis_size(rules.tp_axis or ())
    dp = rules.axis_size(rules.batch_axes)
    e = cfg.num_experts
    tileable = (e % tp == 0) or (tp % e == 0 and cfg.d_ff % (tp // e) == 0)
    if packed:
        tileable = e % tp == 0          # packed codes can't slot-split (r>1)
    ok = (tp > 1 and tileable and b % dp == 0
          and s % tp == 0 and (b // dp) * (s // tp) >= 64)
    if ok:
        return moe_apply_a2a(p, x, cfg, rules)
    return moe_apply_dense(p, x, cfg, rules)


# ---------------------------------------------------------------------------
# MoE decoder model: DenseModel with the MLP swapped for the MoE FFN
# ---------------------------------------------------------------------------

import dataclasses

from repro.models import transformer as T


def moe_block_params(key, cfg: ModelConfig, dtype=jnp.float32):
    ka, km = jax.random.split(key)
    return {"attn": L.attn_params(ka, cfg, dtype),
            "moe": moe_params(km, cfg, dtype)}


def moe_block_apply(p, x, cfg, rules=NO_RULES, *, positions=None, capture=None,
                    kv_cache=None, cache_pos=None, attend_cache=False,
                    block_table=None, fused_decode=False, prefer_a2a=True,
                    attn_chunk: int = 1024, attn_p_dtype=jnp.float32):
    a, new_kv = L.attn_apply(p["attn"], x, cfg, rules, positions=positions,
                             capture=capture, kv_cache=kv_cache,
                             cache_pos=cache_pos, attend_cache=attend_cache,
                             block_table=block_table,
                             fused_decode=fused_decode,
                             attn_chunk=attn_chunk,
                             attn_p_dtype=attn_p_dtype)
    x = x + a
    x = x + moe_apply(p["moe"], x, cfg, rules, capture=capture,
                      prefer_a2a=prefer_a2a)
    return x, new_kv


@dataclasses.dataclass
class MoEModel(T.DenseModel):
    """Decoder LM with MoE FFN ([moe] family: qwen3-moe, grok-1)."""
    prefer_a2a: bool = True

    def init(self, key):
        cfg = self.cfg
        k_emb, k_blk, k_head = jax.random.split(key, 3)
        blocks = jax.vmap(lambda k: moe_block_params(k, cfg, self.param_dtype))(
            jax.random.split(k_blk, cfg.num_layers))
        params = {"embed": L.embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                        self.param_dtype),
                  "blocks": blocks,
                  "final_norm": jnp.ones((cfg.d_model,), self.param_dtype)}
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(k_head, cfg.d_model,
                                             cfg.padded_vocab, self.param_dtype)
        return params

    def param_logical_axes(self):
        ax = super().param_logical_axes()
        ax["blocks"] = {
            "attn": ax["blocks"]["attn"],
            "moe": moe_logical_axes(self.cfg),
        }
        return ax

    def _block_scan(self, params, h, positions):
        cfg, rules = self.cfg, self.rules
        prefer = self.prefer_a2a
        def body(carry, layer_p):
            y, _ = moe_block_apply(layer_p, carry, cfg, rules,
                                   positions=positions, prefer_a2a=prefer,
                                   attn_chunk=self.attn_chunk,
                                   attn_p_dtype=self.attn_p_dtype)
            return hint(y, rules, ("batch", "tp", None)), None  # seq-parallel carry
        if self.unroll:
            for i in range(cfg.num_layers):
                h, _ = body(h, self.block_slice(params, i))
            return h
        body_fn = jax.checkpoint(body) if self.remat else body
        h, _ = jax.lax.scan(body_fn, h, params["blocks"])
        return h

    def _cached_scan(self, params, h, cache, positions, *,
                     attend_cache: bool = False):
        cfg, rules = self.cfg, self.rules
        # prefill (many tokens) uses the a2a path; decode (1 token) the
        # masked-dense path (DESIGN.md §4 MoE path table)
        a2a_ok = self.prefer_a2a and positions.shape[1] > 1
        table = cache.get("table")      # paged layout (see DenseModel)
        def body(x, scanned):
            layer_p, kc, vc = scanned
            y, (kc2, vc2) = moe_block_apply(layer_p, x, cfg, rules,
                                            positions=positions,
                                            kv_cache=(kc, vc),
                                            cache_pos=cache["pos"],
                                            attend_cache=attend_cache,
                                            block_table=table,
                                            fused_decode=self.use_fused_decode,
                                            prefer_a2a=a2a_ok,
                                            attn_chunk=self.attn_chunk,
                                            attn_p_dtype=self.attn_p_dtype)
            return y, (kc2, vc2)
        if self.unroll:
            kvs = []
            for i in range(cfg.num_layers):
                layer_kv = jax.tree.map(lambda x: x[i],
                                        (cache["k"], cache["v"]))
                h, kv2 = body(h, (self.block_slice(params, i),) + layer_kv)
                kvs.append(kv2)
            k_new, v_new = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
        else:
            h, (k_new, v_new) = jax.lax.scan(
                body, h, (params["blocks"], cache["k"], cache["v"]))
        out = {"k": k_new, "v": v_new,
               "pos": cache["pos"] + positions.shape[1]}
        if table is not None:
            out["table"] = table
        return h, out

    def block_apply_one(self, params, i, h, *, capture=False):
        cfg = self.cfg
        bp = self.block_slice(params, i)
        cap = {} if capture else None
        b, s = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        out, _ = moe_block_apply(bp, h, cfg, self.rules, positions=positions,
                                 capture=cap, prefer_a2a=False)
        return out, (cap or {})

    def block_linears(self, i):
        specs = [
            ("wq", ("blocks", "attn", "wq"), "attn_in"),
            ("wk", ("blocks", "attn", "wk"), "attn_in"),
            ("wv", ("blocks", "attn", "wv"), "attn_in"),
            ("wo", ("blocks", "attn", "wo"), "attn_out_in"),
        ]
        for e in range(self.cfg.num_experts):
            if self.cfg.mlp_act == "silu":
                specs.append((f"moe_wg_{e}", ("blocks", "moe", "wg", e), "moe"))
            specs.append((f"moe_wu_{e}", ("blocks", "moe", "wu", e), "moe"))
            specs.append((f"moe_wd_{e}", ("blocks", "moe", "wd", e), "moe_down"))
        return specs


__all__ = ["moe_params", "moe_logical_axes", "moe_apply", "moe_apply_dense",
           "moe_apply_a2a", "MoEModel", "moe_block_params", "moe_block_apply"]
