"""Version-compatibility shims for jax APIs that moved between releases.

The codebase targets current jax (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``); older releases (≤ 0.4.x) spell these
``jax.experimental.shard_map.shard_map`` / ``with mesh:`` and have no axis
types. Everything mesh/SPMD-shaped goes through here so the rest of the
code reads as modern jax.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:                                    # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


def set_mesh(mesh):
    """Context manager making ``mesh`` current for jit/shard_map."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh                          # Mesh is a context manager itself


__all__ = ["shard_map", "set_mesh"]
