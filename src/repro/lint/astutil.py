"""Shared AST machinery for the ``repro.lint`` analyzer.

Everything rule modules need to reason about a source file without
re-walking it from scratch:

* a parsed module with parent links and per-function qualified names;
* an import table canonicalizing aliases (``np`` → ``numpy``, ``pl`` →
  ``jax.experimental.pallas``) so rules match on canonical dotted names;
* detection of *traced* functions — defs wrapped by ``jax.jit`` (call or
  decorator form), ``pmap``/``vmap``/``scan``/``checkpoint`` bodies, and
  Pallas kernel callables handed to ``pl.pallas_call``;
* an intra-module call graph (``self.m()`` → ``Class.m``, bare ``f()`` →
  module function) plus reachability, which is how the host-sync rule
  expands the declared hot-path roots into the full hot set;
* ``# lint: ...`` control comments: ``allow[rule]`` suppressions and
  ``hotpath`` markers (see docs/static-analysis.md).

All of it is plain ``ast`` — no third-party dependencies, by design.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

# ``# lint: allow[host-sync, dtype-drift] reason`` / ``# lint: hotpath``
_LINT_COMMENT = re.compile(
    r"#\s*lint:\s*(?P<verb>allow|hotpath)(?:\[(?P<rules>[^\]]*)\])?")

# Wrappers whose callee body runs under a jax trace. The first group
# compiles a program (a host sync inside is a bug); the second stages into
# an enclosing trace (a tracer leak inside is a bug either way).
JIT_WRAPPERS = ("jax.jit", "jax.pmap", "jax.experimental.pallas.pallas_call")
TRACE_WRAPPERS = JIT_WRAPPERS + (
    "jax.vmap", "jax.checkpoint", "jax.remat", "jax.grad", "jax.value_and_grad",
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.experimental.shard_map.shard_map",
)


@dataclasses.dataclass
class FunctionInfo:
    node: ast.AST                   # FunctionDef | AsyncFunctionDef | Lambda
    name: str
    qualname: str                   # "Engine.step", "_make_step_fns.decode_fn"
    cls: Optional[str]              # enclosing class name, if a method
    jitted: bool = False            # wrapped by a compiling wrapper
    traced: bool = False            # body runs under some jax trace
    hotpath_marker: bool = False    # ``# lint: hotpath`` on the def line
    calls: Set[str] = dataclasses.field(default_factory=set)
    # params declared static in the jit wrapper — Python values under the
    # trace, so branching on them is fine
    static_params: Set[str] = dataclasses.field(default_factory=set)


class Module:
    """One parsed source file plus the derived tables rules consume."""

    def __init__(self, path: str, relpath: str, text: str):
        self.path = path
        self.relpath = relpath
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        self.imports = _import_table(self.tree)
        self.allow: Dict[int, Set[str]] = {}     # line → suppressed rules
        self.hotpath_lines: Set[int] = set()
        self._scan_comments()
        self.functions: List[FunctionInfo] = []
        self._fn_of_node: Dict[ast.AST, FunctionInfo] = {}
        self._collect_functions()
        self._mark_traced()
        self._build_call_graph()

    # -- comments ----------------------------------------------------------
    def _scan_comments(self) -> None:
        for i, line in enumerate(self.lines, start=1):
            m = _LINT_COMMENT.search(line)
            if not m:
                continue
            if m.group("verb") == "hotpath":
                self.hotpath_lines.add(i)
                continue
            rules = m.group("rules")
            names = ({r.strip() for r in rules.split(",") if r.strip()}
                     if rules else {"*"})
            self.allow.setdefault(i, set()).update(names)

    def suppressed(self, line: int, rule: str) -> bool:
        """``# lint: allow[rule]`` on the finding line or the line above."""
        for ln in (line, line - 1):
            rules = self.allow.get(ln)
            if rules and ("*" in rules or rule in rules):
                return True
        return False

    # -- names -------------------------------------------------------------
    def dotted(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name of an expression (``np.asarray`` →
        ``numpy.asarray``; ``self.foo`` stays ``self.foo``); None when the
        expression is not a plain name chain."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        parts.reverse()
        head = self.imports.get(parts[0], parts[0])
        return ".".join([head] + parts[1:])

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def enclosing_function(self, node: ast.AST) -> Optional[FunctionInfo]:
        for anc in self.ancestors(node):
            fn = self._fn_of_node.get(anc)
            if fn is not None:
                return fn
        return None

    # -- functions ---------------------------------------------------------
    def _collect_functions(self) -> None:
        def visit(node: ast.AST, stack: Tuple[str, ...], cls: Optional[str]):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = ".".join(stack + (child.name,))
                    info = FunctionInfo(
                        node=child, name=child.name, qualname=qual, cls=cls,
                        hotpath_marker=any(
                            ln in self.hotpath_lines
                            for ln in range(child.lineno,
                                            child.body[0].lineno + 1)))
                    self.functions.append(info)
                    self._fn_of_node[child] = info
                    visit(child, stack + (child.name,), cls)
                elif isinstance(child, ast.ClassDef):
                    visit(child, stack + (child.name,), child.name)
                else:
                    visit(child, stack, cls)
        visit(self.tree, (), None)

    def by_name(self, name: str) -> List[FunctionInfo]:
        return [f for f in self.functions if f.name == name]

    def by_qualname(self, qualname: str) -> List[FunctionInfo]:
        return [f for f in self.functions if f.qualname == qualname]

    # -- traced / jitted detection -----------------------------------------
    def _wrapper_kind(self, call: ast.Call) -> Optional[str]:
        """'jit' | 'trace' when ``call`` is a known jax wrapper (including
        ``functools.partial(jax.jit, ...)``)."""
        name = self.dotted(call.func)
        if name == "functools.partial" and call.args:
            name = self.dotted(call.args[0])
        if name is None:
            return None
        short = name.rsplit(".", 1)[-1]
        for full in JIT_WRAPPERS:
            if name == full or short == full.rsplit(".", 1)[-1]:
                return "jit"
        for full in TRACE_WRAPPERS:
            if name == full or short == full.rsplit(".", 1)[-1]:
                return "trace"
        return None

    def _static_names(self, call: ast.Call) -> Set[str]:
        """static_argnames string literals declared on a jit wrapper call
        (direct or via functools.partial)."""
        out: Set[str] = set()
        for kw in call.keywords:
            if kw.arg != "static_argnames":
                continue
            elts = (list(kw.value.elts)
                    if isinstance(kw.value, (ast.Tuple, ast.List))
                    else [kw.value])
            for e in elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    out.add(e.value)
        return out

    def _mark_traced(self) -> None:
        wrapped: Dict[str, str] = {}      # function name → 'jit' | 'trace'
        statics: Dict[str, Set[str]] = {}

        def note(name: str, kind: str, static: Set[str]) -> None:
            if kind == "jit" or wrapped.get(name) != "jit":
                wrapped[name] = kind
            statics.setdefault(name, set()).update(static)

        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                kind = self._wrapper_kind(node)
                if kind is not None:
                    for arg in node.args:
                        if isinstance(arg, ast.Name):
                            note(arg.id, kind, self._static_names(node))
        for fn in self.functions:
            for deco in getattr(fn.node, "decorator_list", ()):
                kind = None
                static: Set[str] = set()
                if isinstance(deco, ast.Call):
                    kind = self._wrapper_kind(deco)
                    static = self._static_names(deco)
                else:
                    name = self.dotted(deco)
                    if name is not None:
                        probe = ast.Call(func=deco, args=[], keywords=[])
                        kind = self._wrapper_kind(probe)
                if kind is not None:
                    note(fn.name, kind, static)
        for fn in self.functions:
            kind = wrapped.get(fn.name)
            if kind is not None:
                fn.traced = True
                fn.jitted = kind == "jit"
                fn.static_params |= statics.get(fn.name, set())
        # nested defs inside a traced body are traced too
        changed = True
        while changed:
            changed = False
            for fn in self.functions:
                if fn.traced:
                    continue
                outer = self.enclosing_function(fn.node)
                if outer is not None and outer.traced:
                    fn.traced = True
                    fn.jitted = outer.jitted
                    changed = True

    # -- call graph --------------------------------------------------------
    def _build_call_graph(self) -> None:
        for fn in self.functions:
            for node in ast.walk(fn.node):
                if not isinstance(node, ast.Call):
                    continue
                if self.enclosing_function(node) is not fn and not any(
                        self._fn_of_node.get(a) is fn
                        for a in self.ancestors(node)):
                    continue
                name = self.dotted(node.func)
                if name is None:
                    continue
                if name.startswith("self.") and fn.cls is not None:
                    fn.calls.add(f"{fn.cls}.{name[len('self.'):]}")
                elif "." not in name:
                    fn.calls.add(name)

    def reachable(self, roots: Iterable[str]) -> Set[str]:
        """Qualnames reachable from ``roots`` over the intra-module call
        graph. Roots may be ``Class.method`` or bare function names; bare
        callee names resolve to any same-named function in the module."""
        by_key: Dict[str, List[FunctionInfo]] = {}
        for fn in self.functions:
            by_key.setdefault(fn.name, []).append(fn)
            key = f"{fn.cls}.{fn.name}" if fn.cls else fn.qualname
            by_key.setdefault(key, []).append(fn)
            by_key.setdefault(fn.qualname, []).append(fn)
        seen: Set[str] = set()
        stack = [r for r in roots if r in by_key]
        while stack:
            key = stack.pop()
            for fn in by_key.get(key, ()):
                if fn.qualname in seen:
                    continue
                seen.add(fn.qualname)
                stack.extend(c for c in fn.calls if c in by_key)
        return seen


def _import_table(tree: ast.Module) -> Dict[str, str]:
    table: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                table[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                table[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}" if node.module
                    else alias.name)
    return table


def const_int(node: ast.AST) -> Optional[int]:
    """The int value of a constant expression node, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = const_int(node.operand)
        return -inner if inner is not None else None
    return None


def literal_tuple(node: ast.AST) -> Optional[List[ast.AST]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return None


def call_kwarg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def is_shapelike(node: ast.AST) -> bool:
    """True for expressions that are static under a jax trace even when
    their base value is traced: ``x.shape``, ``x.ndim``, ``x.dtype``,
    ``len(x)``, ``x.shape[i]``."""
    if isinstance(node, ast.Attribute) and node.attr in (
            "shape", "ndim", "dtype", "size"):
        return True
    if isinstance(node, ast.Subscript):
        return is_shapelike(node.value)
    if isinstance(node, ast.Call):
        name = None
        if isinstance(node.func, ast.Name):
            name = node.func.id
        return name in ("len", "getattr")
    return False


__all__ = ["Module", "FunctionInfo", "const_int", "literal_tuple",
           "call_kwarg", "is_shapelike", "JIT_WRAPPERS", "TRACE_WRAPPERS"]
