"""Rule registry, findings, config, baseline, and the lint driver.

The registry mirrors ``repro.core.registry``: rules are plain functions
behind a ``@register(rule_id, ...)`` decorator, loaded on first use by an
idempotent ``_load_builtins()``. Two rule scopes exist:

* ``module`` rules run once per parsed file and receive a
  :class:`ModuleContext`;
* ``project`` rules run once per lint invocation with a
  :class:`ProjectContext` holding every parsed module (the contract lints
  need the whole tree to cross-check metric names against the schema).

Findings carry a content *fingerprint* — ``sha1(rule|path|symbol|message)``
— so the checked-in baseline survives unrelated line drift; moving or
editing the offending code invalidates its baseline entry, which is the
point.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import hashlib
import importlib
import json
import os
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .astutil import Module

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str                       # repo-relative, forward slashes
    line: int
    col: int
    severity: str
    message: str
    symbol: str = ""                # enclosing function qualname, if any

    @property
    def fingerprint(self) -> str:
        blob = f"{self.rule}|{self.path}|{self.symbol}|{self.message}"
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.severity} {self.rule}: {self.message}{sym}")


@dataclasses.dataclass
class LintConfig:
    """What to scan and how strictly. All paths/globs are repo-relative."""
    # NB: fnmatch has no special '**' — a bare '*' already crosses '/'
    # in its regex translation, so 'src/repro/*.py' alone would match
    # the whole tree; keep both spellings for readability.
    include: Tuple[str, ...] = ("src/repro/*.py", "src/repro/**/*.py")
    exclude: Tuple[str, ...] = ()
    # Hot-path roots for host-sync: ``Class.method`` or bare function names,
    # expanded per-module via the intra-module call graph. ``# lint:
    # hotpath`` markers add roots without touching the config.
    hotpath_roots: Tuple[str, ...] = (
        "Engine.step", "Engine._decode", "Engine._run_decode")
    # ``self.*`` attributes known to hold device arrays (jitted step-fn
    # handles): calling them taints the result for the host-sync dataflow.
    device_producers: Tuple[str, ...] = (
        "self._prefill", "self._chunk", "self._decode")
    # Files where bf16/f16 flows through and silent f32 promotion matters.
    dtype_sensitive: Tuple[str, ...] = (
        "src/repro/models/layers.py", "src/repro/serving/kv_cache.py",
        "src/repro/kernels/*.py")
    kernel_globs: Tuple[str, ...] = ("src/repro/kernels/*.py",)
    metrics_schema: str = "scripts/metrics_schema.json"
    # (path glob, rule id or '*') → severity override; first match wins.
    severity_overrides: Tuple[Tuple[str, str, str], ...] = ()
    disabled_rules: Tuple[str, ...] = ()

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "LintConfig":
        kwargs = {}
        for f in dataclasses.fields(cls):
            if f.name in d:
                v = d[f.name]
                if f.name == "severity_overrides":
                    v = tuple(tuple(row) for row in v)  # type: ignore
                elif isinstance(v, list):
                    v = tuple(v)
                kwargs[f.name] = v
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown lint config keys: {sorted(unknown)}")
        return cls(**kwargs)  # type: ignore[arg-type]

    def severity_for(self, rule_id: str, path: str, default: str) -> str:
        for glob, rule, sev in self.severity_overrides:
            if rule in ("*", rule_id) and fnmatch.fnmatch(path, glob):
                if sev not in SEVERITIES + ("off",):
                    raise ValueError(f"bad severity override: {sev!r}")
                return sev
        return default


class ModuleContext:
    """Per-file context handed to ``scope='module'`` rules."""

    def __init__(self, module: Module, config: LintConfig):
        self.module = module
        self.config = config
        self._findings: List[Finding] = []
        self._rule: Optional["_Entry"] = None

    def report(self, node, message: str, *, severity: Optional[str] = None,
               symbol: Optional[str] = None) -> None:
        assert self._rule is not None
        line = getattr(node, "lineno", 0)
        col = getattr(node, "col_offset", 0)
        if self.module.suppressed(line, self._rule.rule_id):
            return
        if symbol is None:
            fn = self.module.enclosing_function(node) if node is not None \
                else None
            symbol = fn.qualname if fn is not None else ""
        sev = self.config.severity_for(
            self._rule.rule_id, self.module.relpath,
            severity or self._rule.severity)
        if sev == "off":
            return
        self._findings.append(Finding(
            rule=self._rule.rule_id, path=self.module.relpath, line=line,
            col=col, severity=sev, message=message, symbol=symbol))


class ProjectContext:
    """Whole-tree context handed to ``scope='project'`` rules."""

    def __init__(self, modules: Sequence[Module], config: LintConfig,
                 root: str):
        self.modules = list(modules)
        self.config = config
        self.root = root
        self._findings: List[Finding] = []
        self._rule: Optional["_Entry"] = None

    def module(self, relpath: str) -> Optional[Module]:
        for m in self.modules:
            if m.relpath == relpath:
                return m
        return None

    def report(self, path: str, line: int, message: str, *,
               severity: Optional[str] = None, symbol: str = "") -> None:
        assert self._rule is not None
        mod = self.module(path)
        if mod is not None and mod.suppressed(line, self._rule.rule_id):
            return
        sev = self.config.severity_for(
            self._rule.rule_id, path, severity or self._rule.severity)
        if sev == "off":
            return
        self._findings.append(Finding(
            rule=self._rule.rule_id, path=path, line=line, col=0,
            severity=sev, message=message, symbol=symbol))


@dataclasses.dataclass
class _Entry:
    rule_id: str
    fn: Callable
    severity: str
    scope: str                      # 'module' | 'project'
    help: str


_REGISTRY: Dict[str, _Entry] = {}
_BUILTINS_LOADED = False


def register(rule_id: str, *, severity: str = "error",
             scope: str = "module", help: str = "") -> Callable:
    """Decorator registering a lint rule, mirroring
    ``repro.core.registry.register``."""
    if severity not in SEVERITIES:
        raise ValueError(f"severity must be one of {SEVERITIES}")
    if scope not in ("module", "project"):
        raise ValueError("scope must be 'module' or 'project'")

    def deco(fn: Callable) -> Callable:
        if rule_id in _REGISTRY and _REGISTRY[rule_id].fn is not fn:
            raise ValueError(f"lint rule {rule_id!r} already registered")
        _REGISTRY[rule_id] = _Entry(rule_id, fn, severity, scope, help)
        return fn

    return deco


def _load_builtins() -> None:
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    importlib.import_module("repro.lint.rules")


def available() -> List[str]:
    _load_builtins()
    return sorted(_REGISTRY)


def rule_entries() -> List[_Entry]:
    _load_builtins()
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


# -- file discovery --------------------------------------------------------

def discover(root: str, config: LintConfig,
             paths: Optional[Sequence[str]] = None) -> List[str]:
    """Repo-relative paths to lint. Explicit ``paths`` bypass the include
    globs (so the CLI can lint one file) but still honor excludes."""
    rels: List[str] = []
    if paths:
        for p in paths:
            rel = os.path.relpath(os.path.abspath(p), root).replace(
                os.sep, "/")
            if os.path.isdir(os.path.join(root, rel)):
                for dirpath, _dirnames, filenames in os.walk(
                        os.path.join(root, rel)):
                    for fname in sorted(filenames):
                        if fname.endswith(".py"):
                            sub = os.path.relpath(
                                os.path.join(dirpath, fname), root)
                            rels.append(sub.replace(os.sep, "/"))
            else:
                rels.append(rel)
    else:
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in sorted(dirnames)
                           if d not in (".git", "__pycache__", ".venv")]
            for fname in sorted(filenames):
                if not fname.endswith(".py"):
                    continue
                rel = os.path.relpath(os.path.join(dirpath, fname), root)
                rel = rel.replace(os.sep, "/")
                if any(fnmatch.fnmatch(rel, g) for g in config.include):
                    rels.append(rel)
    rels = [r for r in dict.fromkeys(rels)
            if not any(fnmatch.fnmatch(r, g) for g in config.exclude)]
    return rels


# -- driver ----------------------------------------------------------------

def run_lint(root: str, config: Optional[LintConfig] = None,
             paths: Optional[Sequence[str]] = None,
             rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Parse the tree and run every enabled rule; returns all findings
    sorted by (path, line, rule)."""
    _load_builtins()
    config = config or LintConfig()
    selected = set(rules) if rules is not None else None
    modules: List[Module] = []
    findings: List[Finding] = []
    for rel in discover(root, config, paths):
        full = os.path.join(root, rel)
        try:
            with open(full, "r", encoding="utf-8") as fh:
                text = fh.read()
            modules.append(Module(full, rel, text))
        except SyntaxError as exc:
            findings.append(Finding(
                rule="parse-error", path=rel, line=exc.lineno or 0,
                col=exc.offset or 0, severity="error",
                message=f"syntax error: {exc.msg}"))
    for entry in rule_entries():
        if entry.rule_id in config.disabled_rules:
            continue
        if selected is not None and entry.rule_id not in selected:
            continue
        if entry.scope == "module":
            for mod in modules:
                ctx = ModuleContext(mod, config)
                ctx._rule = entry
                entry.fn(ctx)
                findings.extend(ctx._findings)
        else:
            ctx = ProjectContext(modules, config, root)
            ctx._rule = entry
            entry.fn(ctx)
            findings.extend(ctx._findings)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


# -- baseline --------------------------------------------------------------

def load_baseline(path: str) -> Dict[str, Dict[str, object]]:
    """fingerprint → baseline entry. Missing file = empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    out: Dict[str, Dict[str, object]] = {}
    for entry in data.get("findings", []):
        out[str(entry["fingerprint"])] = entry
    return out


def save_baseline(path: str, findings: Iterable[Finding]) -> None:
    entries = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        d = f.to_dict()
        entries.append({k: d[k] for k in (
            "fingerprint", "rule", "path", "line", "severity", "message",
            "symbol")})
    payload = {
        "comment": ("Grandfathered lint findings. Entries are matched by "
                    "content fingerprint (rule|path|symbol|message), not "
                    "line number; fix the code and rerun with "
                    "--update-baseline to retire one."),
        "findings": entries,
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2, sort_keys=False)
        fh.write("\n")


def partition(findings: Sequence[Finding],
              baseline: Dict[str, Dict[str, object]]
              ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """(new, baselined, stale-fingerprints) split of a lint run."""
    new: List[Finding] = []
    old: List[Finding] = []
    seen = set()
    for f in findings:
        if f.fingerprint in baseline:
            old.append(f)
            seen.add(f.fingerprint)
        else:
            new.append(f)
    stale = [fp for fp in baseline if fp not in seen]
    return new, old, stale
