"""pallas-tiling: TPU tile-shape and grid/index_map arity constraints.

From the Mosaic lowering rules (see the Pallas guide): the last dimension
of a VMEM tile must be a multiple of 128, and the second-to-last a
multiple of the per-dtype sublane count — 8 for f32, 16 for bf16, 32 for
int8/fp8 — unless it is exactly 1 (a degenerate row tile). Each
``BlockSpec`` index_map must accept one argument per grid axis plus one
per scalar-prefetch operand. Violations lower to mosaic errors (on TPU)
or silent relayouts — neither shows up in interpret-mode CI.

Only constant shapes are checked; dims held in variables (the dominant
idiom in ``kernels/decode_attn.py``) are resolved one assignment deep
when the module binds them to literal ints, otherwise skipped. Arity
checks resolve ``grid=(b, nt)`` through tuple-literal assignments and
skip ambiguous (multiply-assigned) names.
"""
from __future__ import annotations

import ast
from fnmatch import fnmatch
from typing import Dict, List, Optional

from ..astutil import call_kwarg, const_int, literal_tuple
from ..core import ModuleContext, register

_SUBLANE = {"float32": 8, "bfloat16": 16, "float16": 16,
            "int8": 32, "uint8": 32, "float8_e4m3fn": 32}


def _int_bindings(mod) -> Dict[str, List[Optional[int]]]:
    """name → every int it is bound to at module/function level (None for
    non-constant bindings). A name is resolvable iff all bindings agree."""
    out: Dict[str, List[Optional[int]]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out.setdefault(tgt.id, []).append(const_int(node.value))
    return out


def _resolve_int(mod, bindings, node: ast.AST) -> Optional[int]:
    v = const_int(node)
    if v is not None:
        return v
    if isinstance(node, ast.Name):
        vals = bindings.get(node.id, [])
        consts = {v for v in vals}
        if len(vals) >= 1 and len(consts) == 1 and None not in consts:
            return vals[0]
    return None


def _resolve_tuple(mod, node: ast.AST) -> Optional[List[ast.AST]]:
    """A tuple literal, following one Name assignment if unambiguous."""
    elts = literal_tuple(node)
    if elts is not None:
        return elts
    if isinstance(node, ast.Name):
        found: List[List[ast.AST]] = []
        for n in ast.walk(mod.tree):
            if isinstance(n, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == node.id
                    for t in n.targets):
                e = literal_tuple(n.value)
                if e is None:
                    return None          # bound to something opaque
                found.append(e)
        if len(found) == 1:
            return found[0]
        if found and all(len(f) == len(found[0]) for f in found):
            return found[0]              # arity agrees across branches
    return None


def _lambda_arity(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Lambda):
        a = node.args
        if a.vararg is not None:
            return None
        return len(a.posonlyargs) + len(a.args)
    return None


def _dtype_of(mod, node: Optional[ast.AST]) -> Optional[str]:
    if node is None:
        return None
    name = mod.dotted(node)
    if name is None:
        return None
    leaf = name.rsplit(".", 1)[-1]
    return leaf if leaf in _SUBLANE else None


def _check_tile(ctx, call_node, dims: List[ast.AST], bindings,
                dtype: Optional[str], what: str) -> None:
    mod = ctx.module
    if not dims:
        return
    last = _resolve_int(mod, bindings, dims[-1])
    if last is not None and last % 128 != 0 and last != 1:
        ctx.report(call_node, (
            f"{what} last dim {last} is not a multiple of 128 (TPU lane "
            "width) — Mosaic will reject or relayout this tile"))
    if len(dims) >= 2:
        sub = _resolve_int(mod, bindings, dims[-2])
        need = _SUBLANE.get(dtype or "float32", 8)
        if sub is not None and sub != 1 and sub % need != 0:
            dt = dtype or "float32 (assumed)"
            ctx.report(call_node, (
                f"{what} second-to-last dim {sub} is not a multiple of "
                f"{need} (sublane count for {dt})"), severity="warning")


@register("pallas-tiling", severity="error", help=(
    "BlockSpec/scratch tiles must be (sublane, 128)-aligned per dtype and "
    "index_map arity must equal len(grid) + num scalar-prefetch args."))
def check_pallas(ctx: ModuleContext) -> None:
    mod = ctx.module
    if not any(fnmatch(mod.relpath, g) for g in ctx.config.kernel_globs):
        return
    bindings = _int_bindings(mod)

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = mod.dotted(node.func)
        if name is None:
            continue
        leaf = name.rsplit(".", 1)[-1]

        # ---- BlockSpec tile shape + index_map arity ----------------------
        if leaf == "BlockSpec":
            dims = None
            if node.args:
                dims = literal_tuple(node.args[0])
            if dims is None:
                shp = call_kwarg(node, "block_shape")
                if shp is not None:
                    dims = literal_tuple(shp)
            if dims:
                _check_tile(ctx, node, dims, bindings, None, "BlockSpec")

        # ---- VMEM/SMEM scratch shapes -----------------------------------
        if leaf in ("VMEM", "SMEM") and node.args:
            dims = literal_tuple(node.args[0])
            if dims:
                dtype = _dtype_of(
                    mod, node.args[1] if len(node.args) > 1 else
                    call_kwarg(node, "dtype"))
                _check_tile(ctx, node, dims, bindings, dtype,
                            f"{leaf} scratch")

        # ---- pallas_call grid / index_map arity -------------------------
        if leaf == "pallas_call":
            grid_node = call_kwarg(node, "grid")
            grid_spec = call_kwarg(node, "grid_spec")
            n_prefetch: Optional[int] = 0
            if grid_spec is not None and isinstance(grid_spec, ast.Call):
                gleaf = (mod.dotted(grid_spec.func) or "").rsplit(".", 1)[-1]
                if gleaf == "PrefetchScalarGridSpec":
                    grid_node = call_kwarg(grid_spec, "grid")
                    pre = call_kwarg(grid_spec, "num_scalar_prefetch")
                    n_prefetch = _resolve_int(mod, bindings, pre) \
                        if pre is not None else 0
                else:
                    grid_node = grid_node or call_kwarg(grid_spec, "grid")
            if grid_node is None or n_prefetch is None:
                continue
            grid_elts = _resolve_tuple(mod, grid_node)
            if grid_elts is None:
                continue
            want = len(grid_elts) + n_prefetch

            specs_holder = grid_spec if grid_spec is not None else node
            for key in ("in_specs", "out_specs"):
                val = call_kwarg(specs_holder, key)
                if val is None:
                    continue
                spec_elts = literal_tuple(val) or [val]
                for spec in spec_elts:
                    if not isinstance(spec, ast.Call):
                        continue
                    sleaf = (mod.dotted(spec.func) or "").rsplit(".", 1)[-1]
                    if sleaf != "BlockSpec":
                        continue
                    imap = None
                    for arg in list(spec.args) + [
                            kw.value for kw in spec.keywords
                            if kw.arg == "index_map"]:
                        if _lambda_arity(arg) is not None:
                            imap = arg
                            break
                    if imap is None:
                        continue
                    arity = _lambda_arity(imap)
                    if arity is not None and arity != want:
                        ctx.report(spec, (
                            f"index_map takes {arity} args but grid has "
                            f"{len(grid_elts)} axes + {n_prefetch} scalar-"
                            f"prefetch operands (= {want})"))
