"""Contract lints: registry adapters and the metric-name schema.

``register-contract`` (module scope): every function decorated with
``repro.core.registry.register`` must return a ``CompressResult`` on every
return path — that is the whole integration contract (`docs/api.md`), and
a stray bare ``theta`` return only fails much later inside
``compress_model``'s artifact handling. One level of local-helper
indirection is resolved (``awp.py``'s ``_prune_result``).

``metrics-contract`` (project scope): every metric family registered in
``src/repro`` must be listed in ``scripts/metrics_schema.json``'s
``families`` key and vice versa, via the shared extractor in
``repro.lint.contracts`` (also used by ``check_metrics_schema.py``).
"""
from __future__ import annotations

import ast
import os
from fnmatch import fnmatch
from typing import Optional

from ..contracts import KINDS, extract_metric_uses, load_schema_families
from ..core import ModuleContext, ProjectContext, register


def _is_registry_register(mod, deco: ast.AST) -> bool:
    if not isinstance(deco, ast.Call):
        return False
    name = mod.dotted(deco.func)
    if name is None:
        return False
    parts = name.split(".")
    return parts[-1] == "register" and len(parts) >= 2 and \
        parts[-2].lstrip("_").endswith("registry")


def _returns_compress_result(mod, fn_node: ast.AST, depth: int = 0
                             ) -> Optional[ast.Return]:
    """The first return statement that does NOT resolve to a
    CompressResult construction, or None when all paths comply."""

    def resolves(expr: ast.AST) -> bool:
        if isinstance(expr, ast.Call):
            name = mod.dotted(expr.func)
            if name is not None and name.rsplit(".", 1)[-1] == \
                    "CompressResult":
                return True
            # one level of local helper indirection
            if name is not None and "." not in name and depth < 2:
                for helper in mod.by_name(name):
                    if helper.cls is None and _returns_compress_result(
                            mod, helper.node, depth + 1) is None:
                        return True
            return False
        if isinstance(expr, ast.Name):
            # name assigned from a CompressResult call earlier in the body
            for n in ast.walk(fn_node):
                if isinstance(n, ast.Assign) and any(
                        isinstance(t, ast.Name) and t.id == expr.id
                        for t in n.targets) and resolves(n.value):
                    return True
            return False
        if isinstance(expr, ast.IfExp):
            return resolves(expr.body) and resolves(expr.orelse)
        return False

    fn = mod.enclosing_function(fn_node)
    for node in ast.walk(fn_node):
        if not isinstance(node, ast.Return) or node.value is None:
            continue
        owner = mod.enclosing_function(node)
        if owner is not None and owner.node is not fn_node:
            continue
        if not resolves(node.value):
            return node
    return None


@register("register-contract", severity="error", help=(
    "@registry.register adapters must return CompressResult on every "
    "path; anything else breaks compress_model's artifact handling."))
def check_register_contract(ctx: ModuleContext) -> None:
    mod = ctx.module
    for fn in mod.functions:
        decos = getattr(fn.node, "decorator_list", ())
        if not any(_is_registry_register(mod, d) for d in decos):
            continue
        bad = _returns_compress_result(mod, fn.node)
        if bad is not None:
            ctx.report(bad, (
                f"@register adapter {fn.name!r} has a return path that is "
                "not a CompressResult — wrap it: "
                "registry.CompressResult(theta=...)"), symbol=fn.qualname)


@register("metrics-contract", severity="error", scope="project", help=(
    "Metric family names in code and scripts/metrics_schema.json "
    "'families' must match bidirectionally."))
def check_metrics_contract(ctx: ProjectContext) -> None:
    schema_rel = ctx.config.metrics_schema
    schema_path = os.path.join(ctx.root, schema_rel)
    if not os.path.exists(schema_path):
        ctx.report(schema_rel, 1, f"metrics schema not found: {schema_rel}")
        return
    try:
        families = load_schema_families(schema_path)
    except ValueError as exc:
        ctx.report(schema_rel, 1, str(exc))
        return

    uses = []
    for mod in ctx.modules:
        uses.extend(extract_metric_uses(mod))

    # code → schema
    for use in uses:
        names = families.get(use.kind, [])
        if use.exact:
            ok = use.name in names
        else:
            ok = any(fnmatch(n, use.name) for n in names)
        if not ok:
            what = "name" if use.exact else "pattern"
            ctx.report(use.path, use.line, (
                f"metric {what} {use.name!r} ({use.kind}) is not listed "
                f"in {schema_rel} families.{use.kind}"))

    # schema → code
    for kind in KINDS:
        declared = families.get(kind, [])
        for name in declared:
            hit = any(
                u.kind == kind and (
                    u.name == name if u.exact else fnmatch(name, u.name))
                for u in uses)
            if not hit:
                ctx.report(schema_rel, 1, (
                    f"families.{kind} lists {name!r} but no code in the "
                    "scanned tree registers it"))
