"""recompile-hazard: patterns that defeat jax.jit's compilation cache.

Three concrete hazards from this codebase's history:

* a ``jax.jit`` / ``pl.pallas_call`` constructed lexically inside a
  ``for``/``while`` body builds a fresh callable (and cache) every
  iteration — hoist it (the engine builds step fns once in
  ``_make_step_fns`` for exactly this reason);
* unhashable literals (list/dict/set) passed to a parameter declared in
  ``static_argnames`` raise at call time — or, when wrapped in tuples
  per call site, silently key a new cache entry per call;
* array shapes derived from raw ``len(...)`` in hot/jitted code: every
  distinct request length is a distinct trace. Lengths must go through
  the bucket table first (``Scheduler``'s bucketed admission).
"""
from __future__ import annotations

import ast
from typing import Dict, Set

from ..astutil import call_kwarg, literal_tuple
from ..core import ModuleContext, register

_SHAPE_CTORS = (
    "jax.numpy.zeros", "jax.numpy.ones", "jax.numpy.full",
    "jax.numpy.empty", "jax.numpy.arange")
_BUCKET_HINTS = ("bucket", "pad", "round", "align", "tile", "chunk")


def _static_params(mod, call: ast.Call) -> Set[str]:
    """Names declared static in a jit(...) call expression."""
    out: Set[str] = set()
    for key in ("static_argnames", "static_argnums"):
        val = call_kwarg(call, key)
        if val is None:
            continue
        elts = literal_tuple(val) or [val]
        for e in elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.add(e.value)
    return out


@register("recompile-hazard", severity="error", help=(
    "jit built inside a loop, unhashable values fed to static args, or "
    "shapes keyed on unbucketed lengths — each one re-traces per call."))
def check_recompile(ctx: ModuleContext) -> None:
    mod = ctx.module

    # --- jit/pallas_call inside a loop body ------------------------------
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        kind = mod._wrapper_kind(node)
        if kind != "jit":
            continue
        # lexically inside a loop body *within the same function*: the
        # first loop ancestor appears before any enclosing def.
        for anc in mod.ancestors(node):
            if isinstance(anc, (ast.For, ast.While, ast.AsyncFor)):
                name = mod.dotted(node.func) or "jit"
                ctx.report(node, (
                    f"{name} constructed inside a loop builds a new "
                    "compiled callable (and cache) every iteration — "
                    "hoist it out of the loop"))
                break
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                break

    # --- unhashable literals into static params --------------------------
    # Map locally-jitted names → their static param names, then inspect
    # call sites of those names in the same module.
    static_of: Dict[str, Set[str]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if mod._wrapper_kind(node.value) == "jit":
                params = _static_params(mod, node.value)
                if params:
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            static_of[tgt.id] = params
                        elif isinstance(tgt, ast.Attribute):
                            dn = mod.dotted(tgt)
                            if dn:
                                static_of[dn] = params
    for fn in mod.functions:
        for deco in getattr(fn.node, "decorator_list", ()):
            if isinstance(deco, ast.Call) and \
                    mod._wrapper_kind(deco) == "jit":
                params = _static_params(mod, deco)
                if params:
                    static_of[fn.name] = params

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = mod.dotted(node.func)
        if callee is None or callee not in static_of:
            continue
        for kw in node.keywords:
            if kw.arg in static_of[callee] and isinstance(
                    kw.value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                               ast.DictComp, ast.SetComp)):
                ctx.report(kw.value, (
                    f"unhashable {type(kw.value).__name__.lower()} passed "
                    f"to static arg {kw.arg!r} of {callee} — static args "
                    "must be hashable (use a tuple) and stable across "
                    "calls"))

    # Direct jit(...) call expressions with unhashable static defaults:
    # jax.jit(f, static_argnames=...)(..., static=[...]) is rare; the
    # dominant local hazard is covered above.

    # --- shapes from unbucketed lengths ----------------------------------
    hot = mod.reachable(ctx.config.hotpath_roots)
    hot |= {f.qualname for f in mod.functions if f.hotpath_marker}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = mod.dotted(node.func)
        if name not in _SHAPE_CTORS:
            continue
        fn = mod.enclosing_function(node)
        if fn is None or not (fn.traced or fn.qualname in hot):
            continue
        shape_arg = node.args[0] if node.args else call_kwarg(node, "shape")
        if shape_arg is None:
            continue
        for leaf in ast.walk(shape_arg):
            if isinstance(leaf, ast.Call) and \
                    isinstance(leaf.func, ast.Name) and leaf.func.id == "len":
                # bucketed helpers in the expression launder the length
                src = ast.dump(shape_arg).lower()
                if any(h in src for h in _BUCKET_HINTS):
                    continue
                ctx.report(node, (
                    f"{name.rsplit('.', 1)[-1]} shape derived from raw "
                    "len(...) in hot/jitted code — every distinct length "
                    "is a fresh trace; round through the bucket table"),
                    severity="warning")
                break
