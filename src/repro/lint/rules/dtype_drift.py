"""dtype-drift: silent bf16/f16 → f32 promotion in low-precision paths.

JAX's promotion rules make Python scalars *weak*-typed (``x * 2.0`` keeps
``x``'s bf16), but ``np.float32(2.0)`` and numpy array operands are
strong: mixing one into bf16 arithmetic silently promotes the whole
expression to f32 — doubling HBM traffic in exactly the layers the paper's
quantized serving path exists to shrink. ``float64`` anywhere is worse:
jax silently truncates it unless x64 is enabled, and enabling x64 doubles
every buffer.

Checked only in the configured dtype-sensitive files (layers, KV cache,
kernels) to keep host-side tooling free to use numpy scalars.
"""
from __future__ import annotations

import ast
from fnmatch import fnmatch

from ..core import ModuleContext, register

_NP_STRONG_SCALARS = (
    "numpy.float32", "numpy.float64", "numpy.float16",
    "numpy.int64", "numpy.int32", "numpy.int16", "numpy.int8",
    "numpy.uint8", "numpy.uint32")
_NP_UFUNCS = (
    "numpy.exp", "numpy.log", "numpy.sqrt", "numpy.tanh", "numpy.maximum",
    "numpy.minimum", "numpy.where", "numpy.sum", "numpy.mean", "numpy.abs",
    "numpy.clip", "numpy.power", "numpy.square", "numpy.rsqrt")
_F64_NAMES = ("jax.numpy.float64", "numpy.float64")


@register("dtype-drift", severity="error", help=(
    "np strong-typed scalars / numpy ufuncs in bf16-sensitive code promote "
    "the whole expression to f32; float64 dtypes are truncated or double "
    "memory. Use Python scalars (weak-typed) or jnp with an explicit "
    "dtype."))
def check_dtype_drift(ctx: ModuleContext) -> None:
    mod = ctx.module
    sensitive = any(
        fnmatch(mod.relpath, g) for g in ctx.config.dtype_sensitive)

    for node in ast.walk(mod.tree):
        # float64 dtype literals on *jax* calls — host-side numpy f64 is
        # legitimate (GPTQ/SparseGPT Hessian math follows the official
        # f64 implementations), but jax truncates it silently
        if isinstance(node, ast.keyword) and node.arg == "dtype":
            name = mod.dotted(node.value)
            owner = mod.parent(node)
            owner_name = mod.dotted(owner.func) if isinstance(
                owner, ast.Call) else None
            on_jax = owner_name is not None and owner_name.startswith("jax.")
            if name in _F64_NAMES and (on_jax or name == _F64_NAMES[0]):
                ctx.report(node.value, (
                    "dtype=float64 on a jax call: truncated to f32 unless "
                    "x64 is enabled, and x64 doubles every buffer — use "
                    "an explicit f32/bf16 dtype"))
        if not sensitive:
            continue
        if isinstance(node, ast.Call):
            name = mod.dotted(node.func)
            if name in _NP_STRONG_SCALARS:
                # only flag when the scalar feeds arithmetic (a bare
                # np.int32(...) used as an index/host value is fine)
                parent = mod.parent(node)
                if isinstance(parent, (ast.BinOp, ast.Compare, ast.Call)) \
                        and not (isinstance(parent, ast.Call)
                                 and parent.func is node):
                    short = name.rsplit(".", 1)[-1]
                    ctx.report(node, (
                        f"np.{short}(...) is strong-typed: mixing it into "
                        "bf16 arithmetic promotes the whole expression "
                        "(use a Python scalar — weak-typed — or "
                        f"jnp.{short})"))
            elif name in _NP_UFUNCS:
                fn = mod.enclosing_function(node)
                if fn is not None and fn.traced:
                    short = name.rsplit(".", 1)[-1]
                    ctx.report(node, (
                        f"np.{short} on a traced value materializes a "
                        "strong-typed f32/f64 numpy array inside the "
                        f"trace — use jnp.{short}"), severity="warning")
