"""tracer-leak: traced values escaping a jitted body via mutable state.

Assigning a traced value to ``self.*``, a global, or a closed-over cell
inside a jitted/traced body leaks the tracer: jax raises
``UnexpectedTracerError`` at best, or (with a cached side table) silently
stores a stale constant. Stores through ``Ref`` subscripts
(``o_ref[...] = acc``) are the Pallas write idiom and are fine.
"""
from __future__ import annotations

import ast

from ..core import ModuleContext, register


@register("tracer-leak", severity="error", help=(
    "Assignment to self.*/globals/closures inside a jitted body leaks a "
    "tracer out of the trace; return the value instead."))
def check_tracer_leak(ctx: ModuleContext) -> None:
    mod = ctx.module
    for fn in mod.functions:
        if not fn.traced:
            continue
        declared_global = set()
        declared_nonlocal = set()
        for node in ast.walk(fn.node):
            if mod.enclosing_function(node) is not fn:
                continue
            if isinstance(node, ast.Global):
                declared_global.update(node.names)
            elif isinstance(node, ast.Nonlocal):
                declared_nonlocal.update(node.names)
        for node in ast.walk(fn.node):
            if mod.enclosing_function(node) is not fn:
                continue
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    if isinstance(tgt, ast.Attribute):
                        base = tgt.value
                        if isinstance(base, ast.Name) and base.id == "self":
                            ctx.report(node, (
                                f"assignment to self.{tgt.attr} inside a "
                                "traced body leaks the tracer into object "
                                "state — thread it through the return "
                                "value"))
                    elif isinstance(tgt, ast.Name):
                        if tgt.id in declared_global:
                            ctx.report(node, (
                                f"assignment to global {tgt.id!r} inside a "
                                "traced body leaks the tracer"))
                        elif tgt.id in declared_nonlocal:
                            ctx.report(node, (
                                f"assignment to nonlocal {tgt.id!r} inside "
                                "a traced body leaks the tracer into the "
                                "enclosing scope"))
            elif isinstance(node, ast.Call):
                # closure.append(x) / dict.setdefault smuggles tracers into
                # host-side containers; warn (it may be a static value).
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr in ("append", "extend", "add",
                                           "setdefault", "update"):
                    base = node.func.value
                    if isinstance(base, ast.Name):
                        # only when the container is not local to this fn
                        local = False
                        for n2 in ast.walk(fn.node):
                            if isinstance(n2, ast.Assign) and \
                                    mod.enclosing_function(n2) is fn and any(
                                        isinstance(t, ast.Name)
                                        and t.id == base.id
                                        for t in n2.targets):
                                local = True
                                break
                        if not local:
                            ctx.report(node, (
                                f"{base.id}.{node.func.attr}(...) inside a "
                                "traced body may smuggle a tracer into a "
                                "host container"), severity="warning")
