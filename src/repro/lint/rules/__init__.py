"""Built-in lint rules. Importing this package registers every rule —
the ``_load_builtins()`` hook in ``repro.lint.core`` imports it lazily,
mirroring how ``repro.core.registry`` loads its compression methods."""
from . import contracts  # noqa: F401
from . import dtype_drift  # noqa: F401
from . import host_sync  # noqa: F401
from . import pallas_tiling  # noqa: F401
from . import recompile  # noqa: F401
from . import tracer_leak  # noqa: F401
