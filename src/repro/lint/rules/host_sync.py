"""host-sync: device→host transfers on hot paths and inside jitted bodies.

The serving contract is ONE host transfer per decode step (the packed
int32 token block); anything else — ``.item()``, ``np.asarray`` on a
device value, ``int()`` on a device scalar, an ``if`` on an array —
serializes the dispatch pipeline. Inside a jitted body the same calls are
worse: they either fail under tracing or silently force a constant.

Scope: functions that are (a) jitted/traced, (b) reachable from the
configured hot-path roots (``Engine.step`` + markers) over the
intra-module call graph, or (c) carry a ``# lint: hotpath`` marker.

Dataflow: a name is *device-tainted* when assigned from a ``jnp.*`` /
``jax.*`` call, from a configured device producer (``self._decode(...)``),
or from arithmetic over tainted names; ``np.asarray``/``jax.device_get``
launder the result back to host. ``x.shape``/``len(x)`` never taint —
they are static under tracing.
"""
from __future__ import annotations

import ast
from typing import Set

from ..astutil import is_shapelike
from ..core import ModuleContext, register

_ALWAYS_SYNC_ATTRS = ("item", "block_until_ready", "tolist")
_SYNC_CALLS = ("jax.device_get",)
_LAUNDER_CALLS = ("numpy.asarray", "numpy.array", "jax.device_get")
_CAST_BUILTINS = ("float", "int", "bool")
_DEVICE_PREFIXES = ("jax.numpy.", "jax.lax.", "jax.nn.", "jax.random.")
_DEVICE_CALLS = ("jax.device_put", "jax.block_until_ready")


def _tainted_names(ctx: ModuleContext, fn_node: ast.AST,
                   producers: Set[str], params_tainted: bool) -> Set[str]:
    """One forward pass per function body: names holding device values."""
    mod = ctx.module
    tainted: Set[str] = set()
    fn_info = mod.enclosing_function(fn_node.body[0]) if fn_node.body \
        else None
    static = fn_info.static_params if fn_info is not None else set()
    if params_tainted:
        args = fn_node.args
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)):
            if a.arg != "self" and a.arg not in static:
                tainted.add(a.arg)

    def expr_tainted(node: ast.AST) -> bool:
        if is_shapelike(node):
            return False
        if isinstance(node, ast.Name):
            return node.id in tainted
        if isinstance(node, ast.Attribute):
            name = mod.dotted(node)
            if name is not None and name in producers:
                return True
            return expr_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return expr_tainted(node.value)
        if isinstance(node, ast.Call):
            name = mod.dotted(node.func)
            if name is not None:
                if name in _LAUNDER_CALLS:
                    return False
                if name.startswith(_DEVICE_PREFIXES) or name in (
                        _DEVICE_CALLS + tuple(producers)):
                    return True
            return any(expr_tainted(a) for a in node.args)
        if isinstance(node, ast.BinOp):
            return expr_tainted(node.left) or expr_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return expr_tainted(node.operand)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return expr_tainted(node.body) or expr_tainted(node.orelse)
        return False

    def name_targets(tgt: ast.AST):
        """Plain Name binding targets only — ``self.kv`` in a tuple target
        must not taint the ``self`` base name."""
        if isinstance(tgt, ast.Name):
            yield tgt.id
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                yield from name_targets(e)
        elif isinstance(tgt, ast.Starred):
            yield from name_targets(tgt.value)

    for stmt in ast.walk(fn_node):
        if isinstance(stmt, ast.Assign):
            if expr_tainted(stmt.value):
                for tgt in stmt.targets:
                    for nm in name_targets(tgt):
                        tainted.add(nm)
            else:
                # reassignment from a host value clears the taint
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name):
                        tainted.discard(tgt.id)
        elif isinstance(stmt, ast.AugAssign):
            if expr_tainted(stmt.value) and isinstance(stmt.target, ast.Name):
                tainted.add(stmt.target.id)
    return tainted


@register("host-sync", severity="error", help=(
    "Device→host sync (.item(), np.asarray, int()/float()/bool() on a "
    "device value, if-on-array) on a serving hot path or in a jitted body. "
    "The decode loop's contract is one packed transfer per step."))
def check_host_sync(ctx: ModuleContext) -> None:
    mod = ctx.module
    cfg = ctx.config
    producers = set(cfg.device_producers)
    hot = mod.reachable(cfg.hotpath_roots)
    hot |= {f.qualname for f in mod.functions if f.hotpath_marker}
    hot |= mod.reachable(
        [f.qualname for f in mod.functions if f.hotpath_marker])

    for fn in mod.functions:
        in_jit = fn.traced
        in_hot = fn.qualname in hot
        if not (in_jit or in_hot):
            continue
        where = "jitted body" if in_jit else "hot path"
        tainted = _tainted_names(ctx, fn.node, producers, in_jit)

        def is_device(node: ast.AST) -> bool:
            if is_shapelike(node):
                return False
            if isinstance(node, ast.Name):
                return node.id in tainted
            if isinstance(node, ast.Attribute):
                name = mod.dotted(node)
                if name is not None and name in producers:
                    return True
                return is_device(node.value)
            if isinstance(node, ast.Subscript):
                return is_device(node.value)
            if isinstance(node, ast.Call):
                name = mod.dotted(node.func)
                if name is not None and name in _LAUNDER_CALLS:
                    return False
                if name is not None and (
                        name.startswith(_DEVICE_PREFIXES)
                        or name in _DEVICE_CALLS or name in producers):
                    return True
                return any(is_device(a) for a in node.args)
            if isinstance(node, (ast.BinOp, ast.UnaryOp)):
                kids = ([node.left, node.right]
                        if isinstance(node, ast.BinOp) else [node.operand])
                return any(is_device(k) for k in kids)
            return False

        for node in ast.walk(fn.node):
            if mod.enclosing_function(node) is not fn:
                continue
            if isinstance(node, ast.Call):
                name = mod.dotted(node.func)
                # x.item(), x.block_until_ready(), x.tolist()
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr in _ALWAYS_SYNC_ATTRS:
                    base = mod.dotted(node.func.value)
                    if base is None or not base.startswith(
                            ("numpy.", "math.")):
                        ctx.report(node, (
                            f".{node.func.attr}() forces a device→host "
                            f"sync in a {where}"))
                    continue
                if name in _SYNC_CALLS and node.args:
                    ctx.report(node, (
                        f"{name.rsplit('.', 1)[-1]}() is a blocking "
                        f"device→host transfer in a {where}"))
                    continue
                if name in ("numpy.asarray", "numpy.array") and node.args \
                        and is_device(node.args[0]):
                    ctx.report(node, (
                        "np.asarray on a device value blocks until the "
                        f"array is ready ({where})"))
                    continue
                if isinstance(node.func, ast.Name) and \
                        node.func.id in _CAST_BUILTINS and node.args and \
                        is_device(node.args[0]):
                    ctx.report(node, (
                        f"{node.func.id}() on a device value forces a "
                        f"device→host sync in a {where}"))
                    continue
            elif isinstance(node, (ast.If, ast.While)) and in_jit:
                test = node.test
                if is_device(test):
                    ctx.report(test, (
                        "branching on a traced value inside a jitted body "
                        "— use jnp.where/lax.cond"))
            elif isinstance(node, ast.If) and in_hot and not in_jit:
                if is_device(node.test):
                    ctx.report(node.test, (
                        "if-on-device-array implicitly calls bool() — a "
                        "blocking sync on a hot path"))
