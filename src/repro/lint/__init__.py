"""repro.lint — JAX/Pallas-aware static analysis for this repo.

Six rule families over ``src/repro`` (host-sync, recompile-hazard,
tracer-leak, pallas-tiling, dtype-drift, register/metrics contracts),
a content-fingerprinted baseline, and ``scripts/run_lint.py`` as the CLI.
See ``docs/static-analysis.md`` for the catalog and workflow.
"""
from .core import (Finding, LintConfig, available, load_baseline,
                   partition, register, run_lint, save_baseline)

__all__ = ["Finding", "LintConfig", "available", "load_baseline",
           "partition", "register", "run_lint", "save_baseline"]
