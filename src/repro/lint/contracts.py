"""Shared contract-extraction helpers: metric family names and schema.

Used by two consumers so they can never drift apart:

* the ``metrics-contract`` lint rule (``repro.lint.rules.contracts``),
  which cross-checks extracted names against the schema bidirectionally;
* ``scripts/check_metrics_schema.py``, which validates runtime snapshots
  against the same ``families`` list instead of hand-maintained greps.

Extraction is static: every ``<registry>.counter/gauge/histogram(name,
...)`` call in the tree contributes a family name. Literal first args give
exact names; f-strings give ``fnmatch`` patterns (``f"engine_{key}_total"``
→ ``engine_*_total``); and one level of local-helper indirection is
resolved — the engine's ``counter(key, help)`` wrapper and its
``{k: counter(k, h) for k, h in (…literal tuples…)}`` registration dict
both yield exact names.
"""
from __future__ import annotations

import ast
import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

from .astutil import Module

KIND_OF_METHOD = {"counter": "counters", "gauge": "gauges",
                  "histogram": "histograms"}
KINDS = tuple(sorted(set(KIND_OF_METHOD.values())))


@dataclasses.dataclass(frozen=True)
class MetricUse:
    kind: str                       # counters | gauges | histograms
    name: str                       # exact name or fnmatch pattern
    exact: bool
    path: str
    line: int


# ---- template machinery --------------------------------------------------

def _template(mod: Module, node: ast.AST) -> Optional[List[Tuple[str, str]]]:
    """First-arg expression → [('lit', s) | ('hole', varname|'')] parts,
    or None when it contributes no name (non-string)."""
    if isinstance(node, ast.Constant):
        return [("lit", node.value)] if isinstance(node.value, str) else None
    if isinstance(node, ast.JoinedStr):
        parts: List[Tuple[str, str]] = []
        for v in node.values:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                parts.append(("lit", v.value))
            elif isinstance(v, ast.FormattedValue) and \
                    isinstance(v.value, ast.Name):
                parts.append(("hole", v.value.id))
            else:
                parts.append(("hole", ""))
        return parts
    if isinstance(node, ast.Name):
        return [("hole", node.id)]
    return None


def _render(parts: Sequence[Tuple[str, str]],
            subs: Optional[Dict[str, str]] = None) -> Tuple[str, bool]:
    """(name-or-pattern, exact) after substituting hole values."""
    out: List[str] = []
    exact = True
    for kind, val in parts:
        if kind == "lit":
            out.append(val)
        elif subs is not None and val in subs:
            out.append(subs[val])
        else:
            out.append("*")
            exact = False
    return "".join(out), exact


def _params(fn_node: ast.AST) -> List[str]:
    a = fn_node.args
    names = [p.arg for p in list(a.posonlyargs) + list(a.args)]
    return [n for n in names if n != "self"]


def _comp_substitutions(mod: Module, call: ast.Call, arg: ast.AST
                        ) -> Optional[List[str]]:
    """When ``arg`` is the target variable of an enclosing comprehension
    iterating a literal tuple-of-tuples (the engine's registration dict),
    return every literal value the variable takes."""
    if not isinstance(arg, ast.Name):
        return None
    for anc in mod.ancestors(call):
        if not isinstance(anc, (ast.DictComp, ast.ListComp, ast.SetComp,
                                ast.GeneratorExp)):
            continue
        for gen in anc.generators:
            tgt, it = gen.target, gen.iter
            if not isinstance(it, (ast.Tuple, ast.List)):
                continue
            if isinstance(tgt, ast.Name) and tgt.id == arg.id:
                vals = [e.value for e in it.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)]
                return vals if len(vals) == len(it.elts) else None
            if isinstance(tgt, (ast.Tuple, ast.List)):
                for i, t in enumerate(tgt.elts):
                    if isinstance(t, ast.Name) and t.id == arg.id:
                        vals = []
                        for e in it.elts:
                            if isinstance(e, (ast.Tuple, ast.List)) and \
                                    len(e.elts) > i and \
                                    isinstance(e.elts[i], ast.Constant) and \
                                    isinstance(e.elts[i].value, str):
                                vals.append(e.elts[i].value)
                            else:
                                return None
                        return vals
    return None


def extract_metric_uses(mod: Module) -> List[MetricUse]:
    """All metric family names (exact or pattern) a module registers."""
    uses: List[MetricUse] = []
    # helper name → (kind, template, param names, definition FunctionInfo)
    helpers: Dict[str, Tuple[str, List[Tuple[str, str]], List[str]]] = {}

    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in KIND_OF_METHOD):
            continue
        kind = KIND_OF_METHOD[node.func.attr]
        first = node.args[0] if node.args else None
        if first is None:
            for kw in node.keywords:
                if kw.arg == "name":
                    first = kw.value
        if first is None:
            continue
        parts = _template(mod, first)
        if parts is None:
            continue
        holes = [v for k, v in parts if k == "hole"]
        fn = mod.enclosing_function(node)
        if holes and fn is not None and \
                any(h in _params(fn.node) for h in holes if h):
            helpers[fn.name] = (kind, parts, _params(fn.node))
            continue
        name, exact = _render(parts)
        uses.append(MetricUse(kind, name, exact, mod.relpath, node.lineno))

    if not helpers:
        return uses

    # resolve helper call sites: bare ``counter(k, h)`` or ``self._status_
    # counter("requests", ...)``
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        hname = None
        if isinstance(node.func, ast.Name):
            hname = node.func.id
        elif isinstance(node.func, ast.Attribute) and \
                isinstance(node.func.value, ast.Name) and \
                node.func.value.id == "self":
            hname = node.func.attr
        if hname not in helpers:
            continue
        kind, parts, params = helpers[hname]
        subs: Dict[str, str] = {}
        multi: Dict[str, List[str]] = {}
        for i, p in enumerate(params):
            arg: Optional[ast.AST] = None
            if i < len(node.args):
                arg = node.args[i]
            else:
                for kw in node.keywords:
                    if kw.arg == p:
                        arg = kw.value
            if arg is None:
                continue
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                subs[p] = arg.value
            else:
                vals = _comp_substitutions(mod, node, arg)
                if vals is not None:
                    multi[p] = vals
        if multi:
            # one hole expanding over a literal tuple (engine's dict comp)
            p, vals = next(iter(multi.items()))
            for v in vals:
                name, exact = _render(parts, {**subs, p: v})
                uses.append(MetricUse(kind, name, exact, mod.relpath,
                                      node.lineno))
        else:
            name, exact = _render(parts, subs)
            uses.append(MetricUse(kind, name, exact, mod.relpath,
                                  node.lineno))
    return uses


# ---- schema --------------------------------------------------------------

def load_schema_families(path: str) -> Dict[str, List[str]]:
    """The ``families`` contract from ``scripts/metrics_schema.json``:
    ``{"counters": [names...], "gauges": [...], "histograms": [...]}``."""
    with open(path, "r", encoding="utf-8") as fh:
        schema = json.load(fh)
    fam = schema.get("families")
    if not isinstance(fam, dict):
        raise ValueError(
            f"{path} has no 'families' key — the metric-name contract "
            "the lint and snapshot checkers share")
    out: Dict[str, List[str]] = {}
    for kind in KINDS:
        names = fam.get(kind, [])
        if not isinstance(names, list) or \
                not all(isinstance(n, str) for n in names):
            raise ValueError(f"families.{kind} must be a list of strings")
        out[kind] = sorted(names)
    return out
