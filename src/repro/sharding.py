"""Named-axis sharding rules: logical tensor axes → mesh PartitionSpecs.

Every tensor in the system is annotated with *logical* axis names; this module
maps them onto the physical mesh (``pod × data × model`` multi-pod, or
``data × model`` single-pod). The mapping is adaptive: a logical axis is only
sharded if its size divides the mesh axis size (e.g. starcoder2-7b's 36 heads
do not divide model=16, so heads fall back to replicated and the MLP carries
the tensor-parallelism — see DESIGN.md §4).

Conventions
-----------
logical axes:
  "batch"    — data-parallel batch          → ("pod", "data")
  "fsdp"     — parameter FSDP dim           → ("pod", "data")
  "tp"       — tensor-parallel dim (heads / d_ff / experts / vocab) → "model"
  "seq_kv"   — KV-cache sequence dim for decode (flash-decoding)    → "model"
  "rows"     — AWP row-parallel d_out dim   → all axes (whole mesh)
  None       — replicated
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Physical meaning of each logical axis for a given mesh (None = single
    device / no constraint mode)."""
    mesh: Optional[Mesh] = None
    batch_axes: tuple = ("data",)       # ("pod","data") on multi-pod
    tp_axis: Optional[str] = "model"
    fsdp_axes: tuple = ("data",)
    rows_axes: tuple = ("data", "model")

    @staticmethod
    def for_mesh(mesh: Optional[Mesh]) -> "ShardingRules":
        if mesh is None:
            return ShardingRules(mesh=None)
        names = mesh.axis_names
        batch = tuple(n for n in ("pod", "data") if n in names)
        tp = "model" if "model" in names else None
        rows = tuple(n for n in ("pod", "data", "model") if n in names)
        return ShardingRules(mesh=mesh, batch_axes=batch or ("data",),
                             tp_axis=tp, fsdp_axes=batch or ("data",),
                             rows_axes=rows)

    # -- helpers ----------------------------------------------------------
    def axis_size(self, axes) -> int:
        if self.mesh is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def _resolve(self, logical: Optional[str], dim: int):
        """Logical name -> mesh axes, or None when not shardable/divisible."""
        if logical is None or self.mesh is None:
            return None
        table = {
            "batch": self.batch_axes,
            "fsdp": self.fsdp_axes,
            "tp": self.tp_axis,
            "seq_kv": self.tp_axis,
            "rows": self.rows_axes,
        }
        axes = table.get(logical)
        if axes is None:
            return None
        size = self.axis_size(axes)
        if size <= 1 or dim % size != 0:
            return None                      # adaptive fallback: replicate
        return axes

    def spec(self, logical_axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        entries, used = [], set()
        for name, dim in zip(logical_axes, shape):
            ax = self._resolve(name, dim)
            # a mesh axis may appear at most once in a spec
            if ax is not None:
                flat = (ax,) if isinstance(ax, str) else tuple(ax)
                if any(a in used for a in flat):
                    ax = None
                else:
                    used.update(flat)
            entries.append(ax)
        return P(*entries)

    def sharding(self, logical_axes, shape) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def rules_for_cell(mesh: Optional[Mesh], family: str, kind: str,
                   global_batch: Optional[int] = None) -> ShardingRules:
    """Family/step-specific physical mapping (DESIGN.md §4).

    All families use batch over (pod, data) + TP over model. For SSM/hybrid
    the *models* keep the sequence axis unsharded (a sharded sequential scan
    serializes across devices) and carry TP on d_model/d_inner instead —
    the recurrence is elementwise across channels (see mamba.py hints).
    """
    return ShardingRules.for_mesh(mesh)


def qtensor_logical_axes(dense_axes, qt):
    """Per-child logical axes for a packed QTensor leaf.

    ``dense_axes`` are the axes the *dense* leaf would carry in stored
    orientation ``(…lead, d_in, d_out)``; the QTensor children live in
    paper orientation, so ``packed`` gets ``(…lead, d_out_ax, d_in_ax)``,
    ``scale``/``zero`` put their group axis (which tiles d_in) on the
    d_in axis name, and ``col_scale`` keeps the d_in axis. Returned as a
    QTensor-of-tuples so the axes tree mirrors the param tree node-for-node
    (divisibility fallbacks still apply per child at spec time)."""
    from repro.quant import QTensor
    lead = tuple(dense_axes[:-2])
    d_in_ax, d_out_ax = dense_axes[-2], dense_axes[-1]
    return QTensor(
        packed=lead + (d_out_ax, d_in_ax),
        scale=lead + (d_out_ax, d_in_ax),
        zero=lead + (d_out_ax, d_in_ax),
        bits=qt.bits, group_size=qt.group_size, shape=qt.shape,
        col_scale=(lead + (d_in_ax,)) if qt.col_scale is not None else None)


def adapt_logical_axes(logical_tree, params):
    """QTensor-aware param axes: wherever ``params`` holds a packed QTensor
    leaf (a packed-checkpoint restore), expand the model's dense leaf axes
    into per-child axes so packed leaves shard under TP/FSDP instead of
    falling back to replicated. Dense leaves pass through untouched."""
    from repro.quant import QTensor
    if isinstance(params, QTensor):
        return qtensor_logical_axes(logical_tree, params)
    if isinstance(logical_tree, dict):
        return {k: adapt_logical_axes(logical_tree[k], params[k])
                for k in logical_tree}
    return logical_tree


def tree_specs(rules: ShardingRules, logical_tree, shape_tree):
    """Mirror-walk a logical-axes tree against a ShapeDtypeStruct tree and
    produce PartitionSpecs (dicts of dicts; leaves are tuples of axis
    names). QTensor nodes (from :func:`adapt_logical_axes`) map per child."""
    from repro.quant import QTensor
    if isinstance(logical_tree, dict):
        return {k: tree_specs(rules, logical_tree[k], shape_tree[k])
                for k in logical_tree}
    if isinstance(logical_tree, QTensor):
        qt = logical_tree
        return QTensor(
            packed=rules.spec(qt.packed, shape_tree.packed.shape),
            scale=rules.spec(qt.scale, shape_tree.scale.shape),
            zero=rules.spec(qt.zero, shape_tree.zero.shape),
            bits=qt.bits, group_size=qt.group_size, shape=qt.shape,
            col_scale=(rules.spec(qt.col_scale, shape_tree.col_scale.shape)
                       if qt.col_scale is not None else None))
    return rules.spec(logical_tree, shape_tree.shape)


def tree_shardings(rules: ShardingRules, logical_tree, shape_tree):
    if rules.mesh is None:
        return None
    specs = tree_specs(rules, logical_tree, shape_tree)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_logical_axes(opt_name: str, param_logical, param_shapes):
    """Logical axes for optimizer state mirroring the params tree."""
    if opt_name == "adamw":
        return {"m": param_logical, "v": param_logical, "step": ()}

    def leaf(log, shape):
        if len(shape.shape) >= 2:
            return {"vr": tuple(log[:-1]), "vc": tuple(log[:-2]) + (log[-1],)}
        return {"v": tuple(log)}

    def rec(log, shp):
        if isinstance(log, dict):
            return {k: rec(log[k], shp[k]) for k in log}
        return leaf(log, shp)

    return {"v": rec(param_logical, param_shapes), "step": ()}


def hint(x: jax.Array, rules: ShardingRules, logical_axes) -> jax.Array:
    """with_sharding_constraint if a mesh is active, identity otherwise."""
    if rules is None or rules.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec(logical_axes, x.shape)))


NO_RULES = ShardingRules(mesh=None)

__all__ = ["ShardingRules", "adapt_logical_axes", "hint", "NO_RULES", "P",
           "qtensor_logical_axes"]
