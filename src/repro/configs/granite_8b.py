"""granite-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-arch, code. [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=49152, head_dim=128,
    mlp_act="silu", rope_theta=1e4,
    source="arXiv:2405.04324 / hf:ibm-granite/granite-8b-code-base",
)

TINY = ModelConfig(
    name="tiny-granite", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=256, head_dim=16,
    mlp_act="silu",
)
