"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B family; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=128,
    num_experts=128, experts_per_token=8,
    mlp_act="silu", rope_theta=1e6,
    source="hf:Qwen/Qwen3-235B-A22B",
)

TINY = ModelConfig(
    name="tiny-qwen3-moe", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=96, vocab_size=256, head_dim=16,
    num_experts=8, experts_per_token=2,
    mlp_act="silu",
)
