"""llama31-8b [dense] — the paper's Table-3/4 quantization target. 32L
d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256. [arXiv:2407.21783; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama31-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    mlp_act="silu", rope_theta=5e5,
    source="arXiv:2407.21783",
)

TINY = ModelConfig(
    name="tiny-llama31", family="dense",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=448, vocab_size=512, head_dim=32,
    mlp_act="silu",
)
