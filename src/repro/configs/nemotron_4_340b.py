"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    d_ff=73728, vocab_size=256000, head_dim=192,
    mlp_act="relu2", rope_theta=1e4,
    source="arXiv:2402.16819",
)

TINY = ModelConfig(
    name="tiny-nemotron", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256, head_dim=16,
    mlp_act="relu2",
)
