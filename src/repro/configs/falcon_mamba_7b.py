"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — Mamba-1 architecture. [arXiv:2410.05355; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    source="arXiv:2410.05355 / hf:tiiuae/falcon-mamba-7b",
)

TINY = ModelConfig(
    name="tiny-falcon-mamba", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=256,
    ssm_state=8, ssm_conv=4, ssm_expand=2,
)
