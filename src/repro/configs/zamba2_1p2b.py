"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (MHA kv=32) d_ff=8192,
ssm_state=64 — Mamba-2 blocks + one shared attention block interleaved.
[arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    attn_every=6,                       # shared block re-invoked every 6 blocks
    mlp_act="gelu", rope_theta=1e4,
    source="arXiv:2411.15242 / hf:Zyphra/Zamba2-1.2B",
)

TINY = ModelConfig(
    name="tiny-zamba2", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=16,
    attn_every=2, mlp_act="gelu",
)
