"""llama2-7b [dense] — the paper's Table-1 pruning target. 32L d_model=4096
32H (MHA) d_ff=11008 vocab=32000. [arXiv:2307.09288; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=32000, head_dim=128,
    mlp_act="silu", rope_theta=1e4,
    source="arXiv:2307.09288",
)

TINY = ModelConfig(
    name="tiny-llama2", family="dense",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=344, vocab_size=512, head_dim=32,
    mlp_act="silu",
)
