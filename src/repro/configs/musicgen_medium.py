"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only per assignment: the EnCodec frontend is a stub —
``input_specs()`` supplies precomputed frame embeddings (B, S, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    mlp_act="gelu", rope_theta=1e4,
    frontend="audio_frames",
    source="arXiv:2306.05284 / hf:facebook/musicgen-medium",
)

TINY = ModelConfig(
    name="tiny-musicgen", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=192, vocab_size=128, head_dim=16,
    mlp_act="gelu", frontend="audio_frames",
)
