"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE. [arXiv:2402.19173; hf]

Note: 36 heads do not divide the model=16 mesh axis; the adaptive sharding
rules replicate heads and carry TP on d_ff (DESIGN.md §4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152, head_dim=128,
    mlp_act="gelu", rope_theta=1e5,
    source="arXiv:2402.19173 / hf:bigcode/starcoder2-7b",
)

TINY = ModelConfig(
    name="tiny-starcoder2-7b", family="dense",
    num_layers=2, d_model=60, num_heads=3, num_kv_heads=1,
    d_ff=256, vocab_size=256, head_dim=20,
    mlp_act="gelu",
)
