"""llama2-13b [dense] — the paper's Table-2 pruning target. 40L d_model=5120
40H (MHA) d_ff=13824 vocab=32000. [arXiv:2307.09288; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-13b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=13824, vocab_size=32000, head_dim=128,
    mlp_act="silu", rope_theta=1e4,
    source="arXiv:2307.09288",
)

TINY = ModelConfig(
    name="tiny-llama2-13b", family="dense",
    num_layers=5, d_model=160, num_heads=5, num_kv_heads=5,
    d_ff=432, vocab_size=512, head_dim=32,
    mlp_act="silu",
)
