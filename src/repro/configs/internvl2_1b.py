"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + InternLM2/Qwen2 backbone. [arXiv:2404.16821; hf]

Backbone only per assignment: the InternViT frontend is a stub —
``input_specs()`` supplies precomputed patch embeddings (B, P, d_model)
prepended to the token embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    mlp_act="silu", rope_theta=1e6,
    frontend="vision_patches", num_patches=256,
    source="arXiv:2404.16821 / hf:OpenGVLab/InternVL2-1B",
)

TINY = ModelConfig(
    name="tiny-internvl2", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    mlp_act="silu", frontend="vision_patches", num_patches=16,
)
