"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    d_ff=24576, vocab_size=49152, head_dim=128,
    mlp_act="gelu", rope_theta=1e5,
    source="arXiv:2402.19173 / hf:bigcode/starcoder2-15b",
)

TINY = ModelConfig(
    name="tiny-starcoder2-15b", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256, head_dim=16,
    mlp_act="gelu",
)
