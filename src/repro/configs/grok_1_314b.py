"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ModelConfig

# Note: grok-1's experts are GATED (linear_v/linear/linear_1 = 3 matrices —
# that is what makes the total 314B); we model the gate with the silu-gated
# MLP path (gating nonlinearity approximated, widths exact).
CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072, head_dim=128,
    num_experts=8, experts_per_token=2,
    mlp_act="silu", rope_theta=1e4,
    source="hf:xai-org/grok-1",
)

TINY = ModelConfig(
    name="tiny-grok-1", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    num_experts=4, experts_per_token=2,
    mlp_act="silu",
)
