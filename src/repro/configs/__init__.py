from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES, shapes_for,
                                get_config, get_tiny_config, list_archs)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shapes_for", "get_config",
           "get_tiny_config", "list_archs"]
