"""llama32-1b [dense] — the paper's Table-5 joint-compression target. 16L
d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256. [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama32-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=64,
    mlp_act="silu", rope_theta=5e5, tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B",
)

TINY = ModelConfig(
    name="tiny-llama32", family="dense",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32,
    mlp_act="silu", tie_embeddings=True,
)
