"""Unified architecture config system + registry.

Each assigned architecture lives in ``configs/<id>.py`` exposing ``CONFIG``
(exact published dims) and ``TINY`` (a reduced same-family preset for CPU
smoke tests). Select with ``--arch <id>`` anywhere in the launchers.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

_ARCH_IDS = [
    "qwen3-moe-235b-a22b", "grok-1-314b", "nemotron-4-340b",
    "starcoder2-15b", "starcoder2-7b", "granite-8b", "falcon-mamba-7b",
    "musicgen-medium", "zamba2-1.2b", "internvl2-1b",
    # paper's own evaluation models
    "llama2-7b", "llama2-13b", "llama31-8b", "llama32-1b",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                   # dense MLP dim; for moe = per-expert dim
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba1/mamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64      # mamba2 only
    # --- hybrid (zamba2): one shared attention block every `attn_every` ---
    attn_every: int = 0
    # --- misc ---
    mlp_act: str = "silu"       # silu (gated) | relu2 | gelu (non-gated)
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    frontend: Optional[str] = None   # audio_frames | vision_patches (stubs)
    num_patches: int = 0             # vlm prefix length
    source: str = ""                 # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/head rows padded to a TP-shardable, lane-aligned
        multiple (vocab_size stays the logical vocabulary; padded logit
        columns are masked to -inf in the loss). internvl2's 151655 is odd
        and would otherwise replicate 5 GB logits per device."""
        if self.vocab_size >= 2048:
            return -(-self.vocab_size // 2048) * 2048
        return -(-self.vocab_size // 128) * 128

    @property
    def d_inner(self) -> int:        # ssm inner width
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Total parameter count N (for 6·N·D roofline model flops)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim if self.num_heads else 0
        attn = d * (self.num_heads * hd) * 2 + d * (self.num_kv_heads * hd) * 2
        if self.family in ("dense", "audio", "vlm"):
            gate = 3 if self.mlp_act == "silu" else 2
            mlp = gate * d * self.d_ff
            per_layer = attn + mlp
            n = L * per_layer
        elif self.family == "moe":
            gate = 3 if self.mlp_act == "silu" else 2
            mlp = gate * d * self.d_ff * self.num_experts + d * self.num_experts
            n = L * (attn + mlp)
        elif self.family == "ssm":
            di, N = self.d_inner, self.ssm_state
            per = d * 2 * di + di * self.ssm_conv + di * (2 * N + 1) + di + di * d
            n = L * per
        elif self.family == "hybrid":
            di, N = self.d_inner, self.ssm_state
            nh = di // self.ssm_head_dim
            per = d * 2 * di + di * self.ssm_conv + di * N * 2 // (di // nh) + di + di * d
            mamba = L * (d * 2 * di + di * self.ssm_conv + 3 * nh + di + di * d)
            gate3 = 3 if self.mlp_act == "silu" else 2
            shared = attn + gate3 * d * self.d_ff
            n = mamba + shared
        else:
            raise ValueError(self.family)
        n += d * self.vocab_size * (1 if self.tie_embeddings else 2)
        return int(n)

    def active_param_count(self) -> int:
        """N_active for MoE (6·N_active·D)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        attn = d * (self.num_heads * hd) * 2 + d * (self.num_kv_heads * hd) * 2
        gate = 3 if self.mlp_act == "silu" else 2
        mlp = gate * d * self.d_ff * self.experts_per_token + d * self.num_experts
        return int(L * (attn + mlp) + 2 * d * self.vocab_size)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs whose attention is full/quadratic skip long_500k (DESIGN.md §5)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shapes_for(cfg: ModelConfig):
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
            continue   # full attention at 524k is not sub-quadratic: SKIP
        out.append(s)
    return out


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "p")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(_module_name(arch_id))
    return mod.CONFIG


def get_tiny_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(_module_name(arch_id))
    return mod.TINY


def list_archs(include_paper: bool = True):
    return list(_ARCH_IDS) if include_paper else _ARCH_IDS[:10]


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shapes_for", "get_config",
           "get_tiny_config", "list_archs"]
