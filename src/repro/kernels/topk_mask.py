"""Pallas TPU kernel: row-wise hard thresholding H_k (exact top-k) via
vectorized threshold bisection — the projection of Eq. (5).

GPU implementations use radix-select in shared memory; the TPU-native
replacement is a fixed number of lane-parallel "count |z| ≥ τ" sweeps
(DESIGN.md §2): 32 bisection steps shrink [lo, hi) to ~1 ulp, then exact-k
is restored by keeping all entries > boundary plus the first (k − count)
boundary ties in index order (matching jax.lax.top_k's tie-breaking).

Grid: one program per row block; the whole row strip lives in VMEM
(bm × d_in — ≤ 8×73728 f32 ≈ 2.3 MB for the largest assigned arch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BISECT_ITERS = 40


def _kernel(z_ref, out_ref, *, k: int):
    z = z_ref[...]
    mag = jnp.abs(z.astype(jnp.float32))
    d = mag.shape[-1]
    if k >= d:
        out_ref[...] = z
        return
    hi0 = mag.max(axis=-1, keepdims=True) + 1.0        # count(≥hi)=0 < k
    lo0 = jnp.zeros_like(hi0)                          # count(≥0)=d ≥ k

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid).astype(jnp.int32), axis=-1, keepdims=True)
        take_lo = cnt >= k                             # keep invariant
        lo2 = jnp.where(take_lo, mid, lo)
        hi2 = jnp.where(take_lo, hi, mid)
        return lo2, hi2

    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, body, (lo0, hi0))
    definite = mag >= hi                               # strictly above ties
    n_def = jnp.sum(definite.astype(jnp.int32), axis=-1, keepdims=True)
    boundary = jnp.logical_and(mag >= lo, jnp.logical_not(definite))
    order = jnp.cumsum(boundary.astype(jnp.int32), axis=-1)
    take_tie = jnp.logical_and(boundary, order <= (k - n_def))
    keep = jnp.logical_or(definite, take_tie)
    out_ref[...] = jnp.where(keep, z, jnp.zeros_like(z))


def topk_row(z: jax.Array, k: int, *, bm: int = 8,
             interpret: bool = False) -> jax.Array:
    """Keep k largest-|.| per row of z (rows, d); zero the rest."""
    rows, d = z.shape
    bm = min(bm, rows)
    pm = (-rows) % bm
    if pm:
        z = jnp.pad(z, ((0, pm), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=((rows + pm) // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pm, d), z.dtype),
        interpret=interpret,
    )(z)
    return out[:rows]


__all__ = ["topk_row"]
