"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import projections as proj


def awp_pgd_step(w, theta, c, eta):
    """Z = Θ + η (W − Θ) C. Accepts (M, K) or batched (B, M, K) operands;
    η may be per-item (B,) in the batched form."""
    eta = jnp.asarray(eta, jnp.float32)
    if eta.ndim:
        eta = eta[..., None, None]
    return (theta.astype(jnp.float32)
            + eta * ((w.astype(jnp.float32) - theta.astype(jnp.float32))
                     @ c.astype(jnp.float32))).astype(w.dtype)


def topk_row(z, k):
    return proj.topk_row(z, k)


def quant_project(z, bits, group_size=128):
    return proj.quant_project(z, bits, group_size)


def dequant_matmul(x, packed, scale, zero, group_size=128):
    from repro.quant.qtensor import unpack_int4
    codes = unpack_int4(packed).astype(jnp.float32)   # (N, K)
    n, k = codes.shape
    g = codes.reshape(n, k // group_size, group_size)
    deq = ((g - zero[..., None]) * scale[..., None]).reshape(n, k)
    return (x.astype(jnp.float32) @ deq.T).astype(x.dtype)


def kv_dequant(codes, scale, zero, group_size):
    r, k = codes.shape
    g = codes.astype(jnp.float32).reshape(r, k // group_size, group_size)
    deq = (g - zero.astype(jnp.float32)[..., None]) \
        * scale.astype(jnp.float32)[..., None]
    return deq.reshape(r, k)


def decode_attn(q, k, v, lengths):
    """Length-masked decode attention: q (B, H, D) one token per row against
    dense k/v (B, T, Hk, D); row b attends positions [0, lengths[b]).
    Rows with length 0 emit exactly-zero output (the fused kernel's
    contract for parked slots)."""
    b, h, d = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    qh = q.reshape(b, hk, g, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qh, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(d))
    valid = jnp.arange(t)[None, :] < lengths[:, None]        # (B, T)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0))
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = p.sum(axis=-1, keepdims=True)
    pv = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    # zeros only for the exact l == 0 (length-0 row) case; a NaN l —
    # poisoned cache rows — must propagate to the logits (engine guard)
    dead = l == 0.0
    out = jnp.where(dead, 0.0, pv / jnp.where(dead, 1.0, l))
    return out.reshape(b, h, d).astype(q.dtype)


def decode_attn_paged(q, k_pool, v_pool, table, lengths):
    """Paged mirror of :func:`decode_attn`: per-layer pools (P, page, Hk, D)
    gathered through ``table`` (B, n_pages) int32 into each row's contiguous
    view (sentinel entries == P clip to the last physical page — always
    masked by ``lengths``)."""
    b, npg = table.shape
    p_num, page = k_pool.shape[0], k_pool.shape[1]
    tbl = jnp.minimum(table, p_num - 1)
    def gather(pool):
        g = pool[tbl]                                 # (B, npg, page, Hk, D)
        return g.reshape(b, npg * page, *pool.shape[2:])
    return decode_attn(q, gather(k_pool), gather(v_pool), lengths)


__all__ = ["awp_pgd_step", "topk_row", "quant_project", "dequant_matmul",
           "kv_dequant", "decode_attn", "decode_attn_paged"]
