"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import projections as proj


def awp_pgd_step(w, theta, c, eta):
    """Z = Θ + η (W − Θ) C. Accepts (M, K) or batched (B, M, K) operands;
    η may be per-item (B,) in the batched form."""
    eta = jnp.asarray(eta, jnp.float32)
    if eta.ndim:
        eta = eta[..., None, None]
    return (theta.astype(jnp.float32)
            + eta * ((w.astype(jnp.float32) - theta.astype(jnp.float32))
                     @ c.astype(jnp.float32))).astype(w.dtype)


def topk_row(z, k):
    return proj.topk_row(z, k)


def quant_project(z, bits, group_size=128):
    return proj.quant_project(z, bits, group_size)


def dequant_matmul(x, packed, scale, zero, group_size=128):
    from repro.quant.qtensor import unpack_int4
    codes = unpack_int4(packed).astype(jnp.float32)   # (N, K)
    n, k = codes.shape
    g = codes.reshape(n, k // group_size, group_size)
    deq = ((g - zero[..., None]) * scale[..., None]).reshape(n, k)
    return (x.astype(jnp.float32) @ deq.T).astype(x.dtype)


def kv_dequant(codes, scale, zero, group_size):
    r, k = codes.shape
    g = codes.astype(jnp.float32).reshape(r, k // group_size, group_size)
    deq = (g - zero.astype(jnp.float32)[..., None]) \
        * scale.astype(jnp.float32)[..., None]
    return deq.reshape(r, k)


__all__ = ["awp_pgd_step", "topk_row", "quant_project", "dequant_matmul",
           "kv_dequant"]
