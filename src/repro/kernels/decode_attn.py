"""Pallas TPU kernel: fused flash-decode attention over the serving KV cache.

One query token per row attends its whole cache history in a single fused
program — no materialized dense K/V. Four variants share the kernel body:

- **slot layout**: per-layer cache ``(B, T, Hk, D)`` (B = slots), dense
  floats or INT8 codes + per-head-group f16 scale/zero dequantized IN-TILE;
- **paged layout**: per-layer page pools ``(P, page, Hk, D)`` routed through
  a ``(B, n_pages)`` block table — each K tile is one page, gathered via the
  scalar-prefetched table in the BlockSpec index map (sentinel entries
  ``== P`` clip to the last physical page; their garbage is always masked).

The kernel is **length-aware**: per-row lengths (scalar-prefetched to SMEM)
bound the K loop. Tiles at or beyond a row's length skip their compute
(``pl.when``) and their index map clamps to the last live tile, so the TPU
pipeline elides the HBM→VMEM copy (same-index revisit) — decode stops
reading dead rows instead of scanning max_len, for dense and INT8 alike.

Softmax is accumulated online (m, l, acc scratch carried across the K-tile
grid axis), f32 statistics, causal mask ``k_idx < length``. Rows with
``length == 0`` produce exactly zero output (the ``l > 0`` guard).

Grid: ``(B, num_k_tiles)`` — K tiles innermost so the scratch carry is
per-row. Head layout matches ``models/layers._scores``: ``H = Hk·g`` with
head ``h`` ↦ ``(h // g, h % g)``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30   # finite init so exp(m_prev - m_new) is 0.0, never NaN


def _cdiv(a, b):
    return (a + b - 1) // b


def _dequant_tile(codes, scale, zero, group: int):
    """In-tile INT8 → f32 expansion; same op order as the reference
    ``kv_cache._reference_dequant`` so fused-vs-reference parity is tight."""
    bt, hk, d = codes.shape
    g = codes.astype(jnp.float32).reshape(bt, hk, d // group, group)
    deq = (g - zero.astype(jnp.float32)[..., None]) \
        * scale.astype(jnp.float32)[..., None]
    return deq.reshape(bt, hk, d)


def _online_update(q, k, v, start, length, bt, sm_scale,
                   m_ref, l_ref, acc_ref):
    """One K tile of online-softmax flash decode. q (H, D) f32; k/v
    (bt, Hk, D) f32; carries (m, l, acc) live in scratch."""
    h, d = q.shape
    hk = k.shape[1]
    g = h // hk
    qh = q.reshape(hk, g, d)
    kt = k.transpose(1, 2, 0)                       # (Hk, D, bt)
    s = jax.lax.dot_general(qh, kt, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    s = s.reshape(h, bt) * sm_scale                 # (H, bt)
    kpos = start + jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1)
    s = jnp.where(kpos < length, s, -jnp.inf)       # causal: k_idx < length
    m_prev = m_ref[:, :1]                           # (H, 1)
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                          # masked cols → exp(-inf)=0
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    vt = v.transpose(1, 0, 2)                       # (Hk, bt, D)
    pv = jax.lax.dot_general(p.reshape(hk, g, bt), vt,
                             (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)
    acc_ref[...] = acc_ref[...] * corr + pv.reshape(h, d)


def _make_kernel(*, bt: int, sm_scale: float, group: int, quant: bool,
                 paged: bool):
    def kernel(*refs):
        if paged:
            lens_ref, _table_ref, *rest = refs      # table only feeds maps
        else:
            lens_ref, *rest = refs
        if quant:
            (q_ref, kc_ref, ks_ref, kz_ref, vc_ref, vs_ref, vz_ref,
             o_ref, m_ref, l_ref, acc_ref) = rest
        else:
            q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = rest
        b = pl.program_id(0)
        t = pl.program_id(1)
        nt = pl.num_programs(1)

        @pl.when(t == 0)
        def _init():
            m_ref[...] = jnp.full(m_ref.shape, _NEG_INF, jnp.float32)
            l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
            acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

        length = lens_ref[b]
        start = t * bt

        @pl.when(start < length)
        def _tile():
            q = q_ref[0].astype(jnp.float32)
            if quant:
                k = _dequant_tile(kc_ref[0], ks_ref[0], kz_ref[0], group)
                v = _dequant_tile(vc_ref[0], vs_ref[0], vz_ref[0], group)
            else:
                k = k_ref[0].astype(jnp.float32)
                v = v_ref[0].astype(jnp.float32)
            _online_update(q, k, v, start, length, bt, sm_scale,
                           m_ref, l_ref, acc_ref)

        @pl.when(t == nt - 1)
        def _emit():
            # l == 0.0 exactly ⇔ no tile ever computed (a length-0 row) →
            # emit zeros. A NaN l (poisoned cache rows) must PROPAGATE so
            # the engine's non-finite decode guard still fails the slot.
            l = l_ref[:, :1]
            dead = l == 0.0
            out = jnp.where(dead, 0.0,
                            acc_ref[...] / jnp.where(dead, 1.0, l))
            o_ref[0] = out.astype(o_ref.dtype)

    return kernel


def flash_decode(q: jax.Array, k, v, lengths: jax.Array, *,
                 k_scale=None, k_zero=None, v_scale=None, v_zero=None,
                 group_size: int = 0, table=None,
                 block_t: int = 256, interpret: bool = False) -> jax.Array:
    """Fused flash-decode attention. Returns (B, H, D) in q's dtype.

    q: (B, H, D) — one decode token per row, RoPE already applied.
    lengths: (B,) int32 — row b attends cache positions [0, lengths[b]);
    length 0 → exactly-zero output (a parked slot).

    Slot layout (``table=None``): k/v are (B, T, Hk, D) — dense floats, or
    uint8 codes with (B, T, Hk, D/group) ``*_scale``/``*_zero`` planes.
    Paged layout: k/v are per-layer pools (P, page, Hk, D) (same quant
    split) and ``table`` (B, n_pages) int32 maps row positions to physical
    pages; entries == P are sentinels (masked). ``block_t`` tiles the slot
    K loop (clamped to T); paged tiles are always one page wide.
    """
    b, h, d = q.shape
    quant = k_scale is not None
    paged = table is not None
    store = k                 # codes when quant, floats otherwise
    if quant:
        assert group_size > 0 and d % group_size == 0, (d, group_size)
    hk = store.shape[-2]
    assert h % hk == 0, (h, hk)
    sm_scale = 1.0 / math.sqrt(d)
    lengths = lengths.astype(jnp.int32)
    dg = d // group_size if quant else 0

    if paged:
        num_pages, page = store.shape[0], store.shape[1]
        nt = table.shape[1]
        bt = page
        lengths = jnp.minimum(lengths, nt * page)
        grid = (b, nt)

        def kv_map(bi, ti, lens, tbl):
            last = jnp.maximum(_cdiv(lens[bi], page) - 1, 0)
            p = tbl[bi, jnp.minimum(ti, last)]
            return (jnp.minimum(p, num_pages - 1), 0, 0, 0)

        def qo_map(bi, ti, lens, tbl):
            return (bi, 0, 0)

        num_prefetch = 2
        prefetch = (lengths, table.astype(jnp.int32))
        kv_block = (1, page, hk, d)
        sc_block = (1, page, hk, dg)
        operands = (k, v) if not quant else (k, k_scale, k_zero,
                                             v, v_scale, v_zero)
    else:
        t_len = store.shape[1]
        bt = max(1, min(block_t, t_len))
        pad = (-t_len) % bt
        if pad:
            def pad_t(x, cv=0):
                return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2),
                               constant_values=cv)
            if quant:
                k, v = pad_t(k), pad_t(v)
                k_scale, v_scale = pad_t(k_scale, 1), pad_t(v_scale, 1)
                k_zero, v_zero = pad_t(k_zero), pad_t(v_zero)
            else:
                k, v = pad_t(k), pad_t(v)
        lengths = jnp.minimum(lengths, t_len)
        grid = (b, (t_len + pad) // bt)

        def kv_map(bi, ti, lens):
            last = jnp.maximum(_cdiv(lens[bi], bt) - 1, 0)
            return (bi, jnp.minimum(ti, last), 0, 0)

        def qo_map(bi, ti, lens):
            return (bi, 0, 0)

        num_prefetch = 1
        prefetch = (lengths,)
        kv_block = (1, bt, hk, d)
        sc_block = (1, bt, hk, dg)
        operands = (k, v) if not quant else (k, k_scale, k_zero,
                                             v, v_scale, v_zero)

    in_specs = [pl.BlockSpec((1, h, d), qo_map)]
    if quant:
        in_specs += [pl.BlockSpec(kv_block, kv_map),
                     pl.BlockSpec(sc_block, kv_map),
                     pl.BlockSpec(sc_block, kv_map)] * 2
    else:
        in_specs += [pl.BlockSpec(kv_block, kv_map)] * 2

    kernel = _make_kernel(bt=bt, sm_scale=sm_scale, group=group_size,
                          quant=quant, paged=paged)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=num_prefetch,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, h, d), qo_map),
            scratch_shapes=[pltpu.VMEM((h, 128), jnp.float32),
                            pltpu.VMEM((h, 128), jnp.float32),
                            pltpu.VMEM((h, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(*prefetch, q, *operands)


__all__ = ["flash_decode"]
