"""Pallas TPU kernel: fused AWP PGD gradient step  Z = Θ + η·(W−Θ)·C.

The paper's inner-loop hot spot (O(d_out·d_in²) per iteration, §3). TPU
mapping: classic MXU matmul tiling with the subtract folded into the LHS
load and the scale+add epilogue fused into the final K-step — one VMEM
round-trip instead of three separate HLO ops (sub → dot → fma).

Grid (M/bm, N/bn, K/bk); K innermost so the f32 accumulator scratch lives in
VMEM across the contraction. Θ is passed twice with different index maps:
as Θ[i,k] for the residual and Θ[i,j] for the epilogue add.
Block defaults are 128-aligned for the 128×128 MXU.

Batched form: ``(B, M, K)`` weights with ``(B, K, K)`` covariances and a
per-item η run on a ``(B, M/bm, N/bn, K/bk)`` grid — one device program for a
whole shape bucket (all q/k/v heads, every MoE expert of a block), which is
what the batched compression engine in ``repro.core.batched`` drives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(w_ref, theta_k_ref, c_ref, theta_out_ref, eta_ref, z_ref, nrm_ref,
            acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    resid = (w_ref[...] - theta_k_ref[...]).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(
        resid, c_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        eta = eta_ref[0, 0]
        z_ref[...] = (theta_out_ref[...].astype(jnp.float32)
                      + eta * acc_ref[...]).astype(z_ref.dtype)
        # per-block ‖(W−Θ)C‖² partial from the f32 accumulator — recovering
        # the residual norm from Z−Θ instead would cancel catastrophically
        # near convergence (‖ηR‖ ≪ ‖Θ‖) and floor the PGD stopping rule
        nrm_ref[0, 0] = jnp.sum(acc_ref[...] * acc_ref[...])


def _kernel_batched(w_ref, theta_k_ref, c_ref, theta_out_ref, eta_ref, z_ref,
                    nrm_ref, acc_ref, *, n_k: int):
    """Batched variant: blocks carry a leading singleton batch dim; K is
    grid axis 3. η is per-item (read from the (B, 1) eta array)."""
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    resid = (w_ref[0] - theta_k_ref[0]).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(
        resid, c_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _epilogue():
        eta = eta_ref[0, 0]
        z_ref[0] = (theta_out_ref[0].astype(jnp.float32)
                    + eta * acc_ref[...]).astype(z_ref.dtype)
        nrm_ref[0, 0, 0] = jnp.sum(acc_ref[...] * acc_ref[...])


def _step_batched(w, theta, c, eta, *, bm, bn, bk, interpret,
                  with_resid_norm):
    """(B, M, K) × (B, K, K) batched step; eta scalar or (B,)."""
    b, m, k = w.shape
    assert theta.shape == (b, m, k) and c.shape == (b, k, k)
    bm, bn, bk = min(bm, m), min(bn, k), min(bk, k)
    pm, pn, pk = (-m) % bm, (-k) % bn, (-k) % bk
    if pm or pk:
        w = jnp.pad(w, ((0, 0), (0, pm), (0, pk)))
        theta = jnp.pad(theta, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        c = jnp.pad(c, ((0, 0), (0, pk), (0, pn)))
    mp, kp, np_ = m + pm, k + pk, k + pn
    n_k = kp // bk
    gm, gn = mp // bm, np_ // bn
    eta_arr = jnp.broadcast_to(
        jnp.asarray(eta, jnp.float32).reshape(-1), (b,)).reshape(b, 1)

    grid = (b, gm, gn, n_k)
    out, nrm = pl.pallas_call(
        functools.partial(_kernel_batched, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda bb, i, j, kk: (bb, i, kk)),  # W
            pl.BlockSpec((1, bm, bk), lambda bb, i, j, kk: (bb, i, kk)),  # Θ[i,k]
            pl.BlockSpec((1, bk, bn), lambda bb, i, j, kk: (bb, kk, j)),  # C
            pl.BlockSpec((1, bm, bn), lambda bb, i, j, kk: (bb, i, j)),   # Θ[i,j]
            pl.BlockSpec((1, 1), lambda bb, i, j, kk: (bb, 0)),           # η_b
        ],
        out_specs=[
            pl.BlockSpec((1, bm, bn), lambda bb, i, j, kk: (bb, i, j)),
            pl.BlockSpec((1, 1, 1), lambda bb, i, j, kk: (bb, i, j)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, mp, np_), w.dtype),
                   jax.ShapeDtypeStruct((b, gm, gn), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(w, theta, c, theta, eta_arr)
    z = out[:, :m, :k]
    if not with_resid_norm:
        return z
    return z, jnp.sqrt(nrm.sum(axis=(-2, -1)))


def awp_pgd_step(w: jax.Array, theta: jax.Array, c: jax.Array, eta,
                 *, bm: int = 128, bn: int = 128, bk: int = 128,
                 interpret: bool = False, with_resid_norm: bool = False):
    """One PGD gradient step (no projection). w, theta: (M, K); c: (K, N=K).

    3-D inputs ``(B, M, K)`` / ``(B, K, K)`` run the batched grid (η may then
    be per-item, shape ``(B,)``). ``with_resid_norm=True`` additionally
    returns ‖(W−Θ)C‖_F (per item when batched), summed exactly from the f32
    accumulator blocks — the PGD stopping rule consumes this instead of
    reconstructing it from Z−Θ, which cancels near convergence."""
    if w.ndim == 3:
        return _step_batched(w, theta, c, eta, bm=bm, bn=bn, bk=bk,
                             interpret=interpret,
                             with_resid_norm=with_resid_norm)
    m, k = w.shape
    k2, n = c.shape
    assert k == k2 and theta.shape == (m, k) and n == k
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        w = jnp.pad(w, ((0, pm), (0, pk)))
        theta = jnp.pad(theta, ((0, pm), (0, pk)))
    if pk or pn:
        c = jnp.pad(c, ((0, pk), (0, pn)))
    mp, kp, np_ = m + pm, k + pk, n + pn
    n_k = kp // bk
    gm, gn = mp // bm, np_ // bn
    eta_arr = jnp.full((1, 1), eta, jnp.float32)

    grid = (gm, gn, n_k)
    out, nrm = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # W[i, k]
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # Θ[i, k]
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # C[k, j]
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),    # Θ[i, j]
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),      # η
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),
        ],
        out_shape=[jax.ShapeDtypeStruct((mp, np_), w.dtype),
                   jax.ShapeDtypeStruct((gm, gn), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(w, theta, c, theta, eta_arr)
    z = out[:m, :n]
    if not with_resid_norm:
        return z
    return z, jnp.sqrt(nrm.sum())


__all__ = ["awp_pgd_step"]
