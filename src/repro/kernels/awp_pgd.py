"""Pallas TPU kernel: fused AWP PGD gradient step  Z = Θ + η·(W−Θ)·C.

The paper's inner-loop hot spot (O(d_out·d_in²) per iteration, §3). TPU
mapping: classic MXU matmul tiling with the subtract folded into the LHS
load and the scale+add epilogue fused into the final K-step — one VMEM
round-trip instead of three separate HLO ops (sub → dot → fma).

Grid (M/bm, N/bn, K/bk); K innermost so the f32 accumulator scratch lives in
VMEM across the contraction. Θ is passed twice with different index maps:
as Θ[i,k] for the residual and Θ[i,j] for the epilogue add.
Block defaults are 128-aligned for the 128×128 MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(w_ref, theta_k_ref, c_ref, theta_out_ref, eta_ref, z_ref, acc_ref,
            *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    resid = (w_ref[...] - theta_k_ref[...]).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(
        resid, c_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        eta = eta_ref[0, 0]
        z_ref[...] = (theta_out_ref[...].astype(jnp.float32)
                      + eta * acc_ref[...]).astype(z_ref.dtype)


def awp_pgd_step(w: jax.Array, theta: jax.Array, c: jax.Array, eta,
                 *, bm: int = 128, bn: int = 128, bk: int = 128,
                 interpret: bool = False) -> jax.Array:
    """One PGD gradient step (no projection). w, theta: (M, K); c: (K, N=K)."""
    m, k = w.shape
    k2, n = c.shape
    assert k == k2 and theta.shape == (m, k) and n == k
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        w = jnp.pad(w, ((0, pm), (0, pk)))
        theta = jnp.pad(theta, ((0, pm), (0, pk)))
    if pk or pn:
        c = jnp.pad(c, ((0, pk), (0, pn)))
    mp, kp, np_ = m + pm, k + pk, n + pn
    n_k = kp // bk
    eta_arr = jnp.full((1, 1), eta, jnp.float32)

    grid = (mp // bm, np_ // bn, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # W[i, k]
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # Θ[i, k]
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # C[k, j]
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),    # Θ[i, j]
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),      # η
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), w.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(w, theta, c, theta, eta_arr)
    return out[:m, :n]


__all__ = ["awp_pgd_step"]
