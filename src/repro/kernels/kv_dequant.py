"""Pallas TPU kernel: INT8 KV-cache dequant — the attention-read side of the
quantized slot cache (``repro.serving.kv_cache``).

Layout mirrors ``dequant_matmul``'s weight side: codes (R, K) uint8 with
scale/zero (R, K/group) — the caller flattens (L·)S·T·Hk leading dims into
rows R and folds heads into K, so K = Hk·D is lane-aligned for real head
dims. Grid (R/bm, K/bk), bk a multiple of group_size so each block sees
whole groups; pure VPU elementwise expansion, emitted as the requested
float dtype (HBM keeps the 1-byte codes, VMEM gets the floats).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(codes_ref, scale_ref, zero_ref, out_ref, *, group: int):
    codes = codes_ref[...].astype(jnp.float32)          # (bm, bk)
    bm, bk = codes.shape
    g = codes.reshape(bm, bk // group, group)
    deq = (g - zero_ref[...].astype(jnp.float32)[..., None]) \
        * scale_ref[...].astype(jnp.float32)[..., None]
    out_ref[...] = deq.reshape(bm, bk).astype(out_ref.dtype)


def kv_dequant(codes: jax.Array, scale: jax.Array, zero: jax.Array, *,
               group_size: int, out_dtype=jnp.float32,
               bm: int = 256, bk: int = 512,
               interpret: bool = False) -> jax.Array:
    """codes: (R, K) uint8; scale/zero: (R, K//group) f16/f32 → (R, K) float."""
    r, k = codes.shape
    assert k % group_size == 0
    assert scale.shape == (r, k // group_size) == zero.shape
    bk = max(group_size, (min(bk, k) // group_size) * group_size)
    bm = min(bm, -(-r // 8) * 8)
    pr, pk = (-r) % bm, (-k) % bk
    if pr or pk:
        codes = jnp.pad(codes, ((0, pr), (0, pk)))
        scale = jnp.pad(scale, ((0, pr), (0, pk // group_size)),
                        constant_values=1.0)
        zero = jnp.pad(zero, ((0, pr), (0, pk // group_size)))
    rp, kp = r + pr, k + pk
    sg = bk // group_size

    out = pl.pallas_call(
        functools.partial(_kernel, group=group_size),
        grid=(rp // bm, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, sg), lambda i, j: (i, j)),
            pl.BlockSpec((bm, sg), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, kp), out_dtype),
        interpret=interpret,
    )(codes, scale, zero)
    return out[:r, :k]


__all__ = ["kv_dequant"]
