"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile natively. ``use_pallas=False`` falls back to the jnp oracle — the
switch the perf harness flips when comparing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import awp_pgd as _awp_pgd
from repro.kernels import topk_mask as _topk
from repro.kernels import quant_proj as _quant
from repro.kernels import dequant_matmul as _dq
from repro.kernels import kv_dequant as _kv
from repro.kernels import decode_attn as _da
from repro.kernels import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def awp_pgd_step(w, theta, c, eta, use_pallas: bool = True):
    """Fused Z = Θ + η(W−Θ)C. Accepts (M, K) or batched (B, M, K) operands
    (per-item η allowed in the batched form — one program per shape bucket)."""
    if not use_pallas:
        return ref.awp_pgd_step(w, theta, c, eta)
    return _awp_pgd.awp_pgd_step(w, theta, c, eta, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("k", "use_pallas"))
def topk_row(z, k: int, use_pallas: bool = True):
    if not use_pallas:
        return ref.topk_row(z, k)
    return _topk.topk_row(z, k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "use_pallas"))
def quant_project(z, bits: int, group_size: int = 128, use_pallas: bool = True):
    if not use_pallas:
        return ref.quant_project(z, bits, group_size)
    return _quant.quant_project(z, bits, group_size, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("group_size", "use_pallas"))
def dequant_matmul(x, packed, scale, zero, group_size: int = 128,
                   use_pallas: bool = True):
    if not use_pallas:
        return ref.dequant_matmul(x, packed, scale, zero, group_size)
    return _dq.dequant_matmul(x, packed, scale, zero, group_size=group_size,
                              interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("group_size", "use_pallas"))
def kv_dequant(codes, scale, zero, group_size: int, use_pallas: bool = True):
    """INT8 KV-cache expansion: codes (R, K) uint8 + per-group scale/zero →
    (R, K) f32 (the attention-read side of the quantized slot cache)."""
    if not use_pallas:
        return ref.kv_dequant(codes, scale, zero, group_size)
    return _kv.kv_dequant(codes, scale, zero, group_size=group_size,
                          interpret=_interpret())


def _ref_dequant_kv(codes, scale, zero, group_size: int):
    """(B, T, Hk, D) codes + (B, T, Hk, D/g) planes → dense f32, via the
    flattened-row reference expansion (the dequant-then-attend oracle)."""
    lead = codes.shape[:-1]
    rows = 1
    for s in lead:
        rows *= s
    flat = ref.kv_dequant(codes.reshape(rows, codes.shape[-1]),
                          scale.reshape(rows, -1), zero.reshape(rows, -1),
                          group_size)
    return flat.reshape(codes.shape)


@functools.partial(jax.jit, static_argnames=("group_size", "block_t",
                                             "use_pallas"))
def decode_attn(q, k, v, lengths, k_scale=None, k_zero=None, v_scale=None,
                v_zero=None, group_size: int = 0, block_t: int = 256,
                use_pallas: bool = True):
    """Fused flash-decode over a slot-layout cache: q (B, H, D) one token
    per row against k/v (B, T, Hk, D) — dense floats, or uint8 codes with
    per-head-group scale/zero planes dequantized in-tile. ``lengths`` (B,)
    bounds each row's K loop (length 0 → zero output). The jnp oracle
    (``use_pallas=False``) dequantizes then attends — the pre-fusion
    reference path."""
    if not use_pallas:
        if k_scale is not None:
            k = _ref_dequant_kv(k, k_scale, k_zero, group_size)
            v = _ref_dequant_kv(v, v_scale, v_zero, group_size)
        return ref.decode_attn(q, k, v, lengths)
    return _da.flash_decode(q, k, v, lengths, k_scale=k_scale, k_zero=k_zero,
                            v_scale=v_scale, v_zero=v_zero,
                            group_size=group_size, block_t=block_t,
                            interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("group_size", "use_pallas"))
def decode_attn_paged(q, k, v, table, lengths, k_scale=None, k_zero=None,
                      v_scale=None, v_zero=None, group_size: int = 0,
                      use_pallas: bool = True):
    """Paged fused flash-decode: k/v are per-layer page pools
    (P, page, Hk, D) (same dense/INT8 split as :func:`decode_attn`) and
    ``table`` (B, n_pages) int32 routes each row's positions to physical
    pages — gathered tile-by-tile in the kernel's index maps, sentinel
    entries (== P) masked. The oracle gathers the contiguous view then
    dequantizes and attends."""
    if not use_pallas:
        if k_scale is not None:
            k = _ref_dequant_kv(k, k_scale, k_zero, group_size)
            v = _ref_dequant_kv(v, v_scale, v_zero, group_size)
        return ref.decode_attn_paged(q, k, v, table, lengths)
    return _da.flash_decode(q, k, v, lengths, k_scale=k_scale, k_zero=k_zero,
                            v_scale=v_scale, v_zero=v_zero,
                            group_size=group_size, table=table,
                            interpret=_interpret())


def awp_prune_fused(w, c, k: int, eta, iters: int, theta0=None,
                    use_pallas: bool = True):
    """Full AWP pruning loop on the kernel path: fused PGD step + bisection
    top-k per iteration (the production compression inner loop)."""
    theta = w if theta0 is None else theta0
    def body(theta, _):
        z = awp_pgd_step(w, theta, c, eta, use_pallas=use_pallas)
        return topk_row(z, k, use_pallas=use_pallas), None
    theta, _ = jax.lax.scan(body, theta, None, length=iters)
    return theta


__all__ = ["awp_pgd_step", "topk_row", "quant_project", "dequant_matmul",
           "kv_dequant", "decode_attn", "decode_attn_paged",
           "awp_prune_fused"]
