"""Pallas TPU kernel: group-wise asymmetric INT-b quantize-dequantize —
the Proj_{C_INTb} projection (AWQ/GPTQ convention, group_size=128).

One VMEM pass: per (row, group) min/max reduction → scale/zero → round/
clamp → dequant, all fused. Groups tile the lane dimension so the
reductions are segment-local; no scratch, no cross-block communication.

Grid: (rows/bm, d_in/bn) with bn a multiple of group_size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, out_ref, *, bits: int, group: int):
    z = z_ref[...].astype(jnp.float32)
    bm, bn = z.shape
    g = z.reshape(bm, bn // group, group)
    qmax = float(2 ** bits - 1)
    gmax = g.max(axis=-1, keepdims=True)
    gmin = g.min(axis=-1, keepdims=True)
    scale = jnp.maximum((gmax - gmin) / qmax, 1e-8)
    zero = jnp.clip(jnp.round(-gmin / scale), 0.0, qmax)
    q = jnp.clip(jnp.round(g / scale) + zero, 0.0, qmax)
    deq = (q - zero) * scale
    out_ref[...] = deq.reshape(bm, bn).astype(out_ref.dtype)


def quant_project(z: jax.Array, bits: int, group_size: int = 128, *,
                  bm: int = 128, bn: int = 512,
                  interpret: bool = False) -> jax.Array:
    rows, d = z.shape
    assert d % group_size == 0, (d, group_size)
    bn = max(group_size, (min(bn, d) // group_size) * group_size)
    bm = min(bm, rows)
    pm = (-rows) % bm
    pn = (-d) % bn
    if pm or pn:
        # pad columns by replicating the row's first group so padded groups
        # quantize harmlessly; sliced off below either way
        z = jnp.pad(z, ((0, pm), (0, pn)))
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, group=group_size),
        grid=((rows + pm) // bm, (d + pn) // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows + pm, d + pn), z.dtype),
        interpret=interpret,
    )(z)
    return out[:rows, :d]


__all__ = ["quant_project"]
