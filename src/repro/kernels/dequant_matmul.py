"""Pallas TPU kernel: fused int4 dequant-matmul  y = x · Wqᵀ — the serving
payoff of AWP compression (weights stay packed in HBM; nibbles are unpacked
and dequantized in VMEM right before the MXU contraction, so HBM traffic is
~4 bits/weight instead of 16).

Layout: packed (N, K/2) uint8 (low nibble = even k), scale/zero (N, K/group).
Grid (M/bm, N/bn, K/bk), f32 accumulator scratch, K innermost.
bk must be a multiple of group_size so each K-block sees whole groups.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wp_ref, scale_ref, zero_ref, y_ref, acc_ref,
            *, n_k: int, group: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = wp_ref[...]                                # (bn, bk//2) uint8
    lo = (packed & 0xF).astype(jnp.float32)
    hi = (packed >> 4).astype(jnp.float32)
    codes = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)  # (bn, bk)
    bn, bk = codes.shape
    g = codes.reshape(bn, bk // group, group)
    deq = (g - zero_ref[...][..., None]) * scale_ref[...][..., None]
    deq = deq.reshape(bn, bk)                           # (bn, bk) f32
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), deq,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _emit():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def _auto_bm(m: int) -> int:
    """M tile for the serving shapes: full 128 for prefill-sized M, the
    smallest f32-sublane multiple (8) covering M for decode (M = batch·1 —
    a 128-row tile would be >90% padding compute)."""
    return 128 if m >= 128 else -(-m // 8) * 8


def dequant_matmul(x: jax.Array, packed: jax.Array, scale: jax.Array,
                   zero: jax.Array, *, group_size: int = 128,
                   bm: int = 0, bn: int = 128, bk: int = 256,
                   interpret: bool = False) -> jax.Array:
    """x: (M, K) f32/bf16; packed: (N, K//2) uint8; scale/zero: (N, K//group).
    Returns (M, N) = x @ dequant(W)ᵀ. ``bm=0`` (default) picks the M tile
    from the shape — decode-shaped calls get an 8-row tile, not 128."""
    m, k = x.shape
    n = packed.shape[0]
    assert packed.shape[1] * 2 == k
    assert scale.shape == (n, k // group_size) == zero.shape
    bk = max(group_size, (min(bk, k) // group_size) * group_size)
    assert bk % group_size == 0 and bk % 2 == 0
    bm = bm or _auto_bm(m)
    bm, bn = min(bm, -(-m // 8) * 8), min(bn, n)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pn or pk:
        packed = jnp.pad(packed, ((0, pn), (0, pk // 2)))
        scale = jnp.pad(scale, ((0, pn), (0, pk // group_size)),
                        constant_values=1.0)
        zero = jnp.pad(zero, ((0, pn), (0, pk // group_size)))
    mp, np_, kp = m + pm, n + pn, k + pk
    n_k = kp // bk
    sg = bk // group_size

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, group=group_size),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // 2), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, sg), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, sg), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, scale, zero)
    return out[:m, :n]


__all__ = ["dequant_matmul"]
