"""Deterministic, sharded, resumable data pipeline.

Synthetic Zipf–Markov language corpus: next-token depends on the previous
token (Markov) with Zipfian innovations, so a small LM trained on it learns
non-trivial structure and its activations develop the correlated /
outlier-channel statistics that activation-aware compression methods exploit
(the paper's regime, reproduced without external datasets).

Determinism/fault tolerance: batch(step, shard) is a pure function of
(seed, step, shard) — restarting from a checkpointed step reproduces the
exact stream on any number of shards; no iterator state to persist.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3          # Zipf exponent for innovations
    markov_p: float = 0.75       # prob of Markov continuation vs innovation


class ZipfMarkov:
    """token_{t+1} = (a·token_t + b) mod V   w.p. markov_p   (deterministic map)
                   = Zipf(V)                 otherwise."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        # fixed affine permutation of the vocab (odd multiplier → bijective)
        rng = np.random.default_rng(cfg.seed)
        self._a = int(rng.integers(1, v // 2) * 2 + 1)
        self._b = int(rng.integers(0, v))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._cdf = np.cumsum(probs / probs.sum())
        self._perm = rng.permutation(v)       # zipf mass over shuffled ids

    def _zipf(self, u: np.ndarray) -> np.ndarray:
        idx = np.searchsorted(self._cdf, u, side="right")
        return self._perm[np.clip(idx, 0, self.cfg.vocab_size - 1)]

    def batch(self, step: int, shard: int = 0, num_shards: int = 1):
        """Return (tokens, labels) for this step/shard: pure function."""
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b_loc = cfg.global_batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard]))
        v = cfg.vocab_size
        toks = np.empty((b_loc, cfg.seq_len + 1), np.int64)
        toks[:, 0] = self._zipf(rng.random(b_loc))
        cont = rng.random((b_loc, cfg.seq_len)) < cfg.markov_p
        innov = self._zipf(rng.random((b_loc, cfg.seq_len)))
        for t in range(cfg.seq_len):
            markov_next = (self._a * toks[:, t] + self._b) % v
            toks[:, t + 1] = np.where(cont[:, t], markov_next, innov[:, t])
        return (toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32))

    def batches(self, start_step: int, num: int, shard: int = 0,
                num_shards: int = 1):
        for s in range(start_step, start_step + num):
            yield self.batch(s, shard, num_shards)


def calibration_batches(cfg: DataConfig, num: int, shard: int = 0,
                        num_shards: int = 1, seed_offset: int = 1_000_000):
    """Held-out calibration split (disjoint seed range from training)."""
    calib_cfg = dataclasses.replace(cfg, seed=cfg.seed + seed_offset)
    gen = ZipfMarkov(calib_cfg)
    return [gen.batch(i, shard, num_shards) for i in range(num)]


__all__ = ["DataConfig", "ZipfMarkov", "calibration_batches"]
