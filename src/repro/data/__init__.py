from repro.data.pipeline import DataConfig, ZipfMarkov, calibration_batches

__all__ = ["DataConfig", "ZipfMarkov", "calibration_batches"]
