"""Token-prefix → page-sequence cache for the paged KV engine.

Shared system prompts and few-shot headers dominate production traffic;
with the page pool, their K/V only needs to exist once. This cache maps
page-aligned token prefixes to the physical pages already holding their
K/V, so a request whose prompt starts with a cached prefix is admitted
*copy-free*: its block table points at the shared pages, and only the
suffix (everything past the matched pages) runs through prefill.

Prefix-hash contract
--------------------
Entries are keyed by a rolling chain hash: ``h_i = blake2b(h_{i-1} ||
tokens[i·page : (i+1)·page])``. Matching walks pages left to right and
stops at the first miss, so a hit is always a *prefix* of pages and two
prompts sharing i pages share exactly the first i entries. Only FULL pages
are ever cached (a partial page's contents depend on what decode appends
later), and a match is capped at ``(prompt_len - 1) // page`` pages so
every admitted request still prefills at least one real token (the engine
needs a last-token logit to sample from).

Copy-on-write, by construction: shared pages are never written after
insertion. A request diverging at page i gets page i freshly allocated
(the "copy"), writes only there, and the shared pages 0..i-1 stay
byte-stable — the CoW test pins this down.

Refcounts: the cache holds one reference per cached page (on top of the
owning request's), so cached pages survive their creator's retirement.
Eviction walks LRU-first and only drops entries whose page would actually
free (refcount 1 — no live request uses it); an interior eviction makes
deeper entries unreachable for matching, but they stay refcounted and age
out of the LRU themselves, so no page leaks.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import List, Tuple

import numpy as np

from repro.serving.paging import PageAllocator


class PrefixCache:
    """Page-granularity prefix reuse over a :class:`PageAllocator`."""

    def __init__(self, page_size: int, allocator: PageAllocator):
        self.page_size = page_size
        self.alloc = allocator
        self._entries: "OrderedDict[bytes, int]" = OrderedDict()  # hash->page
        self.evicted_pages = 0
        self.inserted_pages = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def cached_pages(self) -> int:
        return len(self._entries)

    def pages(self) -> List[int]:
        """Every page the cache currently holds a reference on (including
        entries made unreachable by an interior eviction) — the engine's
        invariant checker reconciles refcounts against this."""
        return list(self._entries.values())

    def _hashes(self, tokens, n_pages: int):
        pg = self.page_size
        toks = np.asarray(tokens, np.int32)
        h = b""
        for i in range(n_pages):
            h = hashlib.blake2b(h + toks[i * pg:(i + 1) * pg].tobytes(),
                                digest_size=16).digest()
            yield h

    # -- lookup ------------------------------------------------------------
    def match(self, tokens) -> Tuple[List[int], int]:
        """Longest cached page-prefix of ``tokens`` → (pages, n_tokens).

        Takes one reference on every matched page (the caller's block table
        owns them from here; roll back with ``allocator.decref`` if the
        admission is abandoned). Capped below the full prompt so at least
        one token remains to prefill."""
        limit = (len(tokens) - 1) // self.page_size
        pages: List[int] = []
        for h in self._hashes(tokens, limit):
            page = self._entries.get(h)
            if page is None:
                break
            self._entries.move_to_end(h)
            pages.append(page)
        if pages:
            self.alloc.incref(pages)
        return pages, len(pages) * self.page_size

    # -- insert ------------------------------------------------------------
    def insert(self, tokens, pages: List[int]) -> int:
        """Cache the full prompt pages of a just-prefilled request.

        ``pages`` is the request's block-table page run; only the first
        ``len(tokens) // page_size`` (full) pages are cached. Pages already
        cached (the matched prefix, or a concurrent twin's insert) are
        touched, not re-inserted — the twin keeps its private copy. Returns
        the number of newly cached pages (each gains a cache reference)."""
        n = min(len(tokens) // self.page_size, len(pages))
        added = 0
        for i, h in enumerate(self._hashes(tokens, n)):
            if h in self._entries:
                self._entries.move_to_end(h)
                continue
            self._entries[h] = pages[i]
            self.alloc.incref([pages[i]])
            added += 1
        self.inserted_pages += added
        return added

    # -- eviction ----------------------------------------------------------
    def evict(self, need: int = 1) -> int:
        """Free up to ``need`` pages by dropping LRU entries whose page is
        only held by the cache (refcount 1). Returns pages actually freed."""
        freed = 0
        for h in list(self._entries):
            if freed >= need:
                break
            page = self._entries[h]
            if self.alloc.refcount(page) == 1:
                del self._entries[h]
                freed += self.alloc.decref([page])
        self.evicted_pages += freed
        return freed

    def clear(self) -> None:
        """Release every cached page (warmup teardown)."""
        for page in self._entries.values():
            self.alloc.decref([page])
        self._entries.clear()


__all__ = ["PrefixCache"]
