"""Per-request token sampling, folded into the jitted serving steps.

Each slot carries its own (temperature, top_k, seed) as *array* inputs to
the step functions — one compiled program serves any mix of greedy and
sampled requests, and decode still transfers one int32 per slot per step
(the PR-3 argmax-folding convention, generalized).

Determinism: the sampling key for a request's n-th generated token is
``fold_in(PRNGKey(seed), n)`` — it depends only on the request's seed and
the token index, never on which slot the request landed in or what else is
in the batch, so replays and slot reshuffles reproduce bit-identical
streams.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode policy. ``temperature == 0`` → greedy (argmax);
    ``top_k == 0`` → no truncation. ``top_k`` is truncated to the engine's
    static ``max_top_k`` (the top-k filter ranks inside a fixed-size
    ``lax.top_k`` so per-slot k stays a traced value)."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


def sample_tokens(logits: jax.Array, temps: jax.Array, top_ks: jax.Array,
                  seeds: jax.Array, steps: jax.Array, *,
                  max_top_k: int = 64) -> jax.Array:
    """logits (B, V) → (B,) int32 tokens under per-row sampling params.

    temps/top_ks/seeds/steps are (B,) arrays; ``steps`` is the per-request
    generated-token index used to fold the key. Rows with temp <= 0 take
    the argmax (bit-identical to the greedy static path)."""
    b, v = logits.shape
    kk = min(max_top_k, v)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(lg, temp, k, seed, step):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        scaled = lg.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
        if kk > 0:
            vals = jax.lax.top_k(scaled, kk)[0]
            thr = vals[jnp.clip(k - 1, 0, kk - 1)]
            scaled = jnp.where((k > 0) & (scaled < thr), -jnp.inf, scaled)
        return jax.random.categorical(key, scaled).astype(jnp.int32)

    sampled = jax.vmap(one)(logits, temps.astype(jnp.float32),
                            top_ks.astype(jnp.int32),
                            seeds.astype(jnp.uint32),
                            steps.astype(jnp.uint32))
    return jnp.where(temps <= 0.0, greedy, sampled)


__all__ = ["SamplingParams", "sample_tokens"]
