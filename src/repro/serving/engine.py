"""Continuous-batching serving engine: variable-length requests in fixed
device slots, no recompilation after warmup.

The engine owns a slot-indexed KV cache (``serving/kv_cache.py``; S slots ×
max_len tokens, dense or INT8 per-head-group quantized) and three jitted
step functions:

- **prefill** (one compile per (prompt bucket, batch bucket) pair): runs
  the model over a whole same-bucket admission batch of right-padded
  prompts against a fresh (L, B, W) mini-cache, gathers logits at each
  row's true last token, samples the first output tokens on device, and
  splices the B mini-caches into the admitted slots' rows in ONE dispatch
  (``write_slot``; batch sizes round up to pow2 batch buckets, padding
  rows carry slot == num_slots so their writes are dropped);
- **chunk** (one compile, ever): one bucket-width chunk of a prompt LONGER
  than the largest bucket, run against the slot's own cache rows — the
  chunk's K/V is written at [start, start+W) and attention reads the cache
  under the offset causal mask (``model.prefill_chunk``), so max_len-scale
  prompts serve without a max_len-wide compile;
- **decode** (one compile, ever): one token for ALL slots at once — each
  slot reads/writes the cache at its own position (``pos`` is a vector),
  per-slot sampling params ride along as arrays, and exactly one int32 per
  slot crosses the device boundary per step.

The host-side :class:`~repro.serving.scheduler.Scheduler` feeds it: FIFO
admission onto the slot free-list (``admit_batch`` groups the FIFO head-run
by prompt bucket so a burst of B same-bucket arrivals costs one device call
instead of B), prompt-length bucketing (the only shape degree of freedom
besides the batch bucket), retire-on-completion. Retired slots keep
decoding garbage at position 0 until reused — their writes land below the
next request's prefill splice and are never attended.

`launch/serve.py --engine continuous` drives it; `benchmarks/engine_bench.py`
load-tests it (Zipf, burst, and long-prompt traces) into
``results/BENCH_engine.json``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.kv_cache import (KVCacheConfig, cache_bytes,
                                    init_slot_cache, set_slot_rows,
                                    slot_rows, write_slot)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import (AdmittedBatch, GenerationRequest,
                                     GenerationResult, Scheduler)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine shape/storage policy. ``kv_quantized`` switches the slot
    cache to INT8 per-head-group storage (``kv_group_size=0`` → one group
    per head); ``prompt_buckets=()`` → power-of-two buckets covering
    max_len. A custom ``prompt_buckets`` whose largest bucket is smaller
    than max_len turns prompts beyond it into chunked prefills."""
    num_slots: int = 8
    max_len: int = 256
    prompt_buckets: tuple = ()
    kv_dtype: Any = jnp.float32
    kv_quantized: bool = False
    kv_group_size: int = 0
    max_top_k: int = 64


def batch_buckets(num_slots: int) -> tuple:
    """Power-of-two prefill batch buckets 1, 2, … covering num_slots."""
    out, b = [], 1
    while b < num_slots:
        out.append(b)
        b *= 2
    out.append(b)
    return tuple(out)


class Engine:
    """Slot-based continuous batching over a fixed-shape decode program."""

    def __init__(self, model, params, cfg: EngineConfig = EngineConfig()):
        mcfg = model.cfg
        if mcfg.family not in ("dense", "moe") or mcfg.frontend:
            raise ValueError(
                f"engine serves token-LM families (dense/moe), got "
                f"{mcfg.family}/{mcfg.frontend}")
        self.model, self.params, self.cfg = model, params, cfg
        self.scheduler = Scheduler(cfg.num_slots, cfg.max_len,
                                   cfg.prompt_buckets)
        self.batch_buckets = batch_buckets(cfg.num_slots)
        kv_cfg = KVCacheConfig(num_slots=cfg.num_slots, max_len=cfg.max_len,
                               dtype=cfg.kv_dtype, quantized=cfg.kv_quantized,
                               group_size=cfg.kv_group_size)
        cache = init_slot_cache(mcfg, kv_cfg)
        self.kv = {"k": cache["k"], "v": cache["v"]}   # pos lives host-side
        s = cfg.num_slots
        self._pos = np.zeros(s, np.int32)
        self._tok = np.zeros(s, np.int32)
        self._temps = np.zeros(s, np.float32)
        self._topks = np.zeros(s, np.int32)
        self._seeds = np.zeros(s, np.uint32)
        self._steps = np.zeros(s, np.uint32)
        self._results: Dict[int, GenerationResult] = {}
        self._done: List[GenerationResult] = []
        self._reset_counters()
        self._prefill, self._chunk, self._decode = self._make_step_fns()

    def _reset_counters(self) -> None:
        self.decode_steps = 0
        self.active_slot_steps = 0
        self.prefill_dispatches = 0     # batched-prefill device calls
        self.prefill_admitted = 0       # requests admitted via those calls
        self.chunk_dispatches = 0       # chunked-prefill device calls
        self.chunked_admitted = 0       # requests admitted via chunking

    # -- jitted steps ------------------------------------------------------
    def _make_step_fns(self):
        model, cfg = self.model, self.cfg
        mcfg = model.cfg
        mini_dtype = jnp.float32 if cfg.kv_quantized else cfg.kv_dtype

        def prefill_fn(params, kv, tokens, lengths, slots, temps, topks,
                       seeds):
            b, w = tokens.shape
            zeros = jnp.zeros((mcfg.num_layers, b, w, mcfg.num_kv_heads,
                               mcfg.resolved_head_dim), mini_dtype)
            mini = {"k": zeros, "v": zeros, "pos": jnp.zeros((), jnp.int32)}
            logits, mini = model.prefill_at(params, {"tokens": tokens},
                                            mini, lengths=lengths)
            toks = sample_tokens(logits[:, 0, :], temps, topks, seeds,
                                 jnp.zeros((b,), jnp.uint32),
                                 max_top_k=cfg.max_top_k)
            kv = write_slot(kv, slots, mini["k"], mini["v"])
            return toks, kv

        def chunk_fn(params, kv, tokens, start, length, slot, temp, topk,
                     seed):
            row = {"k": slot_rows(kv["k"], slot),
                   "v": slot_rows(kv["v"], slot), "pos": start}
            logits, row = model.prefill_chunk(params, {"tokens": tokens},
                                              row, lengths=length[None])
            tok = sample_tokens(logits[:, 0, :], temp[None], topk[None],
                                seed[None], jnp.zeros((1,), jnp.uint32),
                                max_top_k=cfg.max_top_k)
            kv = {"k": set_slot_rows(kv["k"], slot, row["k"]),
                  "v": set_slot_rows(kv["v"], slot, row["v"])}
            return tok[0], kv

        def decode_fn(params, kv, pos, tokens, temps, topks, seeds, steps):
            cache = {"k": kv["k"], "v": kv["v"], "pos": pos}
            logits, cache = model.decode_step(params, tokens, cache)
            tok = sample_tokens(logits[:, 0, :], temps, topks, seeds, steps,
                                max_top_k=cfg.max_top_k)
            return tok, {"k": cache["k"], "v": cache["v"]}

        return (jax.jit(prefill_fn, donate_argnums=1),
                jax.jit(chunk_fn, donate_argnums=1),
                jax.jit(decode_fn, donate_argnums=1))

    # -- request API -------------------------------------------------------
    def submit(self, req: GenerationRequest) -> None:
        self.scheduler.submit(req)
        self._results[req.rid] = GenerationResult(
            rid=req.rid, prompt_len=req.prompt_len, tokens=[],
            t_enqueue=time.perf_counter())

    def warmup(self, reqs) -> Dict[str, int]:
        """Compile every program a trace shaped like ``reqs`` can hit
        before timing starts:

        - for each distinct prompt bucket in ``reqs``, the full
          (bucket × batch-bucket) prefill grid, traced with all-padding
          dummy batches (slot index == num_slots, so every cache write is
          dropped);
        - the chunked-prefill program (one dummy chunk) if any request's
          prompt exceeds the largest bucket;
        - the decode program, via one short clone per distinct bucket
          (prompt clipped to max_len - 1 so the clone's >= 1-token budget
          always fits) plus a minimal 2-token fallback request if none of
          the clones had decode headroom.

        Warmup requires an IDLE engine: it drains the scheduler, so real
        requests submitted beforehand would be silently executed and their
        results discarded — it raises instead. Clones use negative rids
        (callers' traces use non-negative ones) and are filtered from the
        caller-visible results explicitly. Resets the dispatch/utilization
        counters and returns the post-warmup :meth:`compile_counts`
        snapshot."""
        if not self.scheduler.idle:
            raise RuntimeError(
                "Engine.warmup on a non-idle engine: warmup drains the "
                "scheduler, which would silently execute and discard "
                "already-submitted requests — warm up first, then submit")
        wmax = self.scheduler.buckets[-1]
        seen: Dict[int, GenerationRequest] = {}
        chunked = False
        for r in reqs:
            if r.prompt_len > wmax:
                chunked = True
            else:
                seen.setdefault(self.scheduler.bucket_for(r.prompt_len), r)

        # (bucket × batch-bucket) prefill grid: all-padding dummy batches
        drop = self.cfg.num_slots                  # OOB slot ⇒ writes dropped
        for w in sorted(seen):
            for bb in self.batch_buckets:
                tok_dev, self.kv = self._prefill(
                    self.params, self.kv,
                    jnp.zeros((bb, w), jnp.int32),
                    jnp.ones((bb,), jnp.int32),
                    jnp.full((bb,), drop, jnp.int32),
                    jnp.zeros((bb,), jnp.float32),
                    jnp.zeros((bb,), jnp.int32),
                    jnp.zeros((bb,), jnp.uint32))
        if chunked:
            # one dummy chunk compiles the (single) chunk program; the
            # garbage it writes into slot 0 sits beyond every causal mask
            # until the slot's next prefill overwrites it (engine is idle)
            tok_dev, self.kv = self._chunk(
                self.params, self.kv, jnp.zeros((1, wmax), jnp.int32),
                np.int32(0), np.int32(1), np.int32(0), np.float32(0.0),
                np.int32(0), np.uint32(0))

        # end-to-end clones (decode program + host bookkeeping paths)
        wid = -1
        decode_warmed = False
        for _, r in sorted(seen.items()):
            plen = min(r.prompt_len, self.cfg.max_len - 1)
            nnew = min(2, self.cfg.max_len - plen)     # >= 1 by construction
            decode_warmed |= nnew >= 2
            self.submit(GenerationRequest(rid=wid, prompt=r.prompt[:plen],
                                          max_new_tokens=nnew,
                                          sampling=r.sampling))
            wid -= 1
        if (seen or chunked) and not decode_warmed:
            self.submit(GenerationRequest(
                rid=wid, prompt=np.asarray([1], np.int32), max_new_tokens=2))
        real = [r for r in self.run() if r.rid >= 0]
        self._done.extend(real)        # unreachable under the idle guard
        self._reset_counters()
        return self.compile_counts()

    def step(self) -> None:
        """Admit every admissible request (one batched prefill dispatch per
        same-bucket FIFO head-run, chunked prefill for beyond-largest-bucket
        prompts), then run one decode step for all slots."""
        sched = self.scheduler
        while (batch := sched.admit_batch()) is not None:
            if batch.chunked:
                self._run_chunked(*batch.items[0])
            else:
                self._run_prefill_batch(batch)

        if sched.num_active == 0:
            return
        tok_dev, self.kv = self._decode(
            self.params, self.kv, jnp.asarray(self._pos),
            jnp.asarray(self._tok[:, None]), jnp.asarray(self._temps),
            jnp.asarray(self._topks), jnp.asarray(self._seeds),
            jnp.asarray(self._steps))
        toks = np.asarray(tok_dev)            # one int32 per slot per step
        now = time.perf_counter()
        self.decode_steps += 1
        self.active_slot_steps += sched.num_active
        for slot in sched.active_slots():
            state = sched.slots[slot]
            tok = int(toks[slot])
            state.generated += 1
            self._results[state.request.rid].tokens.append(tok)
            self._pos[slot] += 1
            self._tok[slot] = tok
            self._steps[slot] += 1
            if state.done or tok == state.request.eos_id:
                self._finish(slot, now)

    def _run_prefill_batch(self, batch: AdmittedBatch) -> None:
        """One device dispatch for a whole same-bucket admission batch."""
        b, w = len(batch.items), batch.bucket
        bb = next(x for x in self.batch_buckets if b <= x)
        tokens = np.zeros((bb, w), np.int32)
        lengths = np.ones((bb,), np.int32)
        slots = np.full((bb,), self.cfg.num_slots, np.int32)  # pad: dropped
        temps = np.zeros((bb,), np.float32)
        topks = np.zeros((bb,), np.int32)
        seeds = np.zeros((bb,), np.uint32)
        for i, (slot, req) in enumerate(batch.items):
            tokens[i, :req.prompt_len] = req.prompt
            lengths[i] = req.prompt_len
            slots[i] = slot
            sp = req.sampling
            temps[i], topks[i] = sp.temperature, sp.top_k
            seeds[i] = np.uint32(sp.seed)
        tok_dev, self.kv = self._prefill(
            self.params, self.kv, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(slots), jnp.asarray(temps), jnp.asarray(topks),
            jnp.asarray(seeds))
        toks = np.asarray(tok_dev)            # B first tokens, one transfer
        self.prefill_dispatches += 1
        self.prefill_admitted += b
        now = time.perf_counter()
        for i, (slot, req) in enumerate(batch.items):
            self._record_first_token(slot, req, int(toks[i]), now)

    def _run_chunked(self, slot: int, req: GenerationRequest) -> None:
        """Stream a beyond-largest-bucket prompt through the bucket-width
        chunk program against the slot's own cache rows. Only the final
        chunk's sample is real; intermediate device results are never
        synced."""
        w = self.scheduler.buckets[-1]
        p, sp = req.prompt_len, req.sampling
        tok_dev = None
        for start in range(0, p, w):
            clen = min(w, p - start)
            chunk = np.zeros((1, w), np.int32)
            chunk[0, :clen] = req.prompt[start:start + clen]
            tok_dev, self.kv = self._chunk(
                self.params, self.kv, jnp.asarray(chunk), np.int32(start),
                np.int32(clen), np.int32(slot), np.float32(sp.temperature),
                np.int32(sp.top_k), np.uint32(sp.seed))
            self.chunk_dispatches += 1
        self.chunked_admitted += 1
        self._record_first_token(slot, req, int(tok_dev),
                                 time.perf_counter())

    def _record_first_token(self, slot: int, req: GenerationRequest,
                            tok: int, now: float) -> None:
        res = self._results[req.rid]
        res.t_first_token = now
        res.tokens.append(tok)
        state = self.scheduler.slots[slot]
        state.generated = 1
        sp = req.sampling
        self._pos[slot] = req.prompt_len
        self._tok[slot] = tok
        self._temps[slot] = sp.temperature
        self._topks[slot] = sp.top_k
        self._seeds[slot] = np.uint32(sp.seed)
        self._steps[slot] = 1
        if state.done or tok == req.eos_id:
            self._finish(slot, now)

    def _finish(self, slot: int, now: float) -> None:
        req = self.scheduler.retire(slot)
        res = self._results.pop(req.rid)
        res.t_finish = now
        self._done.append(res)
        # park the freed slot: greedy token 0 at position 0, overwritten by
        # the next admission's prefill before it is ever attended
        self._pos[slot] = 0
        self._tok[slot] = 0
        self._temps[slot] = 0.0
        self._topks[slot] = 0
        self._seeds[slot] = 0
        self._steps[slot] = 0

    def run(self, max_steps: int = 1_000_000) -> List[GenerationResult]:
        """Drive until every submitted request completes; returns results
        in completion order."""
        for _ in range(max_steps):
            if self.scheduler.idle:
                break
            self.step()
        assert self.scheduler.idle, "engine stopped with work outstanding"
        out, self._done = self._done, []
        return out

    # -- introspection -----------------------------------------------------
    def compile_counts(self) -> Dict[str, Optional[int]]:
        """Compiled-program counts (prefill: one per (prompt bucket, batch
        bucket) pair seen; chunk: 1 when the trace has beyond-largest-bucket
        prompts; decode: 1). Flat across a post-warmup trace ⇔ no
        recompilation. ``None`` when the jit cache size is unavailable
        (private jax API moved) — callers must treat that as UNKNOWN, never
        as "no recompilation"."""
        def size(f) -> Optional[int]:
            try:
                return int(f._cache_size())
            except Exception:
                return None
        return {"prefill": size(self._prefill), "chunk": size(self._chunk),
                "decode": size(self._decode)}

    def kv_cache_bytes(self) -> int:
        return cache_bytes(self.kv)

    def utilization(self) -> float:
        if self.decode_steps == 0:
            return 0.0
        return self.active_slot_steps / (self.decode_steps
                                         * self.cfg.num_slots)


__all__ = ["Engine", "EngineConfig", "GenerationRequest", "GenerationResult",
           "batch_buckets"]
