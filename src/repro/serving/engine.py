"""Continuous-batching serving engine: variable-length requests in fixed
device slots, no recompilation after warmup.

The engine owns a slot-indexed KV cache (``serving/kv_cache.py``; S slots ×
max_len tokens, dense or INT8 per-head-group quantized) and three jitted
step functions:

- **prefill** (one compile per (prompt bucket, batch bucket) pair): runs
  the model over a whole same-bucket admission batch of right-padded
  prompts against a fresh (L, B, W) mini-cache, gathers logits at each
  row's true last token, samples the first output tokens on device, and
  splices the B mini-caches into the admitted slots' rows in ONE dispatch
  (``write_slot``; batch sizes round up to pow2 batch buckets, padding
  rows carry slot == num_slots so their writes are dropped);
- **chunk** (one compile, ever): one bucket-width chunk of a prompt LONGER
  than the largest bucket, run against the slot's own cache rows — the
  chunk's K/V is written at [start, start+W) and attention reads the cache
  under the offset causal mask (``model.prefill_chunk``), so max_len-scale
  prompts serve without a max_len-wide compile;
- **decode** (one compile, ever): one token for ALL slots at once — each
  slot reads/writes the cache at its own position (``pos`` is a vector),
  per-slot sampling params ride along as arrays, and exactly one int32 per
  slot crosses the device boundary per step.

The host-side :class:`~repro.serving.scheduler.Scheduler` feeds it: FIFO
admission onto the slot free-list (``admit_batch`` groups the FIFO head-run
by prompt bucket so a burst of B same-bucket arrivals costs one device call
instead of B), prompt-length bucketing (the only shape degree of freedom
besides the batch bucket), retire-on-completion. Retired slots keep
decoding garbage at position 0 until reused — their writes land below the
next request's prefill splice and are never attended.

``kv_layout="paged"`` (the paged subsystem: ``serving/paging.py`` +
``serving/prefix_cache.py``, docs/serving.md §paging) swaps the slot rows
for a fixed pool of fixed-size pages routed through per-slot block tables:
prefill splices through per-row page maps, the chunk program reads the
pool via in-tile paged flash, decode gathers each slot's pages. On top of
the indirection ride copy-free shared-prefix admission (a prefix cache
maps page-aligned token prefixes to live pages; only the suffix prefills)
and preempt-and-resume (under page pressure the youngest request's pages
spill to host memory and its ResumeTicket re-enters the queue by seq).
Greedy outputs stay token-identical to the slot layout.

`launch/serve.py --engine continuous` drives it (``--kv paged|slots``);
`benchmarks/engine_bench.py` load-tests it (Zipf, burst, long-prompt,
shared-prefix, and overload/preemption traces) into
``results/BENCH_engine.json``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import MetricsRegistry, Trace
from repro.serving.kv_cache import (KVCacheConfig, cache_bytes,
                                    init_paged_storage, init_slot_cache,
                                    set_slot_rows, slot_rows, write_pages,
                                    write_slot)
from repro.serving.paging import (PageAllocator, restore_pages, spill_pages)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import (AdmittedBatch, DuplicateRequestError,
                                     EngineInvariantError, EngineStalledError,
                                     GenerationRequest, GenerationResult,
                                     InvalidRequestError, QueueFullError,
                                     RequestStatus, ResumeTicket, Scheduler)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine shape/storage policy. ``kv_quantized`` switches the slot
    cache to INT8 per-head-group storage (``kv_group_size=0`` → one group
    per head); ``prompt_buckets=()`` → power-of-two buckets covering
    max_len. A custom ``prompt_buckets`` whose largest bucket is smaller
    than max_len turns prompts beyond it into chunked prefills.

    ``kv_layout="paged"`` switches the cache from per-slot contiguous rows
    to a fixed pool of ``page_size``-token pages routed through per-slot
    block tables (``num_pages=0`` → num_slots · ceil(max_len/page_size),
    the same token capacity as the slot layout). Paged mode enables
    copy-free shared-prefix admission (``prefix_caching``) and
    preempt-and-resume under page pressure. Greedy output is bit-identical
    to the slot layout when ``page_size`` divides ``max_len`` (otherwise
    the gathered cache view is wider than max_len and reduction shapes
    differ by masked-out zeros). ``mixed_admission`` lets one prefill
    dispatch admit a FIFO head-run that crosses prompt buckets
    (right-padded to the largest member's bucket) — fewer dispatches,
    identical outputs.

    ``max_queue`` bounds the scheduler backlog: a submit that would push
    the queue past it raises :class:`QueueFullError` (load shedding;
    ``try_submit`` converts the raise into a terminal ``rejected``
    result). 0 → unbounded. ``stall_patience`` is how many consecutive
    no-progress steps :meth:`Engine.run` tolerates with work outstanding
    before raising :class:`EngineStalledError` (early deadlock
    detection).

    ``use_fused_decode`` (default on) routes the decode step's cache read
    through the fused Pallas flash-decode kernel — INT8 codes dequantize
    in-tile, per-slot positions bound the K loop, paged tables gather in
    the kernel — instead of dequantizing/gathering the whole cache then
    attending. ``False`` is the escape hatch back to the reference
    dequant-then-attend path (bit-exact pre-fusion numerics)."""
    num_slots: int = 8
    max_len: int = 256
    prompt_buckets: tuple = ()
    kv_dtype: Any = jnp.float32
    kv_quantized: bool = False
    kv_group_size: int = 0
    max_top_k: int = 64
    kv_layout: str = "slots"           # "slots" | "paged"
    page_size: int = 16
    num_pages: int = 0                 # 0 → auto (slot-equivalent capacity)
    prefix_caching: bool = True        # paged only
    mixed_admission: bool = False      # cross-bucket admission runs
    max_queue: int = 0                 # 0 → unbounded backlog
    stall_patience: int = 8            # no-progress steps before stalling
    use_fused_decode: bool = True      # fused flash-decode cache reads
    queue_trace_samples: int = 4096    # queue-depth ring-buffer capacity


def batch_buckets(num_slots: int) -> tuple:
    """Power-of-two prefill batch buckets 1, 2, … covering num_slots."""
    out, b = [], 1
    while b < num_slots:
        out.append(b)
        b *= 2
    out.append(b)
    return tuple(out)


def _counter_view(key: str):
    """Legacy integer counter attribute backed by a registry child."""
    return property(lambda self: int(self._c[key].value))


class Engine:
    """Slot-based continuous batching over a fixed-shape decode program."""

    def __init__(self, model, params, cfg: EngineConfig = EngineConfig(),
                 faults=None, registry: Optional[MetricsRegistry] = None):
        mcfg = model.cfg
        if mcfg.family not in ("dense", "moe") or mcfg.frontend:
            raise ValueError(
                f"engine serves token-LM families (dense/moe), got "
                f"{mcfg.family}/{mcfg.frontend}")
        self.model, self.params, self.cfg = model, params, cfg
        self.faults = faults               # FaultPlan | None (set_faults)
        self.scheduler = Scheduler(cfg.num_slots, cfg.max_len,
                                   cfg.prompt_buckets)
        self.batch_buckets = batch_buckets(cfg.num_slots)
        if cfg.kv_layout not in ("slots", "paged"):
            raise ValueError(f"kv_layout must be 'slots' or 'paged', got "
                             f"{cfg.kv_layout!r}")
        self._paged = cfg.kv_layout == "paged"
        s = cfg.num_slots
        if self._paged:
            pg = cfg.page_size
            if pg < 1:
                raise ValueError(f"page_size must be >= 1, got {pg}")
            self.pages_per_slot = -(-cfg.max_len // pg)
            num_pages = cfg.num_pages or s * self.pages_per_slot
            if num_pages < self.pages_per_slot:
                # the oldest request is unpreemptable; it must always be
                # able to grow to max_len or admission can deadlock
                raise ValueError(
                    f"num_pages {num_pages} < pages_per_slot "
                    f"{self.pages_per_slot}: one max_len request must fit")
            self.kv = init_paged_storage(
                mcfg, num_pages, pg, dtype=cfg.kv_dtype,
                quantized=cfg.kv_quantized, group_size=cfg.kv_group_size)
            self.alloc = PageAllocator(num_pages, faults=faults)
            self.prefix = (PrefixCache(pg, self.alloc)
                           if cfg.prefix_caching else None)
            # block tables are host state; rows ride to the device as plain
            # int32 data each dispatch (sentinel == num_pages everywhere a
            # slot has no page: parked slots' decode writes are dropped)
            self._table = np.full((s, self.pages_per_slot), num_pages,
                                  np.int32)
            self._slot_pages: List[List[int]] = [[] for _ in range(s)]
        else:
            kv_cfg = KVCacheConfig(num_slots=s, max_len=cfg.max_len,
                                   dtype=cfg.kv_dtype,
                                   quantized=cfg.kv_quantized,
                                   group_size=cfg.kv_group_size)
            cache = init_slot_cache(mcfg, kv_cfg)
            self.kv = {"k": cache["k"], "v": cache["v"]}  # pos is host-side
            self.alloc = None
            self.prefix = None
        self._pos = np.zeros(s, np.int32)
        self._tok = np.zeros(s, np.int32)
        self._temps = np.zeros(s, np.float32)
        self._topks = np.zeros(s, np.int32)
        self._seeds = np.zeros(s, np.uint32)
        self._steps = np.zeros(s, np.uint32)
        self._results: Dict[int, GenerationResult] = {}
        self._done: List[GenerationResult] = []
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._init_metrics()
        self._reset_counters()
        self._prefill, self._chunk, self._decode = self._make_step_fns()

    def _init_metrics(self) -> None:
        """Register the engine's metric families and bind one child per
        family under this engine's constant labels. All dispatch and
        utilization counters live here; the legacy integer attributes
        (``engine.decode_steps`` …) are read-only views over the children,
        and ``queue_stats``/``page_stats`` are views over the same state."""
        m = self.metrics
        cfg = self.cfg
        self._mlabels = {"layout": cfg.kv_layout,
                         "kv": "int8" if cfg.kv_quantized else "dense"}
        names = tuple(sorted(self._mlabels))
        self._metric_children: List[Any] = []

        def counter(key: str, help: str):
            fam = m.counter(f"engine_{key}_total", help, labelnames=names)
            child = fam.labels(**self._mlabels)
            self._metric_children.append(child)
            return child

        self._c = {k: counter(k, h) for k, h in (
            ("decode_steps", "decode dispatches"),
            ("active_slot_steps", "slot-steps that carried a live request"),
            ("prefill_dispatches", "batched-prefill device calls"),
            ("prefill_admitted", "requests admitted via batched prefill"),
            ("chunk_dispatches", "chunked-prefill device calls"),
            ("chunked_admitted", "requests admitted via chunking"),
            ("prefix_hits", "admissions with a cached prefix"),
            ("prefix_misses", "admissions without one (paged only)"),
            ("prefix_hit_tokens", "prompt tokens skipped via prefix reuse"),
            ("preemptions", "requests spilled under page pressure"),
            ("resumes", "tickets restored onto a slot"),
            ("pages_spilled", "pages round-tripped through host memory"),
            ("rejected", "try_submit load-shed rejections"),
        )}
        self._g_queue = m.gauge(
            "engine_queue_depth", "scheduler backlog, sampled per step",
            labelnames=names,
            trace_capacity=cfg.queue_trace_samples).labels(**self._mlabels)
        self._g_slots = m.gauge(
            "engine_slots_active", "slots holding a live request",
            labelnames=names).labels(**self._mlabels)
        self._g_pages = m.gauge(
            "engine_pages_in_use", "allocated KV pages (paged layout)",
            labelnames=names).labels(**self._mlabels)
        self._g_prefix_pages = m.gauge(
            "engine_prefix_cached_pages", "pages held by the prefix cache",
            labelnames=names).labels(**self._mlabels)
        self._metric_children += [self._g_queue, self._g_slots,
                                  self._g_pages, self._g_prefix_pages]

        def hist(key: str, help: str):
            fam = m.histogram(key, help, labelnames=names, unit="seconds")
            child = fam.labels(**self._mlabels)
            self._metric_children.append(child)
            return child

        self._h_queue = hist("request_queue_seconds",
                             "submit to first admission")
        self._h_ttft = hist("request_ttft_seconds",
                            "submit to first generated token")
        self._h_tpot = hist("request_tpot_seconds",
                            "mean seconds per generated token after the first")
        self._h_latency = hist("request_latency_seconds",
                               "submit to terminal status")
        # per-status counters bind their children lazily (statuses appear
        # as the trace produces them); families registered up front
        m.counter("engine_requests_total", "terminal results by status",
                  labelnames=names + ("status",))
        m.counter("engine_tokens_generated_total",
                  "generated tokens in terminal results by status",
                  labelnames=names + ("status",))
        self._status_children: Dict[tuple, Any] = {}

    def _status_counter(self, name: str, status: str):
        child = self._status_children.get((name, status))
        if child is None:
            fam = self.metrics.counter(f"engine_{name}_total")
            child = fam.labels(status=status, **self._mlabels)
            self._status_children[(name, status)] = child
            self._metric_children.append(child)
        return child

    def _reset_counters(self) -> None:
        for child in self._metric_children:
            child.reset()
        if self.alloc is not None:
            self.alloc.peak_in_use = self.alloc.pages_in_use
            self.alloc.alloc_calls = 0
            self.alloc.alloc_failures = 0

    # legacy counter attributes — read-only views over the registry
    decode_steps = _counter_view("decode_steps")
    active_slot_steps = _counter_view("active_slot_steps")
    prefill_dispatches = _counter_view("prefill_dispatches")
    prefill_admitted = _counter_view("prefill_admitted")
    chunk_dispatches = _counter_view("chunk_dispatches")
    chunked_admitted = _counter_view("chunked_admitted")
    prefix_hits = _counter_view("prefix_hits")
    prefix_misses = _counter_view("prefix_misses")
    prefix_hit_tokens = _counter_view("prefix_hit_tokens")
    preemptions = _counter_view("preemptions")
    resumes = _counter_view("resumes")
    pages_spilled = _counter_view("pages_spilled")
    rejected = _counter_view("rejected")

    @property
    def queue_depth_peak(self) -> int:
        return int(self._g_queue.peak)

    def set_faults(self, plan) -> None:
        """Attach/replace the :class:`~repro.serving.faults.FaultPlan`
        (engine hooks AND the page allocator). Attach AFTER :meth:`warmup`
        so scripted fault steps count from the first real step (warmup
        disables injection regardless)."""
        self.faults = plan
        if self.alloc is not None:
            self.alloc.faults = plan

    def _now(self) -> float:
        """Engine clock: the fault plan's virtual clock when one with
        ``slow_step_s`` is attached (deterministic deadline tests),
        wall-clock otherwise."""
        return (self.faults.now() if self.faults is not None
                else time.perf_counter())

    # -- jitted steps ------------------------------------------------------
    def _make_step_fns(self):
        model, cfg = self.model, self.cfg
        if getattr(model, "use_fused_decode", None) != cfg.use_fused_decode:
            # the flag lives on the model dataclass (it's baked into the
            # decode trace); rebind a per-engine copy, never mutate the
            # caller's model
            model = dataclasses.replace(
                model, use_fused_decode=cfg.use_fused_decode)
        mcfg = model.cfg
        mini_dtype = jnp.float32 if cfg.kv_quantized else cfg.kv_dtype
        if self._paged:
            return self._make_paged_step_fns(mini_dtype, model)

        def prefill_fn(params, kv, tokens, lengths, slots, temps, topks,
                       seeds):
            b, w = tokens.shape
            zeros = jnp.zeros((mcfg.num_layers, b, w, mcfg.num_kv_heads,
                               mcfg.resolved_head_dim), mini_dtype)
            mini = {"k": zeros, "v": zeros, "pos": jnp.zeros((), jnp.int32)}
            logits, mini = model.prefill_at(params, {"tokens": tokens},
                                            mini, lengths=lengths)
            toks = sample_tokens(logits[:, 0, :], temps, topks, seeds,
                                 jnp.zeros((b,), jnp.uint32),
                                 max_top_k=cfg.max_top_k)
            kv = write_slot(kv, slots, mini["k"], mini["v"])
            return toks, kv

        def chunk_fn(params, kv, tokens, start, length, slot, temp, topk,
                     seed):
            row = {"k": slot_rows(kv["k"], slot),
                   "v": slot_rows(kv["v"], slot), "pos": start}
            logits, row = model.prefill_chunk(params, {"tokens": tokens},
                                              row, lengths=length[None])
            tok = sample_tokens(logits[:, 0, :], temp[None], topk[None],
                                seed[None], jnp.zeros((1,), jnp.uint32),
                                max_top_k=cfg.max_top_k)
            kv = {"k": set_slot_rows(kv["k"], slot, row["k"]),
                  "v": set_slot_rows(kv["v"], slot, row["v"])}
            return tok[0], kv

        def decode_fn(params, kv, pos, tokens, temps, topks, seeds, steps):
            cache = {"k": kv["k"], "v": kv["v"], "pos": pos}
            logits, cache = model.decode_step(params, tokens, cache)
            tok = sample_tokens(logits[:, 0, :], temps, topks, seeds, steps,
                                max_top_k=cfg.max_top_k)
            # finite-logit flag rides along in the same int32 transfer: a
            # slot whose logits went non-finite fails ALONE on the host
            ok = jnp.all(jnp.isfinite(logits[:, 0, :]), axis=-1)
            out = jnp.stack([tok.astype(jnp.int32),
                             ok.astype(jnp.int32)], axis=-1)
            return out, {"k": cache["k"], "v": cache["v"]}

        return (jax.jit(prefill_fn, donate_argnums=1),
                jax.jit(chunk_fn, donate_argnums=1),
                jax.jit(decode_fn, donate_argnums=1))

    def _make_paged_step_fns(self, mini_dtype, model):
        """Paged mirrors of the three step programs. Prefill keeps the
        slot path's math exactly (same dense mini-cache, same per-row
        logit gather) and only the final splice differs — write_pages
        scatters through per-row page maps instead of slot indices — so
        paged greedy output matches the slot engine token for token.
        Chunk and decode route every cache access through a block table
        (in-tile paged flash / fused or page-gathered decode read).
        ``model`` is the caller's fused-decode rebind."""
        cfg = self.cfg
        mcfg = model.cfg
        pg = cfg.page_size

        def prefill_fn(params, kv, tokens, lengths, page_maps, temps, topks,
                       seeds):
            b, w = tokens.shape
            zeros = jnp.zeros((mcfg.num_layers, b, w, mcfg.num_kv_heads,
                               mcfg.resolved_head_dim), mini_dtype)
            mini = {"k": zeros, "v": zeros, "pos": jnp.zeros((), jnp.int32)}
            logits, mini = model.prefill_at(params, {"tokens": tokens},
                                            mini, lengths=lengths)
            toks = sample_tokens(logits[:, 0, :], temps, topks, seeds,
                                 jnp.zeros((b,), jnp.uint32),
                                 max_top_k=cfg.max_top_k)
            kv = write_pages(kv, page_maps, mini["k"], mini["v"], pg)
            return toks, kv

        def chunk_fn(params, kv, tokens, start, length, table_row, temp,
                     topk, seed):
            cache = {"k": kv["k"], "v": kv["v"], "pos": start,
                     "table": table_row}
            logits, cache = model.prefill_chunk(params, {"tokens": tokens},
                                                cache, lengths=length[None])
            tok = sample_tokens(logits[:, 0, :], temp[None], topk[None],
                                seed[None], jnp.zeros((1,), jnp.uint32),
                                max_top_k=cfg.max_top_k)
            return tok[0], {"k": cache["k"], "v": cache["v"]}

        def decode_fn(params, kv, pos, tokens, temps, topks, seeds, steps,
                      tables):
            cache = {"k": kv["k"], "v": kv["v"], "pos": pos,
                     "table": tables}
            logits, cache = model.decode_step(params, tokens, cache)
            tok = sample_tokens(logits[:, 0, :], temps, topks, seeds, steps,
                                max_top_k=cfg.max_top_k)
            ok = jnp.all(jnp.isfinite(logits[:, 0, :]), axis=-1)
            out = jnp.stack([tok.astype(jnp.int32),
                             ok.astype(jnp.int32)], axis=-1)
            return out, {"k": cache["k"], "v": cache["v"]}

        return (jax.jit(prefill_fn, donate_argnums=1),
                jax.jit(chunk_fn, donate_argnums=1),
                jax.jit(decode_fn, donate_argnums=1))

    # -- request API -------------------------------------------------------
    def submit(self, req: GenerationRequest) -> None:
        """Enqueue a request. Raises typed, caller-distinguishable errors:
        :class:`DuplicateRequestError` for an rid already in flight (the
        scheduler would otherwise silently overwrite its result),
        :class:`InvalidRequestError` for requests that can NEVER be
        admitted (bad shape, or a prompt needing more pages than the whole
        pool), :class:`QueueFullError` when ``max_queue`` would be
        exceeded. See :meth:`try_submit` for shed-as-result semantics."""
        if req.rid in self._results:
            raise DuplicateRequestError(
                f"request rid={req.rid} is already in flight")
        if self._paged:
            need = -(-req.prompt_len // self.cfg.page_size)
            if need > self.alloc.num_pages:
                raise InvalidRequestError(
                    f"request rid={req.rid}: prompt needs {need} pages but "
                    f"the pool has {self.alloc.num_pages} — can never be "
                    f"admitted")
        if (self.cfg.max_queue > 0 and req.rid >= 0
                and len(self.scheduler.queue) >= self.cfg.max_queue):
            # negative rids are warmup clones — internal, never shed
            raise QueueFullError(
                f"request rid={req.rid} rejected: queue at "
                f"max_queue={self.cfg.max_queue}")
        self.scheduler.submit(req)
        now = self._now()
        res = GenerationResult(rid=req.rid, prompt_len=req.prompt_len,
                               tokens=[], t_enqueue=now)
        if self.metrics.enabled:
            res.trace = Trace(req.rid, now)
        self._results[req.rid] = res

    def try_submit(self, req: GenerationRequest) -> bool:
        """Load-shedding submit: capacity/validity rejections become a
        terminal result with ``status == "rejected"`` (surfaced by
        :meth:`run` like any other completion) instead of an exception.
        Duplicate rids still raise — shedding a duplicate would emit two
        results for one rid."""
        try:
            self.submit(req)
            return True
        except DuplicateRequestError:
            raise
        except (QueueFullError, InvalidRequestError) as e:
            now = self._now()
            res = GenerationResult(
                rid=req.rid, prompt_len=req.prompt_len, tokens=[],
                t_enqueue=now, t_finish=now,
                status=RequestStatus.REJECTED.value,
                finish_reason=RequestStatus.REJECTED.value, error=str(e))
            if self.metrics.enabled:
                res.trace = Trace(req.rid, now)
            self._c["rejected"].inc()
            self._observe_terminal(res)
            self._done.append(res)
            return False

    def cancel(self, rid: int) -> bool:
        """Cancel an in-flight request. Queued requests (and preempted
        tickets — their spilled host payload dies with the ticket) leave
        the queue; a running request is failed out of its slot with its
        pages reclaimed. Either way the partial tokens generated so far
        are emitted in a terminal ``cancelled`` result. False when the rid
        is unknown or already finished."""
        if rid not in self._results:
            return False
        item = self.scheduler.remove(rid)
        if item is not None:
            self._finish_queued(item, RequestStatus.CANCELLED.value)
            return True
        for slot in self.scheduler.active_slots():
            if self.scheduler.slots[slot].request.rid == rid:
                self._fail_slot(slot, RequestStatus.CANCELLED.value)
                return True
        return False

    def _expire_deadlines(self) -> None:
        """Terminal-fail every request whose ``deadline_s`` has elapsed
        since submit — queued requests shed without ever running; running
        requests keep the tokens they produced. Called at each step
        boundary, so expiry lags the deadline by at most one step."""
        now = self._now()
        sched = self.scheduler
        expired = []
        for item in sched.queue:
            req = item.request if isinstance(item, ResumeTicket) else item
            if (req.deadline_s > 0 and req.rid in self._results
                    and now - self._results[req.rid].t_enqueue
                    >= req.deadline_s):
                expired.append(req.rid)
        for rid in expired:
            self._finish_queued(sched.remove(rid),
                                RequestStatus.DEADLINE.value)
        for slot in list(sched.active_slots()):
            req = sched.slots[slot].request
            if (req.deadline_s > 0
                    and now - self._results[req.rid].t_enqueue
                    >= req.deadline_s):
                self._fail_slot(slot, RequestStatus.DEADLINE.value)

    def warmup(self, reqs) -> Dict[str, int]:
        """Compile every program a trace shaped like ``reqs`` can hit
        before timing starts:

        - for each distinct prompt bucket in ``reqs``, the full
          (bucket × batch-bucket) prefill grid, traced with all-padding
          dummy batches (slot index == num_slots, so every cache write is
          dropped);
        - the chunked-prefill program (one dummy chunk) if any request's
          prompt exceeds the largest bucket;
        - the decode program, via one short clone per distinct bucket
          (prompt clipped to max_len - 1 so the clone's >= 1-token budget
          always fits) plus a minimal 2-token fallback request if none of
          the clones had decode headroom.

        Warmup requires an IDLE engine: it drains the scheduler, so real
        requests submitted beforehand would be silently executed and their
        results discarded — it raises instead. Clones use negative rids
        (callers' traces use non-negative ones) and are filtered from the
        caller-visible results explicitly. Resets the dispatch/utilization
        counters and returns the post-warmup :meth:`compile_counts`
        snapshot."""
        if not self.scheduler.idle:
            raise RuntimeError(
                "Engine.warmup on a non-idle engine: warmup drains the "
                "scheduler, which would silently execute and discard "
                "already-submitted requests — warm up first, then submit")
        plan = self.faults
        self.set_faults(None)          # warmup always runs fault-free
        wmax = self.scheduler.buckets[-1]
        seen: Dict[int, GenerationRequest] = {}
        chunked = False
        for r in reqs:
            if r.prompt_len > wmax:
                chunked = True
            else:
                seen.setdefault(self.scheduler.bucket_for(r.prompt_len), r)

        # (bucket × batch-bucket) prefill grid: all-padding dummy batches
        # (slot path: OOB slot index; paged path: all-sentinel page maps —
        # either way every cache write is dropped)
        drop = self.cfg.num_slots
        for w in sorted(seen):
            for bb in self.batch_buckets:
                if self._paged:
                    route = jnp.full((bb, -(-w // self.cfg.page_size)),
                                     self.alloc.num_pages, jnp.int32)
                else:
                    route = jnp.full((bb,), drop, jnp.int32)
                tok_dev, self.kv = self._prefill(
                    self.params, self.kv,
                    jnp.zeros((bb, w), jnp.int32),
                    jnp.ones((bb,), jnp.int32),
                    route,
                    jnp.zeros((bb,), jnp.float32),
                    jnp.zeros((bb,), jnp.int32),
                    jnp.zeros((bb,), jnp.uint32))
        # a paged engine with prefix caching uses the chunk program for
        # every prefix hit, not just beyond-largest-bucket prompts — warm
        # it whenever a trace could hit it
        if chunked or (self._paged and self.prefix is not None
                       and (seen or chunked)):
            # one dummy chunk compiles the (single) chunk program; on the
            # slot path the garbage it writes into slot 0 sits beyond every
            # causal mask until the slot's next prefill overwrites it (the
            # engine is idle); on the paged path an all-sentinel table row
            # drops the writes outright
            route = (jnp.full((1, self.pages_per_slot), self.alloc.num_pages,
                              jnp.int32) if self._paged else np.int32(0))
            tok_dev, self.kv = self._chunk(
                self.params, self.kv, jnp.zeros((1, wmax), jnp.int32),
                np.int32(0), np.int32(1), route, np.float32(0.0),
                np.int32(0), np.uint32(0))

        # end-to-end clones (decode program + host bookkeeping paths)
        wid = -1
        decode_warmed = False
        for _, r in sorted(seen.items()):
            plen = min(r.prompt_len, self.cfg.max_len - 1)
            nnew = min(2, self.cfg.max_len - plen)     # >= 1 by construction
            decode_warmed |= nnew >= 2
            self.submit(GenerationRequest(rid=wid, prompt=r.prompt[:plen],
                                          max_new_tokens=nnew,
                                          sampling=r.sampling))
            wid -= 1
        if (seen or chunked) and not decode_warmed:
            self.submit(GenerationRequest(
                rid=wid, prompt=np.asarray([1], np.int32), max_new_tokens=2))
        real = [r for r in self.run() if r.rid >= 0]
        self._done.extend(real)        # unreachable under the idle guard
        if self.prefix is not None:
            # the clones seeded the prefix cache with warmup prompts —
            # drop them so runtime hit/miss stats start clean and the
            # first real admissions aren't served warmup pages
            self.prefix.clear()
        if self.alloc is not None:
            assert self.alloc.pages_in_use == 0, \
                f"warmup leaked {self.alloc.pages_in_use} pages"
        self._reset_counters()
        self.set_faults(plan)
        return self.compile_counts()

    def step(self) -> None:
        """Admit every admissible request (one batched prefill dispatch per
        FIFO head-run, chunked prefill for beyond-largest-bucket prompts
        and prefix-hit suffixes, page restoration for resume tickets), then
        run one decode step for all slots.

        Failure-atomic: a fault anywhere in the step (failed spill or
        restore, injected allocation failure, non-finite decode logits, a
        prefill dispatch raising) terminal-fails ONLY the culpable
        request(s), rolls their page/slot acquisitions back, and leaves the
        rest of the batch serving — :meth:`check_invariants` holds at every
        step boundary."""
        sched = self.scheduler
        if self.faults is not None:
            self.faults.tick()
        self._expire_deadlines()
        # ring-buffered backlog sample: peak/mean/samples accumulate in the
        # gauge child, the trace keeps the most recent queue_trace_samples
        # values and counts overwrites in queue_stats()["dropped"]
        self._g_queue.set(len(sched.queue))
        if self._paged:
            self._admit_paged()
        else:
            while (batch := sched.admit_batch(
                    mixed=self.cfg.mixed_admission)) is not None:
                try:
                    if batch.chunked:
                        self._run_chunked(*batch.items[0])
                    else:
                        self._run_prefill_batch(batch)
                except Exception as e:      # noqa: BLE001 — fault isolation
                    self._abort_admission(batch.items, e)

        if sched.num_active == 0:
            return
        if self._paged:
            self._extend_for_decode()
            if sched.num_active == 0:      # extension self-preempted all
                return
            out_dev, self.kv = self._decode(
                self.params, self.kv, jnp.asarray(self._pos),
                jnp.asarray(self._tok[:, None]), jnp.asarray(self._temps),
                jnp.asarray(self._topks), jnp.asarray(self._seeds),
                jnp.asarray(self._steps), jnp.asarray(self._table))
        else:
            out_dev, self.kv = self._decode(
                self.params, self.kv, jnp.asarray(self._pos),
                jnp.asarray(self._tok[:, None]), jnp.asarray(self._temps),
                jnp.asarray(self._topks), jnp.asarray(self._seeds),
                jnp.asarray(self._steps))
        out = np.asarray(out_dev)  # lint: allow[host-sync] THE one transfer per decode step (S, 2): token + finite flag
        toks, finite = out[:, 0], out[:, 1]
        now = self._now()
        self._c["decode_steps"].inc()
        self._c["active_slot_steps"].inc(sched.num_active)
        for slot in list(sched.active_slots()):
            state = sched.slots[slot]
            rid = state.request.rid
            bad = not finite[slot]
            if self.faults is not None and self.faults.poison_logits(rid):
                bad = True
            if bad:
                # the sampled token is garbage: fail this slot alone, keep
                # the rest of the batch decoding
                self._fail_slot(slot, RequestStatus.ERROR.value,
                                "non-finite decode logits")
                continue
            tok = int(toks[slot])
            state.generated += 1
            self._results[rid].tokens.append(tok)
            self._pos[slot] += 1
            self._tok[slot] = tok
            self._steps[slot] += 1
            if state.done or tok == state.request.eos_id:
                self._finish(slot, now)

    def _abort_admission(self, items, exc: Exception) -> None:
        """A prefill dispatch raised mid-admission: terminal-fail exactly
        the requests it was admitting (their slots/pages roll back) and
        keep serving everyone else."""
        for slot, req in items:
            state = self.scheduler.slots[slot]
            if state is not None and state.request.rid == req.rid:
                self._fail_slot(slot, RequestStatus.ERROR.value,
                                f"prefill failed: {exc}")

    def _run_prefill_batch(self, batch: AdmittedBatch) -> None:
        """One device dispatch for a whole same-bucket admission batch."""
        t_admit = self._now()
        b, w = len(batch.items), batch.bucket
        bb = next(x for x in self.batch_buckets if b <= x)
        tokens = np.zeros((bb, w), np.int32)
        lengths = np.ones((bb,), np.int32)
        slots = np.full((bb,), self.cfg.num_slots, np.int32)  # pad: dropped
        temps = np.zeros((bb,), np.float32)
        topks = np.zeros((bb,), np.int32)
        seeds = np.zeros((bb,), np.uint32)
        for i, (slot, req) in enumerate(batch.items):
            tokens[i, :req.prompt_len] = req.prompt
            lengths[i] = req.prompt_len
            slots[i] = slot
            sp = req.sampling
            temps[i], topks[i] = sp.temperature, sp.top_k
            seeds[i] = np.uint32(sp.seed)
        tok_dev, self.kv = self._prefill(
            self.params, self.kv, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(slots), jnp.asarray(temps), jnp.asarray(topks),
            jnp.asarray(seeds))
        toks = np.asarray(tok_dev)  # lint: allow[host-sync] B first tokens, one transfer per batched prefill
        self._c["prefill_dispatches"].inc()
        self._c["prefill_admitted"].inc(b)
        now = self._now()
        for i, (slot, req) in enumerate(batch.items):
            self._record_first_token(slot, req, int(toks[i]), now, t_admit)

    def _run_chunked(self, slot: int, req: GenerationRequest) -> None:
        """Stream a beyond-largest-bucket prompt through the bucket-width
        chunk program against the slot's own cache rows. Only the final
        chunk's sample is real; intermediate device results are never
        synced."""
        t_admit = self._now()
        w = self.scheduler.buckets[-1]
        p, sp = req.prompt_len, req.sampling
        tok_dev = None
        for start in range(0, p, w):
            clen = min(w, p - start)
            chunk = np.zeros((1, w), np.int32)
            chunk[0, :clen] = req.prompt[start:start + clen]
            tok_dev, self.kv = self._chunk(
                self.params, self.kv, jnp.asarray(chunk), np.int32(start),
                np.int32(clen), np.int32(slot), np.float32(sp.temperature),
                np.int32(sp.top_k), np.uint32(sp.seed))
            self._c["chunk_dispatches"].inc()
        self._c["chunked_admitted"].inc()
        # lint: allow[host-sync] one scalar per chunked prefill, by design
        self._record_first_token(slot, req, int(tok_dev), self._now(),
                                 t_admit)

    # -- paged admission ---------------------------------------------------
    def _set_table_row(self, slot: int, pages: List[int]) -> None:
        row = self._table[slot]
        row[:] = self.alloc.num_pages                # sentinel tail
        row[:len(pages)] = pages

    def _acquire_pages(self, n: int, seq: int,
                       allow_preempt: bool) -> Optional[List[int]]:
        """n pages, escalating when the pool is dry: evict unreferenced
        prefix-cache entries first, then (tickets and decode extension
        only) preempt the youngest request no older than ``seq``. None
        when neither escalation can free enough — the caller waits."""
        if n == 0:
            return []
        while True:
            pages = self.alloc.alloc(n)
            if pages is not None:
                return pages
            deficit = n - self.alloc.num_free
            if self.prefix is not None and self.prefix.evict(deficit) > 0:
                continue
            if allow_preempt and self._preempt_youngest(seq):
                continue
            return None

    def _preempt_youngest(self, seq: int) -> bool:
        """Spill the youngest live request with seq >= ``seq`` (self-
        preemption is legal: a decode extension may evict the requester
        itself, which then resumes via its ticket). False when every live
        request is strictly older — the oldest is never preempted, so it
        always runs to completion and admission cannot livelock."""
        victim, vseq = -1, -1
        for slot in self.scheduler.active_slots():
            s = self.scheduler.slots[slot].request.seq
            if s >= seq and s > vseq:
                victim, vseq = slot, s
        if victim < 0:
            return False
        self._preempt(victim)
        return True

    def _preempt(self, slot: int) -> None:
        """Spill a live slot's pages to host memory, requeue its ticket
        (ordered by seq — ahead of every never-admitted request), release
        its pages and park the slot. Prefix-cached pages keep their cache
        reference: only the request's own references drop."""
        sched = self.scheduler
        state = sched.slots[slot]
        pages = self._slot_pages[slot]
        try:
            if self.faults is not None:
                self.faults.check_spill("spill")
            payload = spill_pages(self.kv, pages)
        except Exception as e:             # noqa: BLE001 — fault isolation
            # the spill never produced a payload, so the victim's cache
            # state is unrecoverable: fail it (partial tokens survive
            # host-side) and reclaim its pages — the pool still frees, so
            # the caller's escalation makes progress either way
            self._fail_slot(slot, RequestStatus.ERROR.value,
                            f"preemption spill failed: {e}")
            return
        ticket = ResumeTicket(request=state.request,
                              generated=state.generated,
                              last_token=int(self._tok[slot]),
                              pos=int(self._pos[slot]),
                              n_pages=len(pages),
                              payload=payload)
        sched.preempt(slot, ticket)
        self._c["preemptions"].inc()
        self._c["pages_spilled"].inc(len(pages))
        res = self._results[state.request.rid]
        if res.trace is not None:
            res.trace.stamp("preempt", self._now())
        self.alloc.decref(pages)
        self._slot_pages[slot] = []
        self._set_table_row(slot, [])
        self._park(slot)

    def _try_resume(self) -> bool:
        """Head-of-queue ResumeTicket → fresh pages + restored payload.
        The spilled bytes scatter back verbatim (raw storage round-trip),
        so the resumed request's decode continues bit-identically. False
        when blocked (no free slot, or pages unobtainable without
        preempting a strictly-older request)."""
        sched = self.scheduler
        ticket = sched.peek()
        if not sched.free:
            return False
        pages = self._acquire_pages(ticket.n_pages, ticket.seq,
                                    allow_preempt=True)
        if pages is None:
            return False
        slot, ticket = sched.admit_head()
        try:
            if self.faults is not None:
                self.faults.check_spill("restore")
            self.kv = restore_pages(self.kv, pages, ticket.payload,
                                    self.alloc.num_pages)
        except Exception as e:             # noqa: BLE001 — fault isolation
            # the spilled bytes never reached the device: hand the fresh
            # pages back and fail the ticket's request (its pre-preemption
            # tokens survive in the result). Returning True is honest —
            # the ticket reached a terminal state, the queue moved.
            self.alloc.decref(pages)
            self._fail_slot(slot, RequestStatus.ERROR.value,
                            f"resume restore failed: {e}")
            return True
        self._slot_pages[slot] = pages
        self._set_table_row(slot, pages)
        sp = ticket.request.sampling
        self._pos[slot] = ticket.pos
        self._tok[slot] = ticket.last_token
        self._temps[slot] = sp.temperature
        self._topks[slot] = sp.top_k
        self._seeds[slot] = np.uint32(sp.seed)
        self._steps[slot] = ticket.generated   # sampling's fold_in counter
        self._c["resumes"].inc()
        res = self._results[ticket.request.rid]
        if res.trace is not None:
            res.trace.stamp("resume", self._now())
        return True

    def _extend_for_decode(self) -> None:
        """Back every live slot's next write position with a page before
        the decode dispatch (a write through a sentinel entry would drop
        the new token's K/V and corrupt the sampled output). Oldest-first,
        preempting the youngest (possibly the requester itself) when the
        pool is dry."""
        sched = self.scheduler
        pg = self.cfg.page_size
        order = sorted(sched.active_slots(),
                       key=lambda i: sched.slots[i].request.seq)
        for slot in order:
            state = sched.slots[slot]
            if state is None:            # preempted by an older extension
                continue
            need = int(self._pos[slot]) // pg + 1
            have = self._slot_pages[slot]
            if need <= len(have):
                continue
            pages = self._acquire_pages(need - len(have), state.request.seq,
                                        allow_preempt=True)
            if pages is None or sched.slots[slot] is not state:
                # the slot self-preempted while escalating (its ticket
                # resumes later) — hand back anything grabbed after that
                if pages is not None:
                    self.alloc.decref(pages)
                continue
            have.extend(pages)
            self._set_table_row(slot, have)

    def _admit_paged(self) -> None:
        """Paged admission, strictly FIFO. Plain bucket-size requests
        accumulate into one right-padded prefill dispatch (``pending``);
        prefix hits and beyond-largest-bucket prompts stream their
        unmatched suffix through the chunk program; resume tickets restore
        spilled pages, preempting strictly-younger live requests when the
        pool is short. ``pending`` is always flushed before a resume can
        preempt, so preemption victims are fully prefilled."""
        sched = self.scheduler
        pg = self.cfg.page_size
        wmax = sched.buckets[-1]
        pending: List[tuple] = []            # [(slot, req)], one dispatch
        while sched.free:
            head = sched.peek()
            if head is None:
                break
            if isinstance(head, ResumeTicket):
                self._flush_pending(pending)
                if not self._try_resume():
                    break
                continue
            req = head
            matched, mtok = ([], 0)
            if self.prefix is not None:
                matched, mtok = self.prefix.match(req.prompt)
            fresh = self._acquire_pages(-(-req.prompt_len // pg)
                                        - len(matched),
                                        req.seq, allow_preempt=False)
            if fresh is None:
                # head-of-line waits for pages (never preempts: everything
                # live is older); roll back the prefix references
                if matched:
                    self.alloc.decref(matched)
                break
            if mtok:
                self._c["prefix_hits"].inc()
                self._c["prefix_hit_tokens"].inc(mtok)
            elif self.prefix is not None:
                self._c["prefix_misses"].inc()
            slot, _ = sched.admit_head()
            pages = matched + fresh
            self._slot_pages[slot] = pages
            self._set_table_row(slot, pages)
            if mtok or req.prompt_len > wmax:
                # the flush writes any pending twin's pages before the
                # chunk program reads the matched ones (in-order dispatch)
                self._flush_pending(pending)
                try:
                    self._admit_stream(slot, req, mtok)
                except Exception as e:  # noqa: BLE001 — fault isolation
                    # fail this admission alone; skip the prefix insert
                    # (the pages hold a partially written prompt)
                    self._abort_admission([(slot, req)], e)
                    continue
            else:
                if (pending and not self.cfg.mixed_admission
                        and sched.bucket_for(req.prompt_len)
                        != sched.bucket_for(pending[0][1].prompt_len)):
                    self._flush_pending(pending)
                pending.append((slot, req))
            if self.prefix is not None:
                self.prefix.insert(req.prompt, pages)
        self._flush_pending(pending)

    def _flush_pending(self, pending: List[tuple]) -> None:
        """One right-padded prefill dispatch for the accumulated admission
        run (the paged mirror of :meth:`_run_prefill_batch`; padding rows
        carry all-sentinel page maps)."""
        if not pending:
            return
        try:
            self._dispatch_pending(pending)
        except Exception as e:             # noqa: BLE001 — fault isolation
            self._abort_admission(pending, e)
        del pending[:]

    def _dispatch_pending(self, pending: List[tuple]) -> None:
        t_admit = self._now()
        b = len(pending)
        w = max(self.scheduler.bucket_for(r.prompt_len) for _, r in pending)
        bb = next(x for x in self.batch_buckets if b <= x)
        pg = self.cfg.page_size
        sentinel = self.alloc.num_pages
        tokens = np.zeros((bb, w), np.int32)
        lengths = np.ones((bb,), np.int32)
        maps = np.full((bb, -(-w // pg)), sentinel, np.int32)
        temps = np.zeros((bb,), np.float32)
        topks = np.zeros((bb,), np.int32)
        seeds = np.zeros((bb,), np.uint32)
        for i, (slot, req) in enumerate(pending):
            tokens[i, :req.prompt_len] = req.prompt
            lengths[i] = req.prompt_len
            maps[i, :len(self._slot_pages[slot])] = self._slot_pages[slot]
            sp = req.sampling
            temps[i], topks[i] = sp.temperature, sp.top_k
            seeds[i] = np.uint32(sp.seed)
        tok_dev, self.kv = self._prefill(
            self.params, self.kv, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(maps), jnp.asarray(temps), jnp.asarray(topks),
            jnp.asarray(seeds))
        toks = np.asarray(tok_dev)  # lint: allow[host-sync] one transfer per paged prefill dispatch
        self._c["prefill_dispatches"].inc()
        self._c["prefill_admitted"].inc(b)
        now = self._now()
        for i, (slot, req) in enumerate(pending):
            self._record_first_token(slot, req, int(toks[i]), now, t_admit)

    def _admit_stream(self, slot: int, req: GenerationRequest,
                      start_tok: int) -> None:
        """Stream a prompt's unmatched suffix through the paged chunk
        program, starting at the prefix-matched offset (0 for a plain
        beyond-largest-bucket prompt). Only the final chunk's sample is
        real; intermediate device results are never synced."""
        t_admit = self._now()
        w = self.scheduler.buckets[-1]
        p, sp = req.prompt_len, req.sampling
        table_row = jnp.asarray(self._table[slot:slot + 1])
        tok_dev = None
        for start in range(start_tok, p, w):
            clen = min(w, p - start)
            chunk = np.zeros((1, w), np.int32)
            chunk[0, :clen] = req.prompt[start:start + clen]
            tok_dev, self.kv = self._chunk(
                self.params, self.kv, jnp.asarray(chunk), np.int32(start),
                np.int32(clen), table_row, np.float32(sp.temperature),
                np.int32(sp.top_k), np.uint32(sp.seed))
            self._c["chunk_dispatches"].inc()
        self._c["chunked_admitted"].inc()
        # lint: allow[host-sync] one scalar per chunked prefill, by design
        self._record_first_token(slot, req, int(tok_dev), self._now(),
                                 t_admit)

    def _record_first_token(self, slot: int, req: GenerationRequest,
                            tok: int, now: float,
                            t_admit: Optional[float] = None) -> None:
        res = self._results[req.rid]
        res.t_admit = now if t_admit is None else t_admit
        res.t_first_token = now
        if res.trace is not None:
            res.trace.stamp("admitted", res.t_admit)
            res.trace.stamp("first_token", now)
        res.tokens.append(tok)
        state = self.scheduler.slots[slot]
        state.generated = 1
        sp = req.sampling
        self._pos[slot] = req.prompt_len
        self._tok[slot] = tok
        self._temps[slot] = sp.temperature
        self._topks[slot] = sp.top_k
        self._seeds[slot] = np.uint32(sp.seed)
        self._steps[slot] = 1
        if state.done or tok == req.eos_id:
            self._finish(slot, now)

    def _finish(self, slot: int, now: float) -> None:
        req = self.scheduler.retire(slot)
        res = self._results.pop(req.rid)
        res.t_finish = now
        res.status = RequestStatus.OK.value
        res.finish_reason = (RequestStatus.EOS.value
                             if res.tokens and res.tokens[-1] == req.eos_id
                             else RequestStatus.LENGTH.value)
        self._observe_terminal(res)
        self._done.append(res)
        self._release_slot(slot)

    def _fail_slot(self, slot: int, status: str, msg: str = "") -> None:
        """Terminal-fail a LIVE slot: retire it, reclaim its pages, park
        it, and emit the partial-token result with ``status``. The
        crash-safe reclamation primitive — cancel, deadline expiry, and
        step-level fault isolation all land here, so a failing request can
        never leak pages or wedge its slot."""
        req = self.scheduler.retire(slot)
        res = self._results.pop(req.rid)
        res.t_finish = self._now()
        res.status = status
        res.finish_reason = status
        res.error = msg
        self._observe_terminal(res)
        self._done.append(res)
        self._release_slot(slot)

    def _release_slot(self, slot: int) -> None:
        if self._paged:
            # release the request's page references; prefix-cached pages
            # keep their cache reference and survive for future matches
            self.alloc.decref(self._slot_pages[slot])
            self._slot_pages[slot] = []
            self._set_table_row(slot, [])
        self._park(slot)

    def _finish_queued(self, item, status: str, msg: str = "") -> None:
        """Terminal a request that never reached (or was preempted off) a
        slot: queued requests carry no device state; a ResumeTicket's pages
        were already released at preemption and its spilled host payload
        dies with the ticket. Partial tokens accumulated before a
        preemption are still in the result and are emitted."""
        req = item.request if isinstance(item, ResumeTicket) else item
        res = self._results.pop(req.rid)
        res.t_finish = self._now()
        res.status = status
        res.finish_reason = status
        res.error = msg
        self._observe_terminal(res)
        self._done.append(res)

    def _observe_terminal(self, res: GenerationResult) -> None:
        """Terminal lifecycle observations: close the trace, count the
        result by status, feed the latency histograms. Pure host dict/float
        work — no device interaction, safe inside the step loop."""
        if res.trace is not None:
            res.trace.finish(res.status, res.t_finish)
        self._status_counter("requests", res.status).inc()
        if res.tokens:
            self._status_counter("tokens_generated",
                                 res.status).inc(len(res.tokens))
        if not self.metrics.enabled:
            return
        if res.status != RequestStatus.REJECTED.value:
            # rejected requests never entered the queue; everyone else gets
            # a queue-time sample (whole lifetime when never admitted)
            self._h_queue.observe(res.queue_time)
        if res.t_first_token > 0.0:
            self._h_ttft.observe(res.ttft)
            if len(res.tokens) > 1:
                self._h_tpot.observe(res.tpot)
        self._h_latency.observe(res.latency)

    def _park(self, slot: int) -> None:
        # park the freed slot: greedy token 0 at position 0, overwritten by
        # the next admission's prefill before it is ever attended (paged:
        # the slot's all-sentinel table row drops its parked decode writes)
        self._pos[slot] = 0
        self._tok[slot] = 0
        self._temps[slot] = 0.0
        self._topks[slot] = 0
        self._seeds[slot] = 0
        self._steps[slot] = 0

    def run(self, max_steps: int = 1_000_000,
            step_hook=None) -> List[GenerationResult]:
        """Drive until every submitted request reaches a terminal status;
        returns results in completion order. ``step_hook(engine)``, when
        given, runs after every step (periodic stats printing, profiler
        windows) — host-side only, it must not submit or cancel.

        Raises :class:`EngineStalledError` — carrying the stuck requests'
        rids and where they are stuck — in two cases: ``max_steps``
        exhausted with work outstanding, or (early deadlock detection)
        ``cfg.stall_patience`` consecutive steps made NO progress (no
        decode, no admission, no resume, no completion) while work remains.
        A decode step always counts as progress, so patience only burns
        while the engine spins on an unadmittable queue."""
        sched = self.scheduler
        stalled = 0
        for _ in range(max_steps):
            if sched.idle:
                break
            before = (self.decode_steps, self.prefill_admitted,
                      self.chunked_admitted, self.resumes, len(self._done))
            self.step()
            if step_hook is not None:
                step_hook(self)
            if (self.decode_steps, self.prefill_admitted,
                    self.chunked_admitted, self.resumes,
                    len(self._done)) == before:
                stalled += 1
                if stalled >= self.cfg.stall_patience and not sched.idle:
                    raise EngineStalledError(
                        f"engine deadlocked: no progress for {stalled} "
                        f"consecutive steps with work outstanding",
                        sched.stuck_state())
            else:
                stalled = 0
        if not sched.idle:
            raise EngineStalledError(
                f"engine stopped after max_steps={max_steps} with work "
                f"outstanding", self.scheduler.stuck_state())
        out, self._done = self._done, []
        return out

    # -- invariants --------------------------------------------------------
    def check_invariants(self) -> bool:
        """Reconcile every piece of host bookkeeping against every other:
        scheduler slot partition, result-table coverage, block tables vs.
        per-slot page lists, and (paged) the allocator's refcounts/free
        list against the union of live block tables and prefix-cache
        references. Raises :class:`EngineInvariantError` naming the first
        mismatch; returns True when consistent. Pure host arithmetic — no
        device sync — so chaos tests call it after EVERY step."""
        sched = self.scheduler
        n = self.cfg.num_slots
        free, active = list(sched.free), list(sched.active_slots())
        if sorted(free + active) != list(range(n)):
            raise EngineInvariantError(
                f"slot partition broken: free={sorted(free)} "
                f"active={sorted(active)}")
        for slot in active:
            rid = sched.slots[slot].request.rid
            if rid not in self._results:
                raise EngineInvariantError(
                    f"active rid={rid} (slot {slot}) has no result entry")
        for item in sched.queue:
            req = item.request if isinstance(item, ResumeTicket) else item
            if req.rid not in self._results:
                raise EngineInvariantError(
                    f"queued rid={req.rid} has no result entry")
        if not self._paged:
            return True
        pg, sentinel = self.cfg.page_size, self.alloc.num_pages
        want: Dict[int, int] = {}
        for slot in range(n):
            pages = self._slot_pages[slot]
            row = self._table[slot]
            if list(row[:len(pages)]) != pages or not np.all(
                    row[len(pages):] == sentinel):
                raise EngineInvariantError(
                    f"slot {slot} block table {row.tolist()} does not "
                    f"match page list {pages}")
            if slot in active:
                if len(pages) * pg < int(self._pos[slot]):
                    raise EngineInvariantError(
                        f"slot {slot} holds {len(pages)} pages "
                        f"({len(pages) * pg} tokens) but pos="
                        f"{int(self._pos[slot])}: cache rows unbacked")
            elif pages:
                raise EngineInvariantError(
                    f"parked slot {slot} still holds pages {pages}")
            for p in pages:
                want[p] = want.get(p, 0) + 1
        if self.prefix is not None:
            for p in self.prefix.pages():
                want[p] = want.get(p, 0) + 1
        refs = self.alloc.refs()
        if want != refs:
            diff = {p: (want.get(p, 0), refs.get(p, 0))
                    for p in set(want) | set(refs)
                    if want.get(p, 0) != refs.get(p, 0)}
            raise EngineInvariantError(
                f"page refcounts out of sync (page: want/have): {diff}")
        free_pages = self.alloc.free_pages()
        free_set = set(free_pages)
        if len(free_set) != len(free_pages):
            raise EngineInvariantError("free list holds duplicate pages")
        if free_set & set(refs):
            raise EngineInvariantError(
                f"pages both free and referenced: {free_set & set(refs)}")
        if len(free_set) + len(refs) != self.alloc.num_pages:
            orphans = set(range(self.alloc.num_pages)) - free_set - set(refs)
            raise EngineInvariantError(
                f"pages leaked (neither free nor referenced): {orphans}")
        return True

    # -- introspection -----------------------------------------------------
    def compile_counts(self) -> Dict[str, Optional[int]]:
        """Compiled-program counts (prefill: one per (prompt bucket, batch
        bucket) pair seen; chunk: 1 when the trace has beyond-largest-bucket
        prompts; decode: 1). Flat across a post-warmup trace ⇔ no
        recompilation. ``None`` when the jit cache size is unavailable
        (private jax API moved) — callers must treat that as UNKNOWN, never
        as "no recompilation". The paged spill/restore gathers compile
        lazily at the first preemption (O(log max_pages) programs, bounded
        by the pow2 padding) and are not tracked here."""
        def size(f) -> Optional[int]:
            try:
                return int(f._cache_size())
            except Exception:
                return None
        return {"prefill": size(self._prefill), "chunk": size(self._chunk),
                "decode": size(self._decode)}

    def kv_cache_bytes(self) -> int:
        return cache_bytes(self.kv)

    def page_stats(self) -> Dict[str, int]:
        """Page-pool / prefix-reuse / preemption observability (paged
        layout only; empty dict on the slot layout). Counters reset with
        :meth:`warmup`."""
        if not self._paged:
            return {}
        return {
            "num_pages": self.alloc.num_pages,
            "page_size": self.cfg.page_size,
            "pages_in_use": self.alloc.pages_in_use,
            "peak_pages_in_use": self.alloc.peak_in_use,
            "alloc_calls": self.alloc.alloc_calls,
            "alloc_failures": self.alloc.alloc_failures,
            "prefix_cached_pages": (self.prefix.cached_pages
                                    if self.prefix is not None else 0),
            "prefix_inserted_pages": (self.prefix.inserted_pages
                                      if self.prefix is not None else 0),
            "prefix_evicted_pages": (self.prefix.evicted_pages
                                     if self.prefix is not None else 0),
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "pages_spilled": self.pages_spilled,
        }

    def utilization(self) -> float:
        if self.decode_steps == 0:
            return 0.0
        return self.active_slot_steps / (self.decode_steps
                                         * self.cfg.num_slots)

    def queue_stats(self) -> Dict[str, Any]:
        """Backlog observability — a view over the registry's queue-depth
        gauge: queue depth sampled at each step boundary (peak / mean over
        ALL samples; the per-step trace is a ring of the most recent
        ``cfg.queue_trace_samples`` values with overwrites counted in
        ``dropped``) plus the ``try_submit`` load-shed count. Counters
        reset with :meth:`warmup`."""
        g = self._g_queue
        return {"peak": int(g.peak),
                "mean": g.mean,
                "samples": g.samples,
                "rejected": self.rejected,
                "trace": [int(v) for v in g.trace_values()],
                "dropped": g.trace_dropped}

    def metrics_snapshot(self) -> dict:
        """Full registry snapshot (counters/gauges/histograms) with the
        point-in-time state gauges refreshed. The canonical export for
        benches and ``serve.py --metrics-json`` — everything
        ``page_stats``/``queue_stats``/the legacy counter attributes show
        is derivable from it."""
        self._g_slots.set_value(self.scheduler.num_active)
        if self._paged:
            self._g_pages.set_value(self.alloc.pages_in_use)
            if self.prefix is not None:
                self._g_prefix_pages.set_value(self.prefix.cached_pages)
        return self.metrics.snapshot()


__all__ = ["Engine", "EngineConfig", "GenerationRequest", "GenerationResult",
           "batch_buckets"]
