"""Continuous-batching serving engine: variable-length requests in fixed
device slots, no recompilation after warmup.

The engine owns a slot-indexed KV cache (``serving/kv_cache.py``; S slots ×
max_len tokens, dense or INT8 per-head-group quantized) and two jitted step
functions:

- **prefill** (one compile per prompt bucket): runs the model over one
  request's right-padded prompt against a fresh (L, 1, W) mini-cache,
  gathers logits at the true last token, samples the first output token on
  device, and splices the mini-cache into the admitted slot's rows
  (quantizing if the cache is INT8);
- **decode** (one compile, ever): one token for ALL slots at once — each
  slot reads/writes the cache at its own position (``pos`` is a vector),
  per-slot sampling params ride along as arrays, and exactly one int32 per
  slot crosses the device boundary per step.

The host-side :class:`~repro.serving.scheduler.Scheduler` feeds it: FIFO
admission onto the slot free-list, prompt-length bucketing (the only shape
degree of freedom), retire-on-completion. Retired slots keep decoding
garbage at position 0 until reused — their writes land below the next
request's prefill splice and are never attended.

`launch/serve.py --engine continuous` drives it; `benchmarks/engine_bench.py`
load-tests it (Zipf lengths) into ``results/BENCH_engine.json``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.kv_cache import (KVCacheConfig, cache_bytes,
                                    init_slot_cache, write_slot)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import (GenerationRequest, GenerationResult,
                                     Scheduler)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine shape/storage policy. ``kv_quantized`` switches the slot
    cache to INT8 per-head-group storage (``kv_group_size=0`` → one group
    per head); ``prompt_buckets=()`` → power-of-two buckets."""
    num_slots: int = 8
    max_len: int = 256
    prompt_buckets: tuple = ()
    kv_dtype: Any = jnp.float32
    kv_quantized: bool = False
    kv_group_size: int = 0
    max_top_k: int = 64


class Engine:
    """Slot-based continuous batching over a fixed-shape decode program."""

    def __init__(self, model, params, cfg: EngineConfig = EngineConfig()):
        mcfg = model.cfg
        if mcfg.family not in ("dense", "moe") or mcfg.frontend:
            raise ValueError(
                f"engine serves token-LM families (dense/moe), got "
                f"{mcfg.family}/{mcfg.frontend}")
        self.model, self.params, self.cfg = model, params, cfg
        self.scheduler = Scheduler(cfg.num_slots, cfg.max_len,
                                   cfg.prompt_buckets)
        kv_cfg = KVCacheConfig(num_slots=cfg.num_slots, max_len=cfg.max_len,
                               dtype=cfg.kv_dtype, quantized=cfg.kv_quantized,
                               group_size=cfg.kv_group_size)
        cache = init_slot_cache(mcfg, kv_cfg)
        self.kv = {"k": cache["k"], "v": cache["v"]}   # pos lives host-side
        s = cfg.num_slots
        self._pos = np.zeros(s, np.int32)
        self._tok = np.zeros(s, np.int32)
        self._temps = np.zeros(s, np.float32)
        self._topks = np.zeros(s, np.int32)
        self._seeds = np.zeros(s, np.uint32)
        self._steps = np.zeros(s, np.uint32)
        self._results: Dict[int, GenerationResult] = {}
        self._done: List[GenerationResult] = []
        self.decode_steps = 0
        self.active_slot_steps = 0
        self._prefill, self._decode = self._make_step_fns()

    # -- jitted steps ------------------------------------------------------
    def _make_step_fns(self):
        model, cfg = self.model, self.cfg
        mcfg = model.cfg
        mini_dtype = jnp.float32 if cfg.kv_quantized else cfg.kv_dtype

        def prefill_fn(params, kv, tokens, length, slot, temp, topk, seed):
            w = tokens.shape[1]
            zeros = jnp.zeros((mcfg.num_layers, 1, w, mcfg.num_kv_heads,
                               mcfg.resolved_head_dim), mini_dtype)
            mini = {"k": zeros, "v": zeros, "pos": jnp.zeros((), jnp.int32)}
            logits, mini = model.prefill_at(params, {"tokens": tokens},
                                            mini, lengths=length[None])
            tok = sample_tokens(logits[:, 0, :], temp[None], topk[None],
                                seed[None], jnp.zeros((1,), jnp.uint32),
                                max_top_k=cfg.max_top_k)
            kv = write_slot(kv, slot, mini["k"], mini["v"])
            return tok[0], kv

        def decode_fn(params, kv, pos, tokens, temps, topks, seeds, steps):
            cache = {"k": kv["k"], "v": kv["v"], "pos": pos}
            logits, cache = model.decode_step(params, tokens, cache)
            tok = sample_tokens(logits[:, 0, :], temps, topks, seeds, steps,
                                max_top_k=cfg.max_top_k)
            return tok, {"k": cache["k"], "v": cache["v"]}

        return (jax.jit(prefill_fn, donate_argnums=1),
                jax.jit(decode_fn, donate_argnums=1))

    # -- request API -------------------------------------------------------
    def submit(self, req: GenerationRequest) -> None:
        self.scheduler.submit(req)
        self._results[req.rid] = GenerationResult(
            rid=req.rid, prompt_len=req.prompt_len, tokens=[],
            t_enqueue=time.perf_counter())

    def warmup(self, reqs) -> Dict[str, int]:
        """Compile every prompt bucket's prefill program plus the decode
        program before timing starts: one short clone per distinct bucket
        in ``reqs`` (budget clipped so the clone always fits max_len), and
        a minimal 2-token request if none of the clones had room to decode.
        Uses negative rids (callers' traces use non-negative ones); returns
        the post-warmup :meth:`compile_counts` snapshot."""
        seen = {}
        for r in reqs:
            seen.setdefault(self.scheduler.bucket_for(r.prompt_len), r)
        wid = -1
        decode_warmed = False
        for _, r in sorted(seen.items()):
            nnew = min(2, self.cfg.max_len - r.prompt_len)
            decode_warmed |= nnew >= 2
            self.submit(GenerationRequest(rid=wid, prompt=r.prompt,
                                          max_new_tokens=nnew,
                                          sampling=r.sampling))
            wid -= 1
        if seen and not decode_warmed:
            self.submit(GenerationRequest(
                rid=wid, prompt=np.asarray([1], np.int32), max_new_tokens=2))
        self.run()
        return self.compile_counts()

    def step(self) -> None:
        """Admit every admissible request (one bucketed prefill each), then
        run one decode step for all slots."""
        sched = self.scheduler
        while (adm := sched.admit()) is not None:
            slot, req = adm
            w = sched.bucket_for(req.prompt_len)
            padded = np.zeros((1, w), np.int32)
            padded[0, :req.prompt_len] = req.prompt
            sp = req.sampling
            tok_dev, self.kv = self._prefill(
                self.params, self.kv, jnp.asarray(padded),
                np.int32(req.prompt_len), np.int32(slot),
                np.float32(sp.temperature), np.int32(sp.top_k),
                np.uint32(sp.seed))
            tok = int(tok_dev)
            now = time.perf_counter()
            res = self._results[req.rid]
            res.t_first_token = now
            res.tokens.append(tok)
            state = sched.slots[slot]
            state.generated = 1
            self._pos[slot] = req.prompt_len
            self._tok[slot] = tok
            self._temps[slot] = sp.temperature
            self._topks[slot] = sp.top_k
            self._seeds[slot] = np.uint32(sp.seed)
            self._steps[slot] = 1
            if state.done or tok == req.eos_id:
                self._finish(slot, now)

        if sched.num_active == 0:
            return
        tok_dev, self.kv = self._decode(
            self.params, self.kv, jnp.asarray(self._pos),
            jnp.asarray(self._tok[:, None]), jnp.asarray(self._temps),
            jnp.asarray(self._topks), jnp.asarray(self._seeds),
            jnp.asarray(self._steps))
        toks = np.asarray(tok_dev)            # one int32 per slot per step
        now = time.perf_counter()
        self.decode_steps += 1
        self.active_slot_steps += sched.num_active
        for slot in sched.active_slots():
            state = sched.slots[slot]
            tok = int(toks[slot])
            state.generated += 1
            self._results[state.request.rid].tokens.append(tok)
            self._pos[slot] += 1
            self._tok[slot] = tok
            self._steps[slot] += 1
            if state.done or tok == state.request.eos_id:
                self._finish(slot, now)

    def _finish(self, slot: int, now: float) -> None:
        req = self.scheduler.retire(slot)
        res = self._results.pop(req.rid)
        res.t_finish = now
        self._done.append(res)
        # park the freed slot: greedy token 0 at position 0, overwritten by
        # the next admission's prefill before it is ever attended
        self._pos[slot] = 0
        self._tok[slot] = 0
        self._temps[slot] = 0.0
        self._topks[slot] = 0
        self._seeds[slot] = 0
        self._steps[slot] = 0

    def run(self, max_steps: int = 1_000_000) -> List[GenerationResult]:
        """Drive until every submitted request completes; returns results
        in completion order."""
        for _ in range(max_steps):
            if self.scheduler.idle:
                break
            self.step()
        assert self.scheduler.idle, "engine stopped with work outstanding"
        out, self._done = self._done, []
        return out

    # -- introspection -----------------------------------------------------
    def compile_counts(self) -> Dict[str, Optional[int]]:
        """Compiled-program counts (prefill: one per prompt bucket seen;
        decode: 1). Flat across a post-warmup trace ⇔ no recompilation.
        ``None`` when the jit cache size is unavailable (private jax API
        moved) — callers must treat that as UNKNOWN, never as "no
        recompilation"."""
        def size(f) -> Optional[int]:
            try:
                return int(f._cache_size())
            except Exception:
                return None
        return {"prefill": size(self._prefill), "decode": size(self._decode)}

    def kv_cache_bytes(self) -> int:
        return cache_bytes(self.kv)

    def utilization(self) -> float:
        if self.decode_steps == 0:
            return 0.0
        return self.active_slot_steps / (self.decode_steps
                                         * self.cfg.num_slots)


__all__ = ["Engine", "EngineConfig", "GenerationRequest", "GenerationResult"]
