"""Host-side request scheduler for the continuous-batching engine.

Pure bookkeeping, no jax: a FIFO admission queue, a slot free-list, and
per-slot (request, generated-count) state. The engine asks the
scheduler *what* to run; every device-facing decision that would change
compiled shapes goes through :func:`Scheduler.bucket_for` (prompt-length
bucketing), so the step functions compile once per bucket and never again.

Invariants (tested in tests/test_engine.py):
- admission is FIFO: requests start in submit order;
- a slot is EXCLUSIVE: never two live requests on one slot;
- retire frees the slot for reuse within the same run;
- a request is admitted only if prompt_len + max_new_tokens fits max_len.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.serving.sampling import SamplingParams


@dataclasses.dataclass
class GenerationRequest:
    """One generation job: prompt tokens + decode budget + sampling policy.
    ``eos_id < 0`` disables early stopping (the synthetic-corpus default)."""
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    eos_id: int = -1

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))


@dataclasses.dataclass
class GenerationResult:
    """Completed request: generated tokens + latency breadcrumbs (host
    wall-clock seconds, filled by the engine)."""
    rid: int
    prompt_len: int
    tokens: List[int]
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_finish: float = 0.0

    @property
    def latency(self) -> float:
        return self.t_finish - self.t_enqueue

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_enqueue


@dataclasses.dataclass
class SlotState:
    """Live per-slot decode state. The device-facing KV write position is
    the engine's per-slot ``pos`` array (always request.prompt_len +
    generated - 1 while live), kept in one place to avoid drift."""
    request: GenerationRequest
    generated: int = 0                 # tokens sampled so far

    @property
    def done(self) -> bool:
        return self.generated >= self.request.max_new_tokens


def default_buckets(max_len: int) -> tuple:
    """Power-of-two prompt buckets 8, 16, … covering max_len."""
    out, b = [], 8
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


class Scheduler:
    """FIFO admission over a fixed set of device slots."""

    def __init__(self, num_slots: int, max_len: int,
                 prompt_buckets: tuple = ()):
        self.num_slots = num_slots
        self.max_len = max_len
        self.buckets = tuple(sorted(prompt_buckets)) or default_buckets(max_len)
        self.queue: Deque[GenerationRequest] = deque()
        self.free: Deque[int] = deque(range(num_slots))
        self.slots: List[Optional[SlotState]] = [None] * num_slots

    # -- admission ---------------------------------------------------------
    def submit(self, req: GenerationRequest) -> None:
        if req.prompt_len + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + "
                f"max_new {req.max_new_tokens} exceeds max_len {self.max_len}")
        if req.prompt_len > self.buckets[-1]:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} exceeds the "
                f"largest prompt bucket {self.buckets[-1]}")
        if req.prompt_len < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        self.queue.append(req)

    def admit(self) -> Optional[tuple]:
        """Pop the FIFO head onto a free slot → (slot, request), or None."""
        if not self.queue or not self.free:
            return None
        slot = self.free.popleft()
        req = self.queue.popleft()
        assert self.slots[slot] is None, f"slot {slot} double-booked"
        self.slots[slot] = SlotState(request=req)
        return slot, req

    def retire(self, slot: int) -> GenerationRequest:
        state = self.slots[slot]
        assert state is not None, f"retiring empty slot {slot}"
        self.slots[slot] = None
        self.free.append(slot)
        return state.request

    # -- queries -----------------------------------------------------------
    def bucket_for(self, prompt_len: int) -> int:
        for b in self.buckets:
            if prompt_len <= b:
                return b
        # unreachable for admitted requests: submit() rejects prompts
        # beyond the largest bucket
        return self.buckets[-1]

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    @property
    def idle(self) -> bool:
        return not self.queue and self.num_active == 0


__all__ = ["GenerationRequest", "GenerationResult", "SlotState", "Scheduler",
           "default_buckets"]
