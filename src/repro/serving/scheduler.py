"""Host-side request scheduler for the continuous-batching engine.

Pure bookkeeping, no jax: a FIFO admission queue, a slot free-list, and
per-slot (request, generated-count) state. The engine asks the
scheduler *what* to run; every device-facing decision that would change
compiled shapes goes through :func:`Scheduler.bucket_for` (prompt-length
bucketing), so the step functions compile once per bucket and never again.

Invariants (tested in tests/test_engine.py and tests/test_paging.py):
- admission is FIFO: requests start in submit order (``admit_batch`` pops
  the FIFO head-run — by default the longest run sharing one prompt
  bucket; ``mixed=True`` crosses buckets and right-pads the run to its
  largest member's bucket — it never skips over a queued request);
- a slot is EXCLUSIVE: never two live requests on one slot;
- retire frees the slot for reuse within the same run;
- a request is admitted only if prompt_len + max_new_tokens fits max_len
  and it decodes at least one token (max_new_tokens >= 1);
- a prompt longer than the largest bucket admits alone (chunked prefill);
- priority is submission order (``seq``): preemption (the paged engine)
  always victimizes the YOUNGEST live request, and a preempted request's
  :class:`ResumeTicket` re-enters the queue ordered by seq — ahead of
  every never-admitted request, behind older tickets — so the oldest
  request can never be starved.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.serving.sampling import SamplingParams


class RequestStatus(str, enum.Enum):
    """Terminal request states. Every request the engine ever accepted ends
    in exactly one of these; ``ok`` is the umbrella success status (its
    ``finish_reason`` refines it to ``length`` or ``eos``)."""
    OK = "ok"                  # completed normally (length / eos)
    LENGTH = "length"          # finish_reason: decode budget exhausted
    EOS = "eos"                # finish_reason: sampled the eos token
    CANCELLED = "cancelled"    # Engine.cancel(rid) — partial tokens kept
    DEADLINE = "deadline"      # deadline_s expired (queued or running)
    REJECTED = "rejected"      # shed at submit (queue full / inadmissible)
    ERROR = "error"            # step failure isolated to this request


class EngineError(RuntimeError):
    """Base of the serving layer's typed failures."""


class InvalidRequestError(EngineError, ValueError):
    """The request can never be admitted (shape/budget violations)."""


class DuplicateRequestError(InvalidRequestError):
    """A request with this rid is already in flight."""


class QueueFullError(EngineError):
    """Admission queue at ``EngineConfig.max_queue`` — request shed."""


class EngineInvariantError(EngineError):
    """check_invariants() found irreconcilable engine state."""


class EngineStalledError(EngineError):
    """The engine stopped making progress with work outstanding.

    ``stuck`` carries one dict per unfinished request: rid, where it is
    (``queued`` / ``ticket`` / ``slot N``), prompt length, tokens generated
    so far, and the decode position for running requests."""

    def __init__(self, msg: str, stuck: Optional[List[dict]] = None):
        self.stuck = stuck or []
        detail = "; ".join(
            f"rid={s['rid']} {s['where']} gen={s.get('generated', 0)}"
            for s in self.stuck)
        super().__init__(f"{msg}" + (f" [{detail}]" if detail else ""))


@dataclasses.dataclass
class GenerationRequest:
    """One generation job: prompt tokens + decode budget + sampling policy.
    ``eos_id < 0`` disables early stopping (the synthetic-corpus default).
    ``deadline_s > 0`` expires the request (queued OR running) that many
    seconds after enqueue — checked at step boundaries, partial tokens are
    kept. ``seq`` is the scheduler-assigned admission priority (submit
    order, lower = older = higher priority); callers leave it at -1."""
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    eos_id: int = -1
    deadline_s: float = 0.0            # 0 → no deadline
    seq: int = -1

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))


@dataclasses.dataclass
class GenerationResult:
    """Terminal request record: generated tokens (possibly partial),
    status/finish_reason taxonomy (:class:`RequestStatus` values), and
    latency breadcrumbs (host wall-clock seconds, filled by the engine)."""
    rid: int
    prompt_len: int
    tokens: List[int]
    t_enqueue: float = 0.0
    t_admit: float = 0.0               # first admission onto a slot (0: never)
    t_first_token: float = 0.0
    t_finish: float = 0.0
    status: str = RequestStatus.OK.value
    finish_reason: str = ""            # length|eos|cancelled|deadline|...
    error: str = ""                    # detail for error/rejected statuses
    trace: Optional[object] = None     # repro.obs.Trace (None: metrics off)

    @property
    def ok(self) -> bool:
        return self.status == RequestStatus.OK.value

    @property
    def latency(self) -> float:
        return self.t_finish - self.t_enqueue

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_enqueue

    @property
    def queue_time(self) -> float:
        """Seconds from submit to first admission (whole lifetime when the
        request reached a terminal status without ever being admitted)."""
        return ((self.t_admit if self.t_admit > 0.0 else self.t_finish)
                - self.t_enqueue)

    @property
    def tpot(self) -> float:
        """Mean seconds per generated token after the first (0.0 with
        fewer than two tokens)."""
        if len(self.tokens) < 2 or self.t_first_token <= 0.0:
            return 0.0
        return (self.t_finish - self.t_first_token) / (len(self.tokens) - 1)


@dataclasses.dataclass
class SlotState:
    """Live per-slot decode state. The device-facing KV write position is
    the engine's per-slot ``pos`` array (always request.prompt_len +
    generated - 1 while live), kept in one place to avoid drift."""
    request: GenerationRequest
    generated: int = 0                 # tokens sampled so far

    @property
    def done(self) -> bool:
        return self.generated >= self.request.max_new_tokens


@dataclasses.dataclass
class ResumeTicket:
    """A preempted request's host-side state, queued for re-admission.

    The engine fills it at preemption (spilled page payloads + decode
    cursor) and consumes it on resume; the scheduler only orders it
    (by ``seq``) and re-binds it to a slot. ``payload`` is engine-opaque
    (the pow2-padded spilled page bytes of both pools)."""
    request: GenerationRequest
    generated: int                     # tokens sampled before preemption
    last_token: int                    # next decode input token
    pos: int                           # next cache write position
    n_pages: int                       # live pages at spill time
    payload: object = None

    @property
    def seq(self) -> int:
        return self.request.seq


@dataclasses.dataclass
class AdmittedBatch:
    """One admission group. ``chunked=False``: the FIFO head-run sharing
    one prompt ``bucket``, admitted together — one batched prefill dispatch
    covers every ``(slot, request)`` in ``items``. ``chunked=True``: a
    single request whose prompt exceeds the largest bucket; it streams
    through the bucket-width chunked-prefill program (``bucket`` is the
    chunk width, i.e. the largest bucket)."""
    bucket: int
    items: List[tuple]                 # [(slot, request), ...]
    chunked: bool = False


def default_buckets(max_len: int) -> tuple:
    """Power-of-two prompt buckets 8, 16, … covering max_len."""
    out, b = [], 8
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


class Scheduler:
    """FIFO admission over a fixed set of device slots."""

    def __init__(self, num_slots: int, max_len: int,
                 prompt_buckets: tuple = ()):
        self.num_slots = num_slots
        self.max_len = max_len
        self.buckets = tuple(sorted(prompt_buckets)) or default_buckets(max_len)
        if self.buckets[0] < 1:
            raise ValueError(f"prompt buckets must be >= 1, got {self.buckets}")
        if self.buckets[-1] > max_len:
            # a bucket wider than the cache would silently clip live prompt
            # tokens at the cache edge during the prefill splice
            raise ValueError(
                f"largest prompt bucket {self.buckets[-1]} exceeds max_len "
                f"{max_len}: the bucket-padded prefill would write past the "
                f"slot cache edge")
        self.queue: Deque = deque()        # GenerationRequest | ResumeTicket
        self.free: Deque[int] = deque(range(num_slots))
        self.slots: List[Optional[SlotState]] = [None] * num_slots
        self._seq = 0                      # monotone admission priority

    # -- admission ---------------------------------------------------------
    def submit(self, req: GenerationRequest) -> None:
        if req.max_new_tokens < 1:
            raise InvalidRequestError(
                f"request {req.rid}: max_new_tokens {req.max_new_tokens} < 1 "
                f"(every admitted request emits at least one token)")
        if req.prompt_len + req.max_new_tokens > self.max_len:
            raise InvalidRequestError(
                f"request {req.rid}: prompt {req.prompt_len} + "
                f"max_new {req.max_new_tokens} exceeds max_len {self.max_len}")
        if req.prompt_len < 1:
            raise InvalidRequestError(f"request {req.rid}: empty prompt")
        # prompts beyond the largest bucket are fine: they admit alone and
        # stream through the chunked prefill (see admit_batch)
        req.seq = self._seq
        self._seq += 1
        self.queue.append(req)

    def remove(self, rid: int):
        """Pull a QUEUED request or resume ticket out of the queue by rid
        (cancellation / deadline expiry). Returns the removed item, or None
        if no queued item carries that rid (it may be running or done)."""
        for i, item in enumerate(self.queue):
            r = item.request if isinstance(item, ResumeTicket) else item
            if r.rid == rid:
                del self.queue[i]
                return item
        return None

    def admit(self) -> Optional[tuple]:
        """Pop the FIFO head onto a free slot → (slot, request), or None."""
        if not self.queue or not self.free:
            return None
        assert not isinstance(self.queue[0], ResumeTicket), \
            "resume tickets re-admit through admit_head (engine restores " \
            "spilled pages); admit() only handles fresh requests"
        slot = self.free.popleft()
        req = self.queue.popleft()
        assert self.slots[slot] is None, f"slot {slot} double-booked"
        self.slots[slot] = SlotState(request=req)
        return slot, req

    def peek(self):
        """The queue head (GenerationRequest or ResumeTicket), or None."""
        return self.queue[0] if self.queue else None

    def admit_head(self) -> Optional[tuple]:
        """Pop the FIFO head — request *or* resume ticket — onto a free
        slot → (slot, head). Tickets rebind with their pre-preemption
        decode progress; the engine restores their pages/pos/token."""
        if not self.queue or not self.free:
            return None
        slot = self.free.popleft()
        head = self.queue.popleft()
        assert self.slots[slot] is None, f"slot {slot} double-booked"
        if isinstance(head, ResumeTicket):
            self.slots[slot] = SlotState(request=head.request,
                                         generated=head.generated)
        else:
            self.slots[slot] = SlotState(request=head)
        return slot, head

    def requeue(self, ticket: ResumeTicket) -> None:
        """Re-enter a preempted request, ordered by seq: behind any older
        tickets already waiting, ahead of everything never admitted (all
        plain queued requests have larger seq — they were submitted after
        the ticket's request was already running)."""
        at = 0
        for item in self.queue:
            if isinstance(item, ResumeTicket) and item.seq < ticket.seq:
                at += 1
            else:
                break
        self.queue.insert(at, ticket)

    def preempt(self, slot: int, ticket: ResumeTicket) -> SlotState:
        """Evict a live slot and requeue its ticket. The engine builds the
        ticket (spilled pages + decode cursor) before calling this."""
        state = self.slots[slot]
        assert state is not None, f"preempting empty slot {slot}"
        assert state.request is ticket.request, \
            f"ticket/slot mismatch on slot {slot}"
        self.slots[slot] = None
        self.free.append(slot)
        self.requeue(ticket)
        return state

    def admit_batch(self, mixed: bool = False) -> Optional[AdmittedBatch]:
        """Pop the longest FIFO head-run sharing one prompt bucket onto
        free slots — one batched prefill dispatch admits the whole run.

        A prompt beyond the largest bucket admits alone (``chunked=True``):
        it streams through the bucket-width program chunk by chunk. FIFO
        order is preserved strictly — the run stops at the first queued
        request whose bucket differs (never skips over it) or when the
        free-list empties. With ``mixed=True`` the run crosses buckets:
        it pops the head-run of every in-bucket request and dispatches one
        prefill right-padded to the LARGEST member's bucket (causal masking
        plus per-row lengths make the padding inert), collapsing a
        short/long interleave into one dispatch instead of one per bucket
        flip. Returns None when nothing is admissible.

        Resume tickets are never popped here — the caller drains them via
        :meth:`admit_head` (they need page restoration, not prefill)."""
        if not self.queue or not self.free:
            return None
        if isinstance(self.queue[0], ResumeTicket):
            return None
        wmax = self.buckets[-1]
        if self.queue[0].prompt_len > wmax:
            return AdmittedBatch(bucket=wmax, items=[self.admit()],
                                 chunked=True)
        items = []
        if mixed:
            bucket = 0
            while (self.queue and self.free
                   and not isinstance(self.queue[0], ResumeTicket)
                   and self.queue[0].prompt_len <= wmax):
                bucket = max(bucket, self.bucket_for(self.queue[0].prompt_len))
                items.append(self.admit())
            return AdmittedBatch(bucket=bucket, items=items)
        bucket = self.bucket_for(self.queue[0].prompt_len)
        while (self.queue and self.free
               and not isinstance(self.queue[0], ResumeTicket)
               and self.queue[0].prompt_len <= wmax
               and self.bucket_for(self.queue[0].prompt_len) == bucket):
            items.append(self.admit())
        return AdmittedBatch(bucket=bucket, items=items)

    def retire(self, slot: int) -> GenerationRequest:
        state = self.slots[slot]
        assert state is not None, f"retiring empty slot {slot}"
        self.slots[slot] = None
        self.free.append(slot)
        return state.request

    # -- queries -----------------------------------------------------------
    def bucket_for(self, prompt_len: int) -> int:
        for b in self.buckets:
            if prompt_len <= b:
                return b
        # beyond the largest bucket: the request is chunked — the largest
        # bucket is the chunk width it streams through
        return self.buckets[-1]

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    @property
    def idle(self) -> bool:
        return not self.queue and self.num_active == 0

    def stuck_state(self) -> List[dict]:
        """Snapshot of every unfinished request (queue + slots) for
        :class:`EngineStalledError` diagnostics."""
        out = []
        for item in self.queue:
            if isinstance(item, ResumeTicket):
                out.append({"rid": item.request.rid, "where": "ticket",
                            "prompt_len": item.request.prompt_len,
                            "generated": item.generated, "pos": item.pos})
            else:
                out.append({"rid": item.rid, "where": "queued",
                            "prompt_len": item.prompt_len, "generated": 0})
        for slot, state in enumerate(self.slots):
            if state is not None:
                out.append({"rid": state.request.rid, "where": f"slot {slot}",
                            "prompt_len": state.request.prompt_len,
                            "generated": state.generated})
        return out


__all__ = ["AdmittedBatch", "DuplicateRequestError", "EngineError",
           "EngineInvariantError", "EngineStalledError", "GenerationRequest",
           "GenerationResult", "InvalidRequestError", "QueueFullError",
           "RequestStatus", "ResumeTicket", "SlotState", "Scheduler",
           "default_buckets"]
