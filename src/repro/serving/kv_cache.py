"""Slot-indexed, optionally quantized KV cache for the serving engine.

The cache is a plain pytree (it flows through ``jax.jit`` / ``lax.scan`` /
donation like the rest of the model state):

    {"k": <storage>, "v": <storage>, "pos": (num_slots,) int32}

where ``<storage>`` is either a dense ``(L, S, T, Hk, D)`` array (``L``
layers, ``S`` slots, ``T`` max_len) or a :class:`QuantizedKV` — INT8 codes
plus per-(token, head, group) float16 scale/zero, groups tiling the
head_dim axis ("per-head-group"). INT8 storage costs ``1 + 4/group`` bytes
per element vs 2 for bf16, i.e. ~½ the resident bytes at ``group ≥ 32``.

Reads dequantize at the attention boundary (``models/layers.attn_apply``):
the reference path is pure jnp; on TPU the Pallas ``kv_dequant`` kernel
(``repro.kernels.ops``) does the expansion in VMEM. Dispatch follows the
same ``repro.quant.matmul_impl`` switch as the weight kernels.

Writes quantize the incoming k/v: per-slot decode writes scatter one token
at each slot's own position (``pos`` is a vector — the engine convention),
prefill writes splice a whole batch of slot rows in one dispatch
(:func:`write_slot`), and the chunked prefill works on one slot's rows via
:func:`slot_rows` / :func:`set_slot_rows`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.quant.qtensor import resolved_impl


class QuantizedKV(NamedTuple):
    """INT8 cache storage: codes + per-(…, head, group) affine params.

    ``codes``: (..., T, Hk, D) uint8; ``scale``/``zero``: (..., T, Hk, D/g)
    float16. ``group_size`` is static (pytree aux), so QuantizedKV leaves
    scan/stack/donate like dense arrays with the group layout baked in.
    """
    codes: jax.Array
    scale: jax.Array
    zero: jax.Array
    group_size: int

    def nbytes(self) -> int:
        return int(self.codes.size * self.codes.dtype.itemsize
                   + self.scale.size * self.scale.dtype.itemsize
                   + self.zero.size * self.zero.dtype.itemsize)


jax.tree_util.register_pytree_node(
    QuantizedKV,
    lambda q: ((q.codes, q.scale, q.zero), q.group_size),
    lambda aux, ch: QuantizedKV(ch[0], ch[1], ch[2], aux))


# ---------------------------------------------------------------------------
# quantize / dequantize (per-head-group asymmetric INT8)
# ---------------------------------------------------------------------------

def kv_quantize(x: jax.Array, group_size: int) -> QuantizedKV:
    """x: (..., D) float → codes (..., D) uint8 + scale/zero (..., D/g) f16.

    Asymmetric min/max over each head_dim group, with the grid stretched to
    include 0 (the ONNX convention) so the zero-point is always exactly
    representable — one-sided groups (e.g. a constant bias channel) round-
    trip instead of collapsing, and zero-initialized cache rows stay
    exactly zero. Scales are clamped to a float16-safe minimum."""
    d = x.shape[-1]
    assert d % group_size == 0, (d, group_size)
    g = x.reshape(*x.shape[:-1], d // group_size, group_size).astype(jnp.float32)
    gmax = jnp.maximum(g.max(axis=-1), 0.0)
    gmin = jnp.minimum(g.min(axis=-1), 0.0)
    scale = jnp.maximum((gmax - gmin) / 255.0, 1e-4)
    zero = jnp.clip(jnp.round(-gmin / scale), 0.0, 255.0)
    codes = jnp.clip(jnp.round(g / scale[..., None]) + zero[..., None],
                     0.0, 255.0).astype(jnp.uint8)
    return QuantizedKV(codes=codes.reshape(*x.shape[:-1], d),
                       scale=scale.astype(jnp.float16),
                       zero=zero.astype(jnp.float16),
                       group_size=group_size)


def _reference_dequant(q: QuantizedKV, dtype) -> jax.Array:
    d = q.codes.shape[-1]
    g = q.codes.reshape(*q.codes.shape[:-1], d // q.group_size,
                        q.group_size).astype(jnp.float32)
    deq = (g - q.zero[..., None].astype(jnp.float32)) \
        * q.scale[..., None].astype(jnp.float32)
    return deq.reshape(q.codes.shape).astype(dtype)


def kv_dequantize(q: QuantizedKV, dtype=jnp.float32) -> jax.Array:
    """Dense (..., T, Hk, D) values. Follows ``repro.quant.matmul_impl``:
    the Pallas kernel on the "kernel" path (native on TPU, interpret in
    tests), pure jnp on "reference" (the CPU default)."""
    if resolved_impl() == "reference":
        return _reference_dequant(q, dtype)
    from repro.kernels import ops     # local: kernels are TPU-optional
    lead = q.codes.shape[:-2]
    hk, d = q.codes.shape[-2:]
    rows = 1
    for s in lead:
        rows *= s
    flat = ops.kv_dequant(q.codes.reshape(rows, hk * d),
                          q.scale.reshape(rows, -1),
                          q.zero.reshape(rows, -1), q.group_size)
    return flat.reshape(*lead, hk, d).astype(dtype)


def fused_decode_attn(q: jax.Array, k_entry, v_entry, positions, *,
                      table=None, block_t: int = 256) -> jax.Array:
    """Fused flash-decode read of the cache: one query token per row
    attends its history in a single Pallas program — INT8 codes dequantize
    IN-TILE, no materialized dense K/V, per-row lengths bound the K loop.

    q: (B, 1, H, D) (RoPE applied); positions: (B, 1) absolute decode
    positions (row b's cache holds lengths[b] = positions[b] + 1 live
    tokens — the current token's K/V must already be written).
    ``k_entry``/``v_entry`` are the per-layer storage: (B, T, Hk, D) slot
    rows, or — with ``table`` (B, n_pages) — (P, page, Hk, D) page pools
    (dense or :class:`QuantizedKV` either way). Returns (B, 1, H, D) in
    q's dtype. The escape hatch is the caller's: ``use_fused_decode=False``
    keeps the dequant-then-attend reference path.
    """
    from repro.kernels import ops     # local: kernels are TPU-optional
    lengths = positions[:, 0].astype(jnp.int32) + 1
    q2 = q[:, 0]
    quant = isinstance(k_entry, QuantizedKV)
    kwargs = {}
    if quant:
        kwargs = dict(k_scale=k_entry.scale, k_zero=k_entry.zero,
                      v_scale=v_entry.scale, v_zero=v_entry.zero,
                      group_size=k_entry.group_size)
        k_entry, v_entry = k_entry.codes, v_entry.codes
    if table is not None:
        out = ops.decode_attn_paged(q2, k_entry, v_entry, table, lengths,
                                    **kwargs)
    else:
        out = ops.decode_attn(q2, k_entry, v_entry, lengths,
                              block_t=block_t, **kwargs)
    return out[:, None].astype(q.dtype)


def kv_update(q: QuantizedKV, x: jax.Array, pos) -> QuantizedKV:
    """Write new tokens x (B, s, Hk, D) into the (B, T, Hk, D) storage.

    ``pos`` scalar → splice s tokens at a uniform position (the static
    serving path and the chunked prefill); ``pos`` vector (B,) → scatter
    one token per row at that row's own position (the engine decode path,
    s == 1). Scalar splices scatter per token column with drop semantics:
    columns past the cache edge (a final prefill chunk's padded tail) are
    dropped instead of shifting the write like ``dynamic_update_slice``
    would."""
    new = kv_quantize(x, q.group_size)
    if getattr(pos, "ndim", 0) == 1:
        assert x.shape[1] == 1, "per-slot writes are one token per step"
        b = x.shape[0]
        idx = jnp.arange(b)
        return QuantizedKV(
            q.codes.at[idx, pos].set(new.codes[:, 0]),
            q.scale.at[idx, pos].set(new.scale[:, 0]),
            q.zero.at[idx, pos].set(new.zero[:, 0]),
            q.group_size)
    cols = pos + jnp.arange(x.shape[1])
    return QuantizedKV(
        q.codes.at[:, cols].set(new.codes),
        q.scale.at[:, cols].set(new.scale),
        q.zero.at[:, cols].set(new.zero),
        q.group_size)


# ---------------------------------------------------------------------------
# slot-cache construction / bookkeeping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    """Shape/storage policy for the engine's slot cache."""
    num_slots: int
    max_len: int
    dtype: object = jnp.float32      # dense storage dtype
    quantized: bool = False          # INT8 per-head-group storage
    group_size: int = 0              # 0 → head_dim (one group per head)


def init_slot_cache(model_cfg, cfg: KVCacheConfig) -> dict:
    """Fresh {"k", "v", "pos"} cache: (L, S, T, Hk, D) storage, per-slot
    positions. The layer axis leads so ``lax.scan`` over blocks slices one
    layer's (S, T, Hk, D) cache per step — identical to the static path."""
    shape = (model_cfg.num_layers, cfg.num_slots, cfg.max_len,
             model_cfg.num_kv_heads, model_cfg.resolved_head_dim)
    if cfg.quantized:
        g = cfg.group_size or model_cfg.resolved_head_dim
        assert model_cfg.resolved_head_dim % g == 0, (shape, g)
        store = QuantizedKV(
            codes=jnp.zeros(shape, jnp.uint8),
            scale=jnp.full(shape[:-1] + (shape[-1] // g,), 1e-4, jnp.float16),
            zero=jnp.zeros(shape[:-1] + (shape[-1] // g,), jnp.float16),
            group_size=g)
        k = store
        v = QuantizedKV(jnp.zeros_like(store.codes),
                        jnp.full_like(store.scale, 1e-4),
                        jnp.zeros_like(store.zero), g)
    else:
        k = jnp.zeros(shape, cfg.dtype)
        v = jnp.zeros(shape, cfg.dtype)
    return {"k": k, "v": v, "pos": jnp.zeros((cfg.num_slots,), jnp.int32)}


def write_slot(cache: dict, slots, k_new: jax.Array, v_new: jax.Array) -> dict:
    """Splice B freshly prefilled slot rows into the big cache in one
    dispatch.

    ``slots``: (B,) int32 slot indices (a scalar is treated as B == 1);
    ``k_new``/``v_new``: (L, B, W, Hk, D) dense floats (the batched prefill
    mini-caches), written at [:, slots[b], :W]. The scatter drops rows
    whose slot index is out of range — the batch-bucket padding convention
    (padding rows carry slot == num_slots) — and any token column past the
    cache edge, so padded bucket tails never hold live tokens."""
    out = dict(cache)
    slots = jnp.atleast_1d(jnp.asarray(slots, jnp.int32))
    for name, new in (("k", k_new), ("v", v_new)):
        entry = cache[name]
        idx_s = slots[:, None]                      # (B, 1)
        idx_t = jnp.arange(new.shape[2])[None, :]   # (1, W)
        if isinstance(entry, QuantizedKV):
            q = kv_quantize(new, entry.group_size)
            entry = QuantizedKV(
                entry.codes.at[:, idx_s, idx_t].set(q.codes),
                entry.scale.at[:, idx_s, idx_t].set(q.scale),
                entry.zero.at[:, idx_s, idx_t].set(q.zero),
                entry.group_size)
        else:
            entry = entry.at[:, idx_s, idx_t].set(new.astype(entry.dtype))
        out[name] = entry
    return out


def slot_rows(entry, slot):
    """One slot's (L, 1, T, Hk, D) rows of the (L, S, T, Hk, D) storage
    (dense or :class:`QuantizedKV`) — the chunked prefill's working view."""
    if isinstance(entry, QuantizedKV):
        return QuantizedKV(
            jax.lax.dynamic_slice_in_dim(entry.codes, slot, 1, axis=1),
            jax.lax.dynamic_slice_in_dim(entry.scale, slot, 1, axis=1),
            jax.lax.dynamic_slice_in_dim(entry.zero, slot, 1, axis=1),
            entry.group_size)
    return jax.lax.dynamic_slice_in_dim(entry, slot, 1, axis=1)


def set_slot_rows(entry, slot, rows):
    """Write a (L, 1, T, Hk, D) slot row (from :func:`slot_rows`) back into
    the (L, S, T, Hk, D) storage."""
    if isinstance(entry, QuantizedKV):
        return QuantizedKV(
            jax.lax.dynamic_update_slice_in_dim(
                entry.codes, rows.codes, slot, axis=1),
            jax.lax.dynamic_update_slice_in_dim(
                entry.scale, rows.scale, slot, axis=1),
            jax.lax.dynamic_update_slice_in_dim(
                entry.zero, rows.zero, slot, axis=1),
            entry.group_size)
    return jax.lax.dynamic_update_slice_in_dim(
        entry, rows.astype(entry.dtype), slot, axis=1)


# ---------------------------------------------------------------------------
# paged storage (the block-table layout — serving/paging.py owns the
# allocator/refcounts; this layer owns the device arrays and the scatters)
# ---------------------------------------------------------------------------

def init_paged_storage(model_cfg, num_pages: int, page_size: int,
                       dtype=jnp.float32, quantized: bool = False,
                       group_size: int = 0) -> dict:
    """Fresh {"k", "v"} page pools: (L, P, page_size, Hk, D) storage.

    Every page is allocatable (there is no pinned page); ``num_pages`` (the
    value P itself) is the SENTINEL page index — block-table entries equal
    to it drop writes (JAX scatter OOB semantics) and clip reads to the last
    physical page, whose garbage is always causally masked or discarded.
    Quantized pools carry per-(page, token, head, group) scales, so pages
    move (spill/restore, prefix sharing) without any re-quantization."""
    shape = (model_cfg.num_layers, num_pages, page_size,
             model_cfg.num_kv_heads, model_cfg.resolved_head_dim)
    if quantized:
        g = group_size or model_cfg.resolved_head_dim
        assert model_cfg.resolved_head_dim % g == 0, (shape, g)
        k = QuantizedKV(
            codes=jnp.zeros(shape, jnp.uint8),
            scale=jnp.full(shape[:-1] + (shape[-1] // g,), 1e-4, jnp.float16),
            zero=jnp.zeros(shape[:-1] + (shape[-1] // g,), jnp.float16),
            group_size=g)
        v = QuantizedKV(jnp.zeros_like(k.codes), jnp.full_like(k.scale, 1e-4),
                        jnp.zeros_like(k.zero), g)
    else:
        k = jnp.zeros(shape, dtype)
        v = jnp.zeros(shape, dtype)
    return {"k": k, "v": v}


def write_pages(kv: dict, page_map, k_new: jax.Array, v_new: jax.Array,
                page_size: int) -> dict:
    """Splice B freshly prefilled (L, B, W, Hk, D) mini-caches into the page
    pools through per-row page maps — the paged mirror of :func:`write_slot`.

    ``page_map``: (B, ceil(W/page_size)) int32 physical page per page-column
    of each row. Entries equal to the sentinel (== num_pages) drop their
    whole page column — batch-bucket padding rows carry all-sentinel maps,
    and a row's map holds exactly ceil(prompt_len/page_size) live pages, so
    the bucket-padded tail past the last allocated page is dropped (the
    garbage inside the last live page is overwritten by decode writes in
    position order before it is ever attended, as on the slot path)."""
    out = dict(kv)
    w = k_new.shape[2]
    pidx = jnp.arange(w) // page_size                   # (W,) static
    offs = jnp.arange(w) % page_size
    for name, new in (("k", k_new), ("v", v_new)):
        entry = kv[name]
        pages = page_map[:, pidx]                       # (B, W)
        off = jnp.broadcast_to(offs[None, :], pages.shape)
        if isinstance(entry, QuantizedKV):
            q = kv_quantize(new, entry.group_size)
            entry = QuantizedKV(
                entry.codes.at[:, pages, off].set(q.codes),
                entry.scale.at[:, pages, off].set(q.scale),
                entry.zero.at[:, pages, off].set(q.zero),
                entry.group_size)
        else:
            entry = entry.at[:, pages, off].set(new.astype(entry.dtype))
        out[name] = entry
    return out


def paged_view(entry, table):
    """Gather each block-table row's pages into a contiguous per-request
    view: per-layer pool (P, page, Hk, D) + table (B, n_pages) →
    (B, n_pages·page, Hk, D). Sentinel table entries clip to the last
    physical page — those positions are strictly beyond every live query's
    causal mask, so the view attends identically to a slot-cache row."""
    def gather(pool):
        b, npg = table.shape
        g = pool[table]                                 # (B, npg, page, ...)
        return g.reshape(b, npg * pool.shape[1], *pool.shape[2:])
    if isinstance(entry, QuantizedKV):
        return QuantizedKV(gather(entry.codes), gather(entry.scale),
                           gather(entry.zero), entry.group_size)
    return gather(entry)


def take_pages(entry, pages):
    """Gather whole pages across all layers: (L, P, page, …) + (N,) int32 →
    (L, N, page, …). The spill path (preemption) reads through this."""
    return jax.tree.map(lambda a: jnp.take(a, pages, axis=1), entry)


def put_pages(entry, pages, rows):
    """Scatter whole-page payloads (from :func:`take_pages`) back into the
    pool at ``pages``; sentinel indices drop (the pow2 padding convention
    of the spill/restore helpers)."""
    return jax.tree.map(lambda a, r: a.at[:, pages].set(r.astype(a.dtype)),
                        entry, rows)


def cache_bytes(cache: dict) -> int:
    """Resident bytes of the K/V storage (excludes the tiny pos vector)."""
    total = 0
    for name in ("k", "v"):
        entry = cache[name]
        if isinstance(entry, QuantizedKV):
            total += entry.nbytes()
        else:
            total += int(entry.size * entry.dtype.itemsize)
    return total


def cache_is_finite(cache: dict) -> bool:
    """Debug aid for the engine's non-finite-logit guard: True when every
    float plane of the K/V storage is finite. When the decode guard fails
    a slot, this localizes whether the corruption already lives in the
    cache (bad prefill write, spilled-page bit rot) or only in that step's
    activations. INT8 code planes are skipped (integers are always
    finite); quantized per-group scales are checked. One device reduction
    per plane — a diagnostic, not a per-step check."""
    for name in ("k", "v"):
        entry = cache[name]
        leaves = ([entry.codes, entry.scales]
                  if isinstance(entry, QuantizedKV) else [entry])
        for leaf in leaves:
            if jnp.issubdtype(leaf.dtype, jnp.floating) and not bool(
                    jnp.all(jnp.isfinite(leaf))):
                return False
    return True


__all__ = ["QuantizedKV", "KVCacheConfig", "init_slot_cache", "write_slot",
           "slot_rows", "set_slot_rows", "cache_bytes", "cache_is_finite",
           "kv_quantize", "kv_dequantize", "kv_update", "fused_decode_attn",
           "init_paged_storage", "write_pages", "paged_view", "take_pages",
           "put_pages"]
