"""Serving runtime: continuous-batching engine over a slot-indexed or
paged, optionally INT8-quantized KV cache, with per-request sampling,
shared-prefix reuse and preemption-aware scheduling.

`kv_cache` / `sampling` / `scheduler` / `paging` / `prefix_cache` are
model-free and import eagerly (``models/layers.py`` depends on `kv_cache`
for the quantized-cache hook); the `Engine` itself imports the model
stack, so it loads lazily — keeping `repro.serving.kv_cache` importable
from inside `repro.models` without a cycle.
"""
from repro.serving.faults import (ALLOC_FAIL, KINDS, NAN_LOGITS, SPILL_FAIL,
                                  FaultPlan, InjectedFault)
from repro.serving.kv_cache import (KVCacheConfig, QuantizedKV, cache_bytes,
                                    cache_is_finite, init_paged_storage,
                                    init_slot_cache, kv_dequantize,
                                    kv_quantize, kv_update,
                                    paged_view, set_slot_rows, slot_rows,
                                    write_pages, write_slot)
from repro.serving.paging import (PageAllocator, pow2_at_least,
                                  restore_pages, spill_pages)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import (AdmittedBatch, DuplicateRequestError,
                                     EngineError, EngineInvariantError,
                                     EngineStalledError, GenerationRequest,
                                     GenerationResult, InvalidRequestError,
                                     QueueFullError, RequestStatus,
                                     ResumeTicket, Scheduler)

_LAZY = ("Engine", "EngineConfig", "batch_buckets")


def __getattr__(name):
    if name in _LAZY:
        from repro.serving import engine
        return getattr(engine, name)
    raise AttributeError(name)


__all__ = ["ALLOC_FAIL", "AdmittedBatch", "DuplicateRequestError", "Engine",
           "EngineConfig", "EngineError", "EngineInvariantError",
           "EngineStalledError", "FaultPlan", "GenerationRequest",
           "GenerationResult", "InjectedFault", "InvalidRequestError",
           "KINDS", "KVCacheConfig", "NAN_LOGITS", "PageAllocator",
           "PrefixCache", "QuantizedKV", "QueueFullError", "RequestStatus",
           "ResumeTicket", "SPILL_FAIL", "SamplingParams", "Scheduler",
           "batch_buckets", "cache_bytes", "cache_is_finite",
           "init_paged_storage",
           "init_slot_cache", "kv_dequantize", "kv_quantize", "kv_update",
           "paged_view", "pow2_at_least", "restore_pages", "sample_tokens",
           "set_slot_rows", "slot_rows", "spill_pages", "write_pages",
           "write_slot"]
