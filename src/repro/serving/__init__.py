"""Serving runtime: continuous-batching engine over a slot-indexed,
optionally INT8-quantized KV cache, with per-request sampling.

`kv_cache` / `sampling` / `scheduler` are model-free and import eagerly
(``models/layers.py`` depends on `kv_cache` for the quantized-cache hook);
the `Engine` itself imports the model stack, so it loads lazily — keeping
`repro.serving.kv_cache` importable from inside `repro.models` without a
cycle.
"""
from repro.serving.kv_cache import (KVCacheConfig, QuantizedKV, cache_bytes,
                                    init_slot_cache, kv_dequantize,
                                    kv_quantize, kv_update, set_slot_rows,
                                    slot_rows, write_slot)
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import (AdmittedBatch, GenerationRequest,
                                     GenerationResult, Scheduler)

_LAZY = ("Engine", "EngineConfig", "batch_buckets")


def __getattr__(name):
    if name in _LAZY:
        from repro.serving import engine
        return getattr(engine, name)
    raise AttributeError(name)


__all__ = ["AdmittedBatch", "Engine", "EngineConfig", "GenerationRequest",
           "GenerationResult", "KVCacheConfig", "QuantizedKV",
           "SamplingParams", "Scheduler", "batch_buckets", "cache_bytes",
           "init_slot_cache", "kv_dequantize", "kv_quantize", "kv_update",
           "sample_tokens", "set_slot_rows", "slot_rows", "write_slot"]
