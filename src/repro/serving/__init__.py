"""Serving runtime: continuous-batching engine over a slot-indexed,
optionally INT8-quantized KV cache, with per-request sampling.

`kv_cache` / `sampling` / `scheduler` are model-free and import eagerly
(``models/layers.py`` depends on `kv_cache` for the quantized-cache hook);
the `Engine` itself imports the model stack, so it loads lazily — keeping
`repro.serving.kv_cache` importable from inside `repro.models` without a
cycle.
"""
from repro.serving.kv_cache import (KVCacheConfig, QuantizedKV, cache_bytes,
                                    init_slot_cache, kv_dequantize,
                                    kv_quantize, kv_update, write_slot)
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import (GenerationRequest, GenerationResult,
                                     Scheduler)

_LAZY = ("Engine", "EngineConfig")


def __getattr__(name):
    if name in _LAZY:
        from repro.serving import engine
        return getattr(engine, name)
    raise AttributeError(name)


__all__ = ["Engine", "EngineConfig", "GenerationRequest", "GenerationResult",
           "KVCacheConfig", "QuantizedKV", "SamplingParams", "Scheduler",
           "cache_bytes", "init_slot_cache", "kv_dequantize", "kv_quantize",
           "kv_update", "sample_tokens", "write_slot"]
