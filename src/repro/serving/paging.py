"""Page-pool bookkeeping for the paged KV cache.

The paged engine replaces per-slot contiguous cache rows with a fixed pool
of ``num_pages`` pages of ``page_size`` tokens each (storage in
``serving/kv_cache.py``: ``(L, P, page, Hk, D)`` dense or INT8
:class:`~repro.serving.kv_cache.QuantizedKV` with per-page scales). Each
live request owns a *block table* — a host-side list of physical page
indices covering its token positions — and the device only ever sees those
tables as plain int32 arrays, so page indirection never changes compiled
shapes.

This module is the host side of that design:

- :class:`PageAllocator` — a free-list allocator with per-page refcounts.
  A page is held by the request(s) whose block tables contain it and,
  for full prompt pages, by the prefix cache
  (:class:`~repro.serving.prefix_cache.PrefixCache`); it returns to the
  free list when the last reference drops. Double-free and
  incref-after-free raise — the invariants the paging tests pin down.
- :func:`spill_pages` / :func:`restore_pages` — preemption support: gather
  a victim's pages to host memory (one jitted gather per pow2-padded page
  count, so the rare preemption path compiles O(log max_pages) programs,
  never per request) and scatter them back into freshly allocated pages on
  resume. Payloads round-trip raw storage (INT8 codes+scales move as-is),
  so a resumed request's cache contents are bit-identical to pre-spill.

Sentinel convention (shared with kv_cache / the engine): page index
``num_pages`` marks an unallocated block-table entry. Writes to it are
dropped by JAX scatter OOB semantics; reads clip to the last physical page
and are always causally masked or discarded.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.kv_cache import put_pages, take_pages


def pow2_at_least(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


class PageAllocator:
    """Free-list page allocator with refcounts. Pure host bookkeeping —
    the device pool itself lives in the engine's kv dict.

    ``faults`` (a :class:`~repro.serving.faults.FaultPlan`) lets tests and
    chaos benchmarks inject allocation failures: a faulted :meth:`alloc`
    reports pool-dry without touching state, driving callers through their
    real escalation paths (prefix eviction → preemption → wait)."""

    def __init__(self, num_pages: int, faults=None):
        if num_pages < 1:
            raise ValueError(f"page pool needs >= 1 page, got {num_pages}")
        self.num_pages = num_pages          # also the sentinel index
        self.faults = faults
        self._free: deque = deque(range(num_pages))
        self._refs: Dict[int, int] = {}     # page -> refcount (live pages)
        self.peak_in_use = 0
        self.alloc_calls = 0                # alloc() attempts (incl. failed)
        self.alloc_failures = 0             # pool-dry / injected failures

    # -- queries -----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def refcount(self, page: int) -> int:
        """0 for free pages."""
        return self._refs.get(page, 0)

    def refs(self) -> Dict[int, int]:
        """Snapshot of live page -> refcount (the engine's invariant
        checker reconciles this against block tables + prefix cache)."""
        return dict(self._refs)

    def free_pages(self) -> List[int]:
        """Snapshot of the free list, in pop order."""
        return list(self._free)

    # -- alloc/free --------------------------------------------------------
    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop n pages (refcount 1 each), or None if the pool is short —
        the caller escalates (evict prefix entries, preempt a request)."""
        self.alloc_calls += 1
        if self.faults is not None and self.faults.fail_alloc():
            self.alloc_failures += 1
            return None                     # injected: pretend pool-dry
        if n > len(self._free):
            self.alloc_failures += 1
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return pages

    def incref(self, pages) -> None:
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"incref on free page {p}")
            self._refs[p] += 1

    def decref(self, pages) -> int:
        """Drop one reference per page; pages reaching zero return to the
        free list. Returns the number of pages actually freed."""
        freed = 0
        for p in pages:
            rc = self._refs.get(p)
            if rc is None:
                raise ValueError(f"double free of page {p}")
            if rc == 1:
                del self._refs[p]
                self._free.append(p)
                freed += 1
            else:
                self._refs[p] = rc - 1
        return freed


# ---------------------------------------------------------------------------
# preemption spill/restore (pow2-padded page counts bound the compile set)
# ---------------------------------------------------------------------------

@jax.jit
def _gather_pages_jit(entry, pages):
    return take_pages(entry, pages)


@jax.jit
def _scatter_pages_jit(entry, pages, rows):
    return put_pages(entry, pages, rows)


def spill_pages(kv: dict, pages: List[int]) -> dict:
    """Gather ``pages`` of both pools to host memory → payload dict.

    The page list is padded to a power of two (with page 0 — a harmless
    duplicate read), so the jitted gather compiles once per pow2 count.
    The payload keeps the padded shape; :func:`restore_pages` drops the
    padding through sentinel scatter indices."""
    n = pow2_at_least(len(pages))
    padded = np.zeros(n, np.int32)
    padded[:len(pages)] = pages
    idx = jnp.asarray(padded)
    return {name: jax.device_get(_gather_pages_jit(kv[name], idx))
            for name in ("k", "v")}


def restore_pages(kv: dict, pages: List[int], payload: dict,
                  num_pages: int) -> dict:
    """Scatter a spilled payload into freshly allocated ``pages`` (same
    count as at spill time); the pow2 padding lanes carry the sentinel
    index and are dropped."""
    n = next(iter(jax.tree.leaves(payload["k"]))).shape[1]
    assert n == pow2_at_least(len(pages)), (n, len(pages))
    padded = np.full(n, num_pages, np.int32)
    padded[:len(pages)] = pages
    idx = jnp.asarray(padded)
    return {name: _scatter_pages_jit(kv[name], idx, payload[name])
            for name in ("k", "v")}


__all__ = ["PageAllocator", "pow2_at_least", "spill_pages", "restore_pages"]
