"""Deterministic fault injection for the serving engine.

Overload, memory pressure, and partial failure are the steady state of the
constrained deployments AWP-compressed models target — the engine's
failure semantics (docs/serving.md §"Failure semantics") need to be
*testable*, not just asserted. A :class:`FaultPlan` is a seeded source of
injected faults that hooks into three places:

- the page allocator (``PageAllocator(..., faults=plan)``): ``alloc_fail``
  makes :meth:`PageAllocator.alloc` report pool-dry, driving the engine
  through its real escalation path (prefix eviction → preemption → wait);
- the spill/restore path (engine preemption/resume): ``spill_fail`` raises
  :class:`InjectedFault` where the page gather/scatter would run, so the
  engine must reclaim the victim's pages and fail only that request;
- the decode step (engine): ``nan_logits`` poisons one slot's finite-logit
  flag for a step, exercising the guard that fails the offending slot and
  keeps the batch serving.

``slow_step_s`` switches the engine onto the plan's VIRTUAL clock (one
tick per engine step), making deadline expiry deterministic in tests — no
wall-clock sleeps, no flakiness.

Faults draw from one seeded ``numpy`` Generator in engine call order, so a
given (plan seed, trace) pair replays the identical fault sequence; the
chaos tests and the ``engine_bench`` chaos row lean on that to assert
bit-identical survivor outputs against a fault-free run. One-shot faults
can be scripted exactly with ``script`` entries ``(step, kind)`` or
``(step, kind, rid)`` (rid only filters ``nan_logits``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Tuple

import numpy as np

ALLOC_FAIL = "alloc_fail"
SPILL_FAIL = "spill_fail"
NAN_LOGITS = "nan_logits"
KINDS = (ALLOC_FAIL, SPILL_FAIL, NAN_LOGITS)


class InjectedFault(RuntimeError):
    """Raised where an injected fault simulates a failing operation."""


@dataclasses.dataclass
class FaultPlan:
    """Seeded, deterministic fault source for one engine run.

    Rates are per-opportunity probabilities (an opportunity being one
    allocator call, one spill/restore, or one active slot × decode step).
    ``max_faults`` caps the TOTAL injected faults across kinds (-1 →
    unlimited); ``script`` fires faults at exact engine steps regardless
    of rates and does not count against the cap."""
    seed: int = 0
    alloc_fail: float = 0.0
    spill_fail: float = 0.0
    nan_logits: float = 0.0
    slow_step_s: float = 0.0           # >0 → virtual clock, this much/step
    max_faults: int = -1
    script: Tuple = ()                 # ((step, kind[, rid]), ...)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.step = 0                  # engine step counter (tick())
        self._vtime = 0.0
        self.fired: Dict[str, int] = {k: 0 for k in KINDS}
        for entry in self.script:
            if len(entry) not in (2, 3) or entry[1] not in KINDS:
                raise ValueError(f"bad script entry {entry!r}: want "
                                 f"(step, kind[, rid]) with kind in {KINDS}")

    # -- engine integration ------------------------------------------------
    def tick(self) -> None:
        """Advance one engine step (and the virtual clock)."""
        self.step += 1
        self._vtime += self.slow_step_s

    def now(self) -> float:
        """The engine's clock: virtual when ``slow_step_s`` is set (so
        deadline tests are deterministic), wall-clock otherwise."""
        return self._vtime if self.slow_step_s > 0 else time.perf_counter()

    # -- fault draws (engine call order == replay order) -------------------
    def _scripted(self, kind: str, rid: int = -1) -> bool:
        for entry in self.script:
            if entry[0] == self.step and entry[1] == kind and \
                    (len(entry) < 3 or rid < 0 or entry[2] == rid):
                return True
        return False

    def _draw(self, kind: str, rate: float) -> bool:
        if rate <= 0.0:
            return False
        if 0 <= self.max_faults <= sum(self.fired.values()):
            return False
        hit = bool(self._rng.random() < rate)
        if hit:
            self.fired[kind] += 1
        return hit

    def fail_alloc(self) -> bool:
        """One allocator call pretends the pool is dry."""
        if self._scripted(ALLOC_FAIL):
            return True
        return self._draw(ALLOC_FAIL, self.alloc_fail)

    def check_spill(self, what: str = "spill") -> None:
        """Raise :class:`InjectedFault` where a spill/restore would run."""
        if self._scripted(SPILL_FAIL) or self._draw(SPILL_FAIL,
                                                    self.spill_fail):
            raise InjectedFault(f"injected {what} failure "
                                f"(step {self.step})")

    def poison_logits(self, rid: int) -> bool:
        """True → treat this slot's decode logits as non-finite this step."""
        if self._scripted(NAN_LOGITS, rid):
            return True
        return self._draw(NAN_LOGITS, self.nan_logits)

    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())


__all__ = ["FaultPlan", "InjectedFault", "ALLOC_FAIL", "SPILL_FAIL",
           "NAN_LOGITS", "KINDS"]
