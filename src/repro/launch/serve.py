"""Serving launcher: static batch or continuous-batching engine, with
optional compressed weights (what the paper compresses models FOR).

Static path (one prefill + fixed-length greedy decode, uniform batch):

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --tiny \
      --batch 8 --prompt-len 32 --gen 32 [--ckpt results/compressed_ckpt]

Engine path (slot-based continuous batching over a mixed-length trace,
batched same-bucket admissions, chunked prefill for prompts beyond the
largest bucket, per-request sampling, optional INT8 KV cache — see
docs/serving.md):

  PYTHONPATH=src python -m repro.launch.serve --tiny --engine continuous \
      --requests 32 --slots 8 --gen 32 [--buckets 8,16] [--kv-quant] \
      [--kv paged --page-size 16 --pages 0] [--mixed-admission] [--verify]

``--kv paged`` swaps the slot cache for a fixed-size-page pool with a
free-list allocator, refcounted shared-prefix page reuse, and
preemption-aware scheduling (youngest spills to host under page
pressure, resumes bit-identically). Greedy outputs stay bit-identical
to the slot engine whenever page-size divides max-len.

With ``--packed`` the checkpoint is a packed QTensor checkpoint (written by
``repro.launch.compress --save-packed``): quantized layers stay packed
``QTensor`` leaves of the param tree end-to-end — the jitted forward pass
reads the integer codes through the fused dequant-matmul, no dense floats
are ever materialized for them (``--materialize`` restores the legacy
dense expansion). Token selection runs inside the jitted steps on both
paths, so decode transfers one int32 per request per step, not the logits.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_tiny_config
from repro.data import DataConfig, ZipfMarkov
from repro.models import build_model
from repro.quant import QTensor


def qtensor_leaves(params) -> list:
    """The QTensor leaves of a params tree. ``is_leaf`` stops traversal AT
    each QTensor so stacked leaves are counted once (their children carry
    the block/expert dims)."""
    return [l for l in jax.tree.leaves(
                params, is_leaf=lambda x: isinstance(x, QTensor))
            if isinstance(l, QTensor)]


def dense_itemsize(params) -> int:
    """Bytes per element of the tree's dense serving dtype: the first
    floating dense leaf decides (bf16 trees → 2, f32 → 4; QTensor children
    are skipped — their f32 scales are not the serving dtype). 4 when the
    tree has no dense float leaf."""
    for leaf in jax.tree.leaves(params,
                                is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            continue
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            return jnp.dtype(leaf.dtype).itemsize
    return 4


def packed_weight_bytes(params) -> tuple:
    """(packed_bytes, dense_equiv_bytes) over the QTensor leaves of params.
    The dense equivalent is counted at the tree's ACTUAL dense dtype (a
    bf16 serving tree compares against 2-byte floats, not a hardcoded 4)."""
    itemsize = dense_itemsize(params)
    packed = dense = 0
    for leaf in qtensor_leaves(params):
        packed += leaf.nbytes()
        nibble = leaf.bits == 4 and leaf.packed.shape[-1] * 2 == leaf.shape[1]
        dense += leaf.packed.size * (2 if nibble else 1) * itemsize
    return packed, dense


def make_step_fns(model):
    """Jitted (prefill_fn, decode_fn) with greedy token selection folded in:
    each returns ``(tokens (B,1) int32, cache)`` — full logits never leave
    the device during decode."""
    def prefill_fn(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        return jnp.argmax(logits[:, -1], -1)[:, None], cache

    def decode_fn(params, tok, cache):
        logits, cache = model.decode_step(params, tok, cache)
        return jnp.argmax(logits[:, -1], -1)[:, None], cache

    return (jax.jit(prefill_fn),
            jax.jit(decode_fn, donate_argnums=2))


def _load_params(args, model):
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt and args.packed:
        params, qts, manifest = CheckpointManager(
            args.ckpt).restore_latest_packed(params,
                                             materialize=args.materialize)
        if params is None:
            raise SystemExit(f"[serve] no checkpoint under {args.ckpt}")
        itemsize = dense_itemsize(params)
        dense = sum(int(np.prod(qt.shape)) * itemsize for qt in qts.values())
        packed_b = sum(qt.nbytes() for qt in qts.values())
        resident, _ = packed_weight_bytes(params)
        print(f"[serve] loaded packed checkpoint step {manifest['step']}: "
              f"{len(qts)} QTensor layers, "
              f"{dense / 1e6:.1f}MB dense ({itemsize}B/elem) -> "
              f"{packed_b / 1e6:.1f}MB packed")
        note = ""
        if args.materialize:
            note = " (materialized dense — legacy path)"
        elif resident == 0:
            note = " (no leaf qualified for packing — serving dense)"
        print(f"[serve] {resident / 1e6:.1f}MB packed weights resident in "
              f"the param tree" + note)
    elif args.ckpt:
        restored, step = CheckpointManager(args.ckpt).restore_latest(
            {"params": params})
        if restored is not None:
            params = restored["params"]
            print(f"[serve] loaded checkpoint step {step}")
    return params


def _serve_static(args, cfg, model, params):
    gen = ZipfMarkov(DataConfig(vocab_size=cfg.vocab_size,
                                seq_len=args.prompt_len,
                                global_batch=args.batch))
    prompts, _ = gen.batch(0)
    max_len = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, max_len, jnp.float32)

    prefill, decode = make_step_fns(model)

    t0 = time.time()
    tok, cache = prefill(params, {"tokens": jnp.asarray(prompts)}, cache)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    t1 = time.time()
    for _ in range(args.gen - 1):
        tok, cache = decode(params, tok, cache)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"[serve] batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"[serve] prefill {args.batch * args.prompt_len / t_prefill:.0f} tok/s, "
          f"decode {args.batch * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s")
    print(f"[serve] sample continuation (req 0): {seqs[0][:16].tolist()}")


def build_trace(cfg, *, num_requests: int, max_prompt: int, max_new: int,
                seed: int = 0, temperature: float = 0.0, top_k: int = 0):
    """Mixed-length request trace off the Zipf-Markov corpus: Zipf-ish
    prompt/output lengths (many short, a heavy tail), FIFO submit order."""
    from repro.serving import GenerationRequest, SamplingParams
    gen = ZipfMarkov(DataConfig(vocab_size=cfg.vocab_size, seq_len=max_prompt,
                                global_batch=1, seed=seed))
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(num_requests):
        plen = int(np.clip(rng.zipf(1.6), 1, max_prompt))
        nnew = int(np.clip(rng.zipf(1.4), 1, max_new))
        toks, _ = gen.batch(i)
        reqs.append(GenerationRequest(
            rid=i, prompt=toks[0, :plen].astype(np.int32),
            max_new_tokens=nnew,
            sampling=SamplingParams(temperature=temperature, top_k=top_k,
                                    seed=seed + i)))
    return reqs


def static_greedy_reference(model, params, req, max_len,
                            step_fns=None) -> list:
    """One request on the STATIC path: batch=1, exact prompt, greedy decode,
    same cache length as the engine's slots — the per-request bit-parity
    oracle shared by ``--verify``, engine_bench, and the engine tests."""
    prefill, decode = step_fns or make_step_fns(model)
    cache = model.init_cache(1, max_len, jnp.float32)
    tok, cache = prefill(params, {"tokens": jnp.asarray(req.prompt[None, :])},
                         cache)
    out = [int(tok[0, 0])]
    for _ in range(req.max_new_tokens - 1):
        tok, cache = decode(params, tok, cache)
        out.append(int(tok[0, 0]))
    return out


def _verify_against_static(model, params, reqs, results, max_len) -> tuple:
    """Greedy engine outputs must be bit-identical to the static path run
    per request (same cache length). Requests that never completed —
    shed at --max-queue, cancelled, errored — have no reference to match
    and are skipped. Returns the mismatch count."""
    step_fns = make_step_fns(model)
    by_rid = {r.rid: r.tokens for r in results if r.ok}
    bad = checked = 0
    for req in reqs:
        if req.rid not in by_rid:
            continue
        checked += 1
        ref = static_greedy_reference(model, params, req, max_len, step_fns)
        if by_rid[req.rid] != ref:
            bad += 1
            print(f"[serve]   MISMATCH rid={req.rid}: {by_rid[req.rid]} != {ref}")
    return bad, checked


def _interval_printer(every: int):
    """Step hook: one-line engine stats every ``every`` steps."""
    t0 = time.time()
    n = 0

    def on_step(eng):
        nonlocal n
        n += 1
        if n % every:
            return
        print(f"[serve] step {n}: active "
              f"{eng.scheduler.num_active}/{eng.cfg.num_slots}, queue "
              f"{len(eng.scheduler.queue)}, decode steps {eng.decode_steps}, "
              f"util {eng.utilization():.2f}, {time.time() - t0:.1f}s")

    return on_step


def _serve_engine(args, cfg, model, params):
    from repro.obs import StepTraceWindow
    from repro.serving import Engine, EngineConfig

    max_len = min(args.max_len, args.prompt_len + args.gen) \
        if args.max_len else args.prompt_len + args.gen
    if max_len <= args.gen:
        raise SystemExit(f"[serve] --max-len {max_len} leaves no room for "
                         f"prompts at --gen {args.gen}")
    buckets = tuple(int(b) for b in args.buckets.split(",")) \
        if args.buckets else ()
    ecfg = EngineConfig(num_slots=args.slots, max_len=max_len,
                        prompt_buckets=buckets,
                        kv_quantized=args.kv_quant,
                        kv_dtype=jnp.float32,
                        kv_layout=args.kv,
                        page_size=args.page_size,
                        num_pages=args.pages,
                        prefix_caching=not args.no_prefix_cache,
                        mixed_admission=args.mixed_admission,
                        max_queue=args.max_queue,
                        use_fused_decode=not args.no_fused_decode)
    engine = Engine(model, params, ecfg)
    reqs = build_trace(cfg, num_requests=args.requests,
                       max_prompt=min(args.prompt_len, max_len - args.gen),
                       max_new=args.gen, seed=args.seed,
                       temperature=args.temperature, top_k=args.top_k)
    compiled = engine.warmup(reqs)

    hooks = []
    prof = StepTraceWindow(args.profile_dir, args.profile_steps)
    if prof.enabled:
        print(f"[serve] profiling first {args.profile_steps} steps -> "
              f"{args.profile_dir}")
        prof.start()
        hooks.append(prof.on_step)
    if args.metrics_interval > 0:
        hooks.append(_interval_printer(args.metrics_interval))
    hook = None
    if hooks:
        def hook(eng, _hooks=tuple(hooks)):
            for h in _hooks:
                h(eng)

    t0 = time.time()
    for r in reqs:
        engine.try_submit(r)           # --max-queue sheds, never raises
    results = engine.run(step_hook=hook)
    prof.stop()                        # no-op unless still inside the window
    wall = time.time() - t0
    after = engine.compile_counts()

    done = [r for r in results if r.ok]
    statuses = {}
    for r in results:
        statuses[r.status] = statuses.get(r.status, 0) + 1
    n_tok = sum(len(r.tokens) for r in results)
    lats = sorted(r.latency for r in done) or [0.0]
    p50 = lats[len(lats) // 2]
    p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
    print(f"[serve] engine: {len(results)} requests, {n_tok} tokens in "
          f"{wall:.2f}s -> {n_tok / wall:.0f} tok/s")
    qs = engine.queue_stats()
    print(f"[serve] statuses {statuses}, queue depth peak {qs['peak']} "
          f"mean {qs['mean']:.1f}"
          + (f", {qs['rejected']} shed at --max-queue {args.max_queue}"
             if args.max_queue else ""))
    print(f"[serve] latency p50 {p50 * 1e3:.1f}ms p99 {p99 * 1e3:.1f}ms, "
          f"slot utilization {engine.utilization():.2f}")
    admit_note = (f"[serve] admissions: {engine.prefill_admitted} requests "
                  f"via {engine.prefill_dispatches} batched prefill "
                  f"dispatches")
    if engine.chunked_admitted:
        admit_note += (f", {engine.chunked_admitted} chunked prompts via "
                       f"{engine.chunk_dispatches} chunk dispatches")
    print(admit_note)
    print(f"[serve] kv cache resident "
          f"{engine.kv_cache_bytes() / 1e6:.2f}MB "
          f"({'int8' if args.kv_quant else 'dense'}, {args.kv}), "
          f"compiled programs {after} (warmup {compiled})")
    ps = engine.page_stats()
    if ps:
        print(f"[serve] pages: {ps['pages_in_use']}/{ps['num_pages']} in use "
              f"(peak {ps['peak_pages_in_use']}, size {ps['page_size']}), "
              f"{ps['prefix_cached_pages']} prefix-cached")
        hits, misses = ps["prefix_hits"], ps["prefix_misses"]
        rate = hits / max(hits + misses, 1)
        print(f"[serve] prefix cache: {hits} hits / {misses} misses "
              f"({rate:.0%}), {ps['prefix_hit_tokens']} prompt tokens reused")
        print(f"[serve] preemptions {ps['preemptions']}, resumes "
              f"{ps['resumes']}, pages spilled {ps['pages_spilled']}")
    if None in after.values() or None in compiled.values():
        print("[serve] note: jit cache sizes unavailable on this jax — "
              "recompilation check is UNKNOWN")
    elif after != compiled:
        print("[serve] WARNING: recompilation after warmup")
    if args.verify:
        if args.temperature > 0:
            print("[serve] --verify needs greedy (temperature 0); skipping")
        elif args.kv_quant:
            print("[serve] --verify compares dense-KV greedy; skipping "
                  "under --kv-quant")
        else:
            bad, checked = _verify_against_static(model, params, reqs,
                                                  results, max_len)
            for r in sorted(results, key=lambda r: r.rid):
                print(f"[serve]   rid={r.rid} status={r.status} "
                      f"queue {r.queue_time * 1e3:.1f}ms "
                      f"ttft {r.ttft * 1e3:.1f}ms "
                      f"tpot {r.tpot * 1e3:.2f}ms "
                      f"({len(r.tokens)} tok)")
            print(f"[serve] verify vs static path: "
                  f"{checked - bad}/{checked} completed requests "
                  f"bit-identical ({len(reqs) - checked} not completed)")
            if bad:
                raise SystemExit(1)

    if args.metrics_json:
        engine.metrics_snapshot()      # refresh the state gauges
        engine.metrics.dump_json(args.metrics_json, meta={
            "source": "serve", "engine": "continuous", "arch": args.arch,
            "layout": args.kv, "requests": len(reqs), "wall_s": wall})
        prom = args.metrics_json + ".prom"
        with open(prom, "w") as f:
            f.write(engine.metrics.to_prometheus())
        print(f"[serve] metrics snapshot -> {args.metrics_json} (+ {prom})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--packed", action="store_true",
                    help="--ckpt is a packed QTensor checkpoint")
    ap.add_argument("--materialize", action="store_true",
                    help="with --packed: expand quantized layers to dense "
                         "floats (legacy path) instead of serving packed")
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static",
                    help="static: uniform batch, one prefill + N decodes; "
                         "continuous: slot-based continuous batching")
    ap.add_argument("--slots", type=int, default=8,
                    help="engine: device slots (concurrent requests)")
    ap.add_argument("--requests", type=int, default=32,
                    help="engine: trace length (mixed-length requests)")
    ap.add_argument("--max-len", type=int, default=0,
                    help="engine: slot KV length (0 -> prompt+gen)")
    ap.add_argument("--buckets", default="",
                    help="engine: comma-separated prompt buckets (empty -> "
                         "pow2 buckets covering max-len); prompts beyond "
                         "the largest bucket stream via chunked prefill")
    ap.add_argument("--kv-quant", action="store_true",
                    help="engine: INT8 per-head-group KV cache")
    ap.add_argument("--kv", choices=("slots", "paged"), default="slots",
                    help="engine KV layout: slots (one contiguous max-len "
                         "row per slot) or paged (fixed-size-page pool with "
                         "shared-prefix reuse and preemption)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="engine --kv paged: tokens per KV page (greedy "
                         "bit-parity with slots needs page-size | max-len)")
    ap.add_argument("--pages", type=int, default=0,
                    help="engine --kv paged: page-pool size (0 -> "
                         "slots * ceil(max-len / page-size), i.e. the slot "
                         "engine's footprint; smaller pools oversubscribe "
                         "and trigger preemption)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="engine --kv paged: disable shared-prefix page "
                         "reuse across requests")
    ap.add_argument("--mixed-admission", action="store_true",
                    help="engine: admit mixed-bucket FIFO head-runs in one "
                         "right-padded prefill dispatch")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="engine: bound the admission queue — submissions "
                         "past the bound shed with a 'rejected' status "
                         "(0 -> unbounded)")
    ap.add_argument("--no-fused-decode", action="store_true",
                    help="engine: revert decode cache reads to the "
                         "dequant-then-attend reference path instead of "
                         "the fused Pallas flash-decode kernel")
    ap.add_argument("--metrics-json", default="",
                    help="engine: write the registry snapshot as JSON here "
                         "(plus Prometheus text exposition at PATH.prom)")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    help="engine: print one-line stats every N steps "
                         "(0 -> off)")
    ap.add_argument("--profile-steps", type=int, default=0,
                    help="engine: jax.profiler trace window around the "
                         "first N steps (needs --profile-dir)")
    ap.add_argument("--profile-dir", default="",
                    help="directory for jax.profiler traces")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="engine: assert greedy outputs are bit-identical "
                         "to the static path per request")
    args = ap.parse_args()
    if args.packed and not args.ckpt:
        ap.error("--packed requires --ckpt")

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = build_model(cfg, remat=False)
    params = _load_params(args, model)
    if args.engine == "continuous":
        _serve_engine(args, cfg, model, params)
    else:
        _serve_static(args, cfg, model, params)


if __name__ == "__main__":
    main()
