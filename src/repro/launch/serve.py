"""Serving launcher: batched prefill + decode with optional compressed
weights (what the paper compresses models FOR).

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --tiny \
      --batch 8 --prompt-len 32 --gen 32 [--ckpt results/compressed_ckpt]

With ``--packed`` the checkpoint is a packed QTensor checkpoint (written by
``repro.launch.compress --save-packed``): quantized layers stay packed
``QTensor`` leaves of the param tree end-to-end — the jitted forward pass
reads the integer codes through the fused dequant-matmul, no dense floats
are ever materialized for them (``--materialize`` restores the legacy
dense expansion). Greedy sampling runs inside the jitted prefill/decode
steps, so decode transfers one int32 per request per step, not the logits.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_tiny_config
from repro.data import DataConfig, ZipfMarkov
from repro.models import build_model
from repro.quant import QTensor


def qtensor_leaves(params) -> list:
    """The QTensor leaves of a params tree. ``is_leaf`` stops traversal AT
    each QTensor so stacked leaves are counted once (their children carry
    the block/expert dims)."""
    return [l for l in jax.tree.leaves(
                params, is_leaf=lambda x: isinstance(x, QTensor))
            if isinstance(l, QTensor)]


def packed_weight_bytes(params) -> tuple:
    """(packed_bytes, dense_equiv_bytes) over the QTensor leaves of params."""
    packed = dense = 0
    for leaf in qtensor_leaves(params):
        packed += leaf.nbytes()
        nibble = leaf.bits == 4 and leaf.packed.shape[-1] * 2 == leaf.shape[1]
        dense += leaf.packed.size * (2 if nibble else 1) * 4
    return packed, dense


def make_step_fns(model):
    """Jitted (prefill_fn, decode_fn) with greedy token selection folded in:
    each returns ``(tokens (B,1) int32, cache)`` — full logits never leave
    the device during decode."""
    def prefill_fn(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        return jnp.argmax(logits[:, -1], -1)[:, None], cache

    def decode_fn(params, tok, cache):
        logits, cache = model.decode_step(params, tok, cache)
        return jnp.argmax(logits[:, -1], -1)[:, None], cache

    return (jax.jit(prefill_fn),
            jax.jit(decode_fn, donate_argnums=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--packed", action="store_true",
                    help="--ckpt is a packed QTensor checkpoint")
    ap.add_argument("--materialize", action="store_true",
                    help="with --packed: expand quantized layers to dense "
                         "floats (legacy path) instead of serving packed")
    args = ap.parse_args()
    if args.packed and not args.ckpt:
        ap.error("--packed requires --ckpt")

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt and args.packed:
        params, qts, manifest = CheckpointManager(
            args.ckpt).restore_latest_packed(params,
                                             materialize=args.materialize)
        if params is None:
            raise SystemExit(f"[serve] no checkpoint under {args.ckpt}")
        dense = sum(int(np.prod(qt.shape)) * 4 for qt in qts.values())
        packed_b = sum(qt.nbytes() for qt in qts.values())
        resident, _ = packed_weight_bytes(params)
        print(f"[serve] loaded packed checkpoint step {manifest['step']}: "
              f"{len(qts)} QTensor layers, "
              f"{dense / 1e6:.1f}MB dense -> {packed_b / 1e6:.1f}MB packed")
        note = ""
        if args.materialize:
            note = " (materialized dense — legacy path)"
        elif resident == 0:
            note = " (no leaf qualified for packing — serving dense)"
        print(f"[serve] {resident / 1e6:.1f}MB packed weights resident in "
              f"the param tree" + note)
    elif args.ckpt:
        restored, step = CheckpointManager(args.ckpt).restore_latest(
            {"params": params})
        if restored is not None:
            params = restored["params"]
            print(f"[serve] loaded checkpoint step {step}")

    gen = ZipfMarkov(DataConfig(vocab_size=cfg.vocab_size,
                                seq_len=args.prompt_len,
                                global_batch=args.batch))
    prompts, _ = gen.batch(0)
    max_len = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, max_len, jnp.float32)

    prefill, decode = make_step_fns(model)

    t0 = time.time()
    tok, cache = prefill(params, {"tokens": jnp.asarray(prompts)}, cache)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    t1 = time.time()
    for _ in range(args.gen - 1):
        tok, cache = decode(params, tok, cache)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"[serve] batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"[serve] prefill {args.batch * args.prompt_len / t_prefill:.0f} tok/s, "
          f"decode {args.batch * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s")
    print(f"[serve] sample continuation (req 0): {seqs[0][:16].tolist()}")


if __name__ == "__main__":
    main()
