"""Serving launcher: batched prefill + decode with optional compressed
weights (what the paper compresses models FOR).

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --tiny \
      --batch 8 --prompt-len 32 --gen 32 [--ckpt results/compressed_ckpt]

With ``--packed`` the checkpoint is a packed QTensor checkpoint (written by
``repro.launch.compress --save-packed``): the quantized layers are loaded
straight from their integer codes — no dense floats are re-quantized.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_tiny_config
from repro.data import DataConfig, ZipfMarkov
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--packed", action="store_true",
                    help="--ckpt is a packed QTensor checkpoint")
    args = ap.parse_args()
    if args.packed and not args.ckpt:
        ap.error("--packed requires --ckpt")

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt and args.packed:
        params, qts, manifest = CheckpointManager(
            args.ckpt).restore_latest_packed(params)
        if params is None:
            raise SystemExit(f"[serve] no checkpoint under {args.ckpt}")
        dense = sum(int(np.prod(qt.shape)) * 4 for qt in qts.values())
        packed_b = sum(qt.nbytes() for qt in qts.values())
        print(f"[serve] loaded packed checkpoint step {manifest['step']}: "
              f"{len(qts)} QTensor layers, "
              f"{dense / 1e6:.1f}MB dense -> {packed_b / 1e6:.1f}MB packed")
    elif args.ckpt:
        restored, step = CheckpointManager(args.ckpt).restore_latest(
            {"params": params})
        if restored is not None:
            params = restored["params"]
            print(f"[serve] loaded checkpoint step {step}")

    gen = ZipfMarkov(DataConfig(vocab_size=cfg.vocab_size,
                                seq_len=args.prompt_len,
                                global_batch=args.batch))
    prompts, _ = gen.batch(0)
    max_len = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, max_len, jnp.float32)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=2)

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)}, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    t_prefill = time.time() - t0

    out = [tok]
    t1 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"[serve] batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"[serve] prefill {args.batch * args.prompt_len / t_prefill:.0f} tok/s, "
          f"decode {args.batch * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s")
    print(f"[serve] sample continuation (req 0): {seqs[0][:16].tolist()}")


if __name__ == "__main__":
    main()
