import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

Two lowerings per cell (see EXPERIMENTS.md §Dry-run "methodology"):

1. PRODUCTION lowering — the real scanned/remat program at full depth.
   Proves the cell compiles and fits: memory_analysis() is recorded.
2. COST lowerings — XLA's HloCostAnalysis counts a while-loop body ONCE
   (verified empirically), so scanned programs under-report FLOPs/bytes/
   collectives by ~num_layers ×. We therefore lower reduced-depth UNROLLED
   variants (every repeat-scan a python loop, chunk scans single-iteration)
   at 2-3 depths and reconstruct full-depth costs by exact linear fit
   f(L) = fixed + L·per_layer (+ ceil(L/p)·per_shared for the hybrid).

Everything is ShapeDtypeStruct — no allocation.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--compress] [--timeout N]
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

from repro import compat

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _out_path(mesh_name, arch, shape):
    d = os.path.abspath(os.path.join(RESULTS_DIR, mesh_name))
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}.json")


# ---------------------------------------------------------------------------
# lowering builders
# ---------------------------------------------------------------------------

def _build_lowered(cfg, mesh, shape, kind, *, unroll: bool, n_micro: int):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import build_model, batch_specs
    from repro.optim import OptimizerConfig
    from repro.sharding import (rules_for_cell, tree_shardings,
                                opt_logical_axes)
    from repro.training.train_loop import TrainConfig, make_train_step

    rules = rules_for_cell(mesh, cfg.family, kind,
                           global_batch=shape.global_batch)
    model = build_model(cfg, rules, param_dtype=jnp.bfloat16, remat=True)
    model.unroll = unroll
    model.attn_p_dtype = jnp.bfloat16   # TPU-flash convention (§Perf)
    if unroll:
        model.attn_chunk = max(shape.seq_len, 1024)
        model.logit_chunk = shape.seq_len
        if hasattr(model, "scan_chunk"):
            model.scan_chunk = shape.seq_len

    param_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_logical = model.param_logical_axes()
    p_shardings = tree_shardings(rules, p_logical, param_sds)
    n_active = cfg.active_param_count()

    if kind == "train":
        import jax.numpy as _jnp
        n_param = cfg.param_count()
        opt_name = "adafactor" if n_param > 20e9 else "adamw"
        # each microbatch must still span every batch shard
        batch_shards = rules.axis_size(rules.batch_axes)
        n_micro = max(1, min(n_micro, shape.global_batch // batch_shards))
        accum = _jnp.bfloat16 if (n_param > 100e9 and n_micro > 1) else _jnp.float32
        tcfg = TrainConfig(optimizer=OptimizerConfig(name=opt_name),
                           microbatches=n_micro, accum_dtype=accum,
                           unroll_accum=unroll)  # cost fit: count per-micro
                                                 # collectives (FSDP regathers)
        step_fn, opt_init = make_train_step(model, tcfg)
        opt_sds = jax.eval_shape(opt_init, param_sds)
        o_logical = opt_logical_axes(opt_name, p_logical, param_sds)
        o_shardings = tree_shardings(rules, o_logical, opt_sds)
        state_sds = {"params": param_sds, "opt": opt_sds,
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
        state_sh = {"params": p_shardings, "opt": o_shardings,
                    "step": NamedSharding(mesh, P())}
        b_sds = batch_specs(cfg, shape.global_batch, shape.seq_len)
        b_sh = {k: NamedSharding(mesh, rules.spec(("batch",) + (None,) * (len(v.shape) - 1),
                                                  v.shape))
                for k, v in b_sds.items()}
        with compat.set_mesh(mesh):
            lowered = jax.jit(step_fn, in_shardings=(state_sh, b_sh),
                              donate_argnums=0).lower(state_sds, b_sds)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
        return lowered, model_flops

    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 jnp.bfloat16))
    c_shardings = tree_shardings(rules, model.cache_logical_axes(), cache_sds)
    if kind == "prefill":
        b_sds = batch_specs(cfg, shape.global_batch, shape.seq_len)
        b_sds.pop("labels", None)
        b_sh = {k: NamedSharding(mesh, rules.spec(("batch",) + (None,) * (len(v.shape) - 1),
                                                  v.shape))
                for k, v in b_sds.items()}
        def serve_step(params, batch, cache):
            return model.prefill(params, batch, cache)
        args_sds = (param_sds, b_sds, cache_sds)
        args_sh = (p_shardings, b_sh, c_shardings)
        tokens = shape.global_batch * shape.seq_len
    else:  # decode: one new token against a seq_len-deep cache
        tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_sh = NamedSharding(mesh, rules.spec(("batch", None), tok_sds.shape))
        def serve_step(params, tokens_, cache):
            return model.decode_step(params, tokens_, cache)
        args_sds = (param_sds, tok_sds, cache_sds)
        args_sh = (p_shardings, tok_sh, c_shardings)
        tokens = shape.global_batch
    with compat.set_mesh(mesh):
        lowered = jax.jit(serve_step, in_shardings=args_sh,
                          donate_argnums=2).lower(*args_sds)
    return lowered, 2.0 * n_active * tokens


def _costs_of(compiled, hlo=None):
    from repro.analysis import roofline as rl
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    text = hlo if hlo is not None else compiled.as_text()
    coll = rl.collective_bytes(text)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collectives": coll,
            "collective_total": float(sum(coll.values()))}


def _fit_costs(cfg, mesh, shape, kind, n_micro):
    """Reduced-depth unrolled lowerings → exact linear reconstruction."""
    p = cfg.attn_every if cfg.family == "hybrid" else 0
    if p:
        depths = [p, p + 2, 2 * p]        # solves (fixed, per_layer, per_shared)
    else:
        depths = [1, 2]
    samples = []
    for L in depths:
        sub = dataclasses.replace(cfg, num_layers=L)
        lowered, _ = _build_lowered(sub, mesh, shape, kind,
                                    unroll=True, n_micro=n_micro)
        samples.append(_costs_of(lowered.compile()))

    import numpy as np
    def feats(L):
        row = [1.0, float(L)]
        if p:
            row.append(float(-(-L // p)))
        return row
    A = np.array([feats(L) for L in depths])

    def predict(vals):
        coef, *_ = np.linalg.lstsq(A, np.array(vals), rcond=None)
        # guard: the full model can never cost less than the deepest sample
        # (negative per-layer slopes = XLA hoisting artifacts at tiny L)
        return float(max(np.dot(feats(cfg.num_layers), coef), max(vals), 0.0))

    out = {"flops": predict([s["flops"] for s in samples]),
           "bytes": predict([s["bytes"] for s in samples]),
           "collective_total": predict(
               [s["collective_total"] for s in samples])}
    out["collectives"] = {
        k: predict([s["collectives"][k] for s in samples])
        for k in samples[0]["collectives"]}
    out["fit_depths"] = depths
    out["fit_samples"] = samples
    return out


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    if "argument_size_in_bytes" in out and "temp_size_in_bytes" in out:
        out["total_per_device_bytes"] = (out["argument_size_in_bytes"]
                                         + out["output_size_in_bytes"]
                                         + out["temp_size_in_bytes"]
                                         - out.get("alias_size_in_bytes", 0))
    return out


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_name: str) -> dict:
    import jax
    from repro.analysis import roofline as rl
    from repro.configs import get_config, SHAPES
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size
    t0 = time.time()

    if shape_name == "compress":
        return _lower_compress(cfg, mesh, chips)

    shape = SHAPES[shape_name]
    kind = shape.kind
    # ≤20B: full-batch step (grad-accum carry costs more than activations);
    # bigger: accumulate over micro-batches to bound a2a/attention transients.
    n = cfg.param_count()
    default_micro = "16" if n > 100e9 else ("8" if n > 20e9 else "1")
    n_micro = int(os.environ.get("DRYRUN_MICRO", default_micro))

    # 1) production lowering: compile + memory proof
    lowered, model_flops = _build_lowered(cfg, mesh, shape, kind,
                                          unroll=False, n_micro=n_micro)
    compiled = lowered.compile()
    mem = _mem_dict(compiled.memory_analysis())
    print(f"memory_analysis: {mem}")
    raw = _costs_of(compiled)

    # 2) cost lowerings: reduced-depth unrolled + linear fit. The microbatch
    # loop is unrolled too (unroll_accum) so per-microbatch FSDP re-gathers
    # are counted — total cost is NOT microbatch-invariant.
    fit = _fit_costs(cfg, mesh, shape, kind, n_micro)
    print(f"cost (scan-corrected): flops={fit['flops']:.3e} "
          f"bytes={fit['bytes']:.3e} coll={fit['collective_total']:.3e}")

    roof = rl.Roofline(flops_per_device=fit["flops"],
                       bytes_per_device=fit["bytes"],
                       collective_per_device=fit["collective_total"],
                       chips=chips, model_flops=model_flops)
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "kind": kind,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "memory": mem,
        "raw_scan_costs": raw,        # uncorrected, for reference
        "cost_fit": {k: fit[k] for k in
                     ("flops", "bytes", "collective_total", "collectives",
                      "fit_depths")},
        "roofline": roof.to_dict(),
        "compile_seconds": time.time() - t0,
    }


def _lower_compress(cfg, mesh, chips) -> dict:
    """Extra cell: the AWP compression step itself on the arch's largest
    linear — row-sharded (zero-collective) or column-sharded when replicated
    C would not fit (d_in > 46k). Loop unrolled ⇒ costs are exact."""
    import jax
    import jax.numpy as jnp
    from repro.analysis import roofline as rl
    from repro.core import distributed as dist
    from repro.core import projections as proj
    from repro.sharding import rules_for_cell

    d_model = cfg.d_model
    if cfg.family == "moe":
        d_out, d_in = cfg.d_ff, d_model
    elif cfg.family in ("ssm", "hybrid"):
        d_out, d_in = 2 * cfg.d_inner, d_model
    else:
        d_out, d_in = d_model, cfg.d_ff or d_model   # down-proj: largest fan-in
    rules = rules_for_cell(mesh, "dense", "compress")
    iters, k, eta = 20, d_in // 2, 1e-3
    # v2 schedule (§Perf compress hillclimb, iteration 2): rows over 'data'
    # only — 16× more rows per device lifts arithmetic intensity from
    # ~8 FLOP/B to ~128 (256 w/ bf16 C); the freed 'model' axis runs other
    # layers in parallel (whole-model compression is layer-parallel).
    sched = os.environ.get("DRYRUN_COMPRESS_SCHED", "v2")
    # iteration 2b (§Perf): bf16 C halves HBM reads on TPU, but the CPU cost
    # backend inserts a bf16→f32 convert copy per iteration (no native bf16
    # dot), tripling counted bytes — keep the counted model f32 and note the
    # TPU-side bf16 win separately.
    c_dtype = jnp.float32
    w_sds = jax.ShapeDtypeStruct((d_out, d_in), jnp.float32)
    c_sds = jax.ShapeDtypeStruct((d_in, d_in), c_dtype)
    t0 = time.time()

    def unrolled_run(w, c):
        theta = proj.topk_row(w, k)
        for _ in range(iters):
            z = theta + eta * (w - theta).astype(c.dtype) @ c
            theta = proj.topk_row(z.astype(jnp.float32), k)
        return theta

    if d_in <= 46_000:
        if sched == "v2":
            from repro.sharding import ShardingRules
            v2_rules = ShardingRules(mesh=mesh, batch_axes=rules.batch_axes,
                                     tp_axis=rules.tp_axis,
                                     fsdp_axes=rules.fsdp_axes,
                                     rows_axes=("data",))
            (in_w, in_c), out_sh = dist.rowsharded_shardings(v2_rules, d_out)
        else:
            (in_w, in_c), out_sh = dist.rowsharded_shardings(rules, d_out)
        with compat.set_mesh(mesh):
            lowered = jax.jit(unrolled_run, in_shardings=(in_w, in_c),
                              out_shardings=out_sh).lower(w_sds, c_sds)
        schedule = f"row-sharded (zero-collective, {sched})"
    else:
        run = dist.awp_prune_colsharded_fn(k, eta, iters, rules)
        with compat.set_mesh(mesh):
            lowered = jax.jit(run).lower(w_sds, c_sds)
        schedule = "column-sharded C (psum per iteration)"
    compiled = lowered.compile()
    costs = _costs_of(compiled)
    if schedule.startswith("column"):
        # the col-sharded loop is a scan: scale body costs by iters
        for kk in ("flops", "bytes", "collective_total"):
            costs[kk] *= iters
        costs["collectives"] = {kk: v * iters
                                for kk, v in costs["collectives"].items()}
    if "v2" in schedule:
        # v2 row-shards over 'data' only: one layer occupies a 16-chip slice
        # and the model axis runs 16 layers concurrently — normalize the
        # roofline to the slice (per-device costs already reflect it).
        chips = mesh.shape["data"]
    model_flops = iters * 2.0 * d_out * d_in * d_in
    roof = rl.Roofline(flops_per_device=costs["flops"],
                       bytes_per_device=costs["bytes"],
                       collective_per_device=costs["collective_total"],
                       chips=chips, model_flops=model_flops)
    return {
        "arch": cfg.name, "shape": "compress", "mesh": "single",
        "chips": chips, "kind": "compress", "schedule": schedule,
        "layer": {"d_out": d_out, "d_in": d_in, "iters": iters},
        "memory": _mem_dict(compiled.memory_analysis()),
        "cost_fit": costs,
        "roofline": roof.to_dict(),
        "compile_seconds": time.time() - t0,
    }


# ---------------------------------------------------------------------------
# orchestrator
# ---------------------------------------------------------------------------

def all_cells(include_compress: bool):
    from repro.configs import get_config, list_archs, shapes_for
    cells = []
    for arch in list_archs(include_paper=False):
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            cells.append((arch, shape.name))
        if include_compress:
            cells.append((arch, "compress"))
    return cells


def orchestrate(meshes, include_compress: bool, timeout: int):
    done, failed = [], []
    cells = all_cells(include_compress)
    for mesh_name in meshes:
        for arch, shape in cells:
            if shape == "compress" and mesh_name == "multi":
                continue                       # compress rooflined single-pod
            path = _out_path(mesh_name, arch, shape)
            if os.path.exists(path):
                done.append((mesh_name, arch, shape, "cached"))
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_name]
            print(f"[dryrun] {mesh_name} {arch} {shape} ...", flush=True)
            try:
                r = subprocess.run(cmd, timeout=timeout, capture_output=True,
                                   text=True)
                if r.returncode == 0:
                    done.append((mesh_name, arch, shape, "ok"))
                else:
                    failed.append((mesh_name, arch, shape,
                                   r.stderr.strip().splitlines()[-1]
                                   if r.stderr.strip() else "nonzero exit"))
                    print(r.stderr[-2000:], flush=True)
            except subprocess.TimeoutExpired:
                failed.append((mesh_name, arch, shape, f"timeout {timeout}s"))
    print(f"\n=== dry-run summary: {len(done)} ok, {len(failed)} failed ===")
    for f in failed:
        print("FAILED:", f)
    return 0 if not failed else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="include AWP compress-step cells")
    ap.add_argument("--timeout", type=int, default=5400)
    args = ap.parse_args()

    if args.all:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        sys.exit(orchestrate(meshes, args.compress, args.timeout))

    assert args.arch and args.shape and args.mesh != "both"
    try:
        result = run_cell(args.arch, args.shape, args.mesh)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    path = _out_path(args.mesh, args.arch, args.shape)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[dryrun] wrote {path}")
    r = result["roofline"]
    print(f"[dryrun] {args.arch} {args.shape} {args.mesh}: "
          f"compute={r['t_compute_s']:.4f}s memory={r['t_memory_s']:.4f}s "
          f"collective={r['t_collective_s']:.4f}s -> {r['bottleneck']}")


if __name__ == "__main__":
    main()
