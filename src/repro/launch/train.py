"""Training launcher: deterministic, fault-tolerant, resumable.

CPU-scale demo:   PYTHONPATH=src python -m repro.launch.train --arch llama2-7b \
                      --tiny --steps 100
Production shape: same CLI with --mesh single|multi on a real slice (the
                  dry-run proves the lowering; see launch/dryrun.py).

Fault tolerance (DESIGN.md §4): atomic rotating checkpoints every
--ckpt-every steps (async), deterministic batch(step) data so a restart
reproduces the exact stream, and restore works across mesh shapes (elastic).
A watchdog marks liveness for external supervisors (e.g. k8s) via a
heartbeat file touched every step.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_tiny_config
from repro.data import DataConfig, ZipfMarkov
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.sharding import ShardingRules, rules_for_cell, tree_shardings, opt_logical_axes
from repro.training.train_loop import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--ckpt-dir", default="results/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--heartbeat", default="")
    args = ap.parse_args()

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    rules = rules_for_cell(mesh, cfg.family, "train")
    model = build_model(cfg, rules, remat=not args.tiny)

    tcfg = TrainConfig(optimizer=OptimizerConfig(
        name=args.optimizer, lr=args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps), microbatches=args.microbatches)
    step_fn, opt_init = make_train_step(model, tcfg)

    params = model.init(jax.random.PRNGKey(0))
    if mesh is not None:
        p_sh = tree_shardings(rules, model.param_logical_axes(),
                              jax.eval_shape(model.init, jax.random.PRNGKey(0)))
        params = jax.device_put(params, p_sh)
    state = {"params": params, "opt": opt_init(params),
             "step": jnp.zeros((), jnp.int32)}

    mgr = CheckpointManager(args.ckpt_dir, keep_n=3)
    start = 0
    restored, step = mgr.restore_latest(state)
    if restored is not None:
        state, start = restored, step
        print(f"[train] resumed from step {start}")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    gen = ZipfMarkov(dc)
    jstep = jax.jit(step_fn, donate_argnums=0)

    t0 = time.time()
    for i in range(start, args.steps):
        toks, labels = gen.batch(i)
        state, metrics = jstep(state, {"tokens": jnp.asarray(toks),
                                       "labels": jnp.asarray(labels)})
        if args.heartbeat:
            with open(args.heartbeat, "w") as f:
                f.write(str(i))
        if (i + 1) % args.ckpt_every == 0 or i + 1 == args.steps:
            mgr.save_async(i + 1, state)
        if i % 10 == 0 or i + 1 == args.steps:
            tok_s = args.batch * args.seq * (i - start + 1) / (time.time() - t0)
            print(f"step {i}: loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} tok/s={tok_s:.0f}")
    mgr.wait()
    print(f"[train] done: final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
