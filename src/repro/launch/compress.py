"""Compression launcher — the paper's pipeline as a CLI.

  PYTHONPATH=src python -m repro.launch.compress --arch llama2-7b --tiny \
      --method awp_prune --ratio 0.6 --ckpt results/train_ckpt

Loads a trained checkpoint (or trains briefly if absent), runs the
sequential layer-wise compression with the chosen method, reports per-layer
reconstruction losses + perplexity before/after, and saves the compressed
checkpoint.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, save_checkpoint
from repro.configs import get_config, get_tiny_config
from repro.core.compress import METHODS, CompressionConfig, compress_model
from repro.core import metrics
from repro.data import DataConfig, ZipfMarkov, calibration_batches
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--method", default="awp_prune", choices=list(METHODS))
    ap.add_argument("--ratio", type=float, default=0.5)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=128)
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="results/train_ckpt")
    ap.add_argument("--out", default="results/compressed_ckpt")
    args = ap.parse_args()

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(args.ckpt)
    restored, step = mgr.restore_latest({"params": params})
    if restored is not None:
        params = restored["params"]
        print(f"[compress] loaded checkpoint step {step}")
    else:
        print("[compress] no checkpoint found — compressing random init "
              "(train first with repro.launch.train for meaningful numbers)")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=8)
    calib = [{"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
             for t, l in calibration_batches(dc, args.calib_batches)]
    gen = ZipfMarkov(dc)
    eval_batches = [gen.batch(9000 + i) for i in range(4)]

    def ppl(p):
        def loss_fn(p, t, l):
            _, m = jax.jit(model.loss)(p, {"tokens": t, "labels": l})
            return m["sum_nll"], m["tokens"]
        return metrics.perplexity(loss_fn, p, [
            (jnp.asarray(t), jnp.asarray(l)) for t, l in eval_batches])

    before = ppl(params)
    ccfg = CompressionConfig(method=args.method, ratio=args.ratio,
                             bits=args.bits, group_size=args.group_size)
    cp, reports = compress_model(model, params, calib, ccfg, verbose=True)
    after = ppl(cp)
    sp = float(np.mean([r.sparsity for r in reports]))
    loss = float(np.mean([r.loss_after for r in reports]))
    print(f"[compress] method={args.method} ratio={args.ratio} bits={args.bits}")
    print(f"[compress] mean recon loss={loss:.4f} mean sparsity={sp:.2f}")
    print(f"[compress] perplexity {before:.3f} -> {after:.3f}")
    save_checkpoint(args.out, 0, {"params": cp})
    print(f"[compress] wrote {args.out}")


if __name__ == "__main__":
    main()
