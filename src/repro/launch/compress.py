"""Compression launcher — the paper's pipeline as a CLI.

  PYTHONPATH=src python -m repro.launch.compress --arch llama2-7b --tiny \
      --method awp_prune --ratio 0.6 --ckpt results/train_ckpt

Per-layer policies come from ``--policy`` (inline JSON or @file), e.g.

  --policy '{"rules": [["blocks.0.*", null],
                       ["*.attn.*", {"kind": "QuantSpec", "bits": 8}],
                       ["*.mlp.*",  {"kind": "QuantSpec", "bits": 4}]]}'

Loads a trained checkpoint (or compresses random init if absent), runs the
layer-wise compression through the method registry (shape-bucketed batched
engine by default; ``--engine sequential`` for the reference driver), reports
per-layer reconstruction losses + perplexity before/after, and saves the
compressed checkpoint — packed QTensor codes included with ``--save-packed``.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.checkpoint import (CheckpointManager, save_checkpoint,
                              save_packed_checkpoint)
from repro.configs import get_config, get_tiny_config
from repro.core import metrics, registry
from repro.core.compress import CompressionConfig, compress_model
from repro.core.specs import Policy
from repro.data import DataConfig, ZipfMarkov, calibration_batches
from repro.models import build_model
from repro.obs import MetricsRegistry


def build_policy(args) -> "Policy | CompressionConfig":
    if args.policy:
        text = args.policy
        if text.startswith("@"):
            with open(text[1:]) as f:
                text = f.read()
        return Policy.from_dict(json.loads(text))
    return CompressionConfig(method=args.method, ratio=args.ratio,
                             bits=args.bits, group_size=args.group_size,
                             skip=tuple(args.skip))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--method", default="awp_prune",
                    choices=list(registry.available()))
    ap.add_argument("--ratio", type=float, default=0.5)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=128)
    ap.add_argument("--skip", nargs="*", default=(),
                    help="layer-name patterns to leave dense")
    ap.add_argument("--policy", default="",
                    help="per-layer policy as JSON (or @file.json); "
                         "overrides --method/--ratio/--bits")
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="results/train_ckpt")
    ap.add_argument("--out", default="results/compressed_ckpt")
    ap.add_argument("--save-packed", action="store_true",
                    help="store quantized layers as packed QTensor codes")
    ap.add_argument("--engine", default="batched",
                    choices=("batched", "sequential"),
                    help="shape-bucketed batched engine (default) or the "
                         "layer-at-a-time reference driver")
    ap.add_argument("--metrics-json", default="",
                    help="write the compression telemetry snapshot as JSON "
                         "here (plus Prometheus text exposition at "
                         "PATH.prom)")
    ap.add_argument("--profile-dir", default="",
                    help="directory for jax.profiler traces")
    ap.add_argument("--profile-block", type=int, default=-1,
                    help="block index to wrap in a jax.profiler trace "
                         "window (needs --profile-dir; -1 -> off)")
    args = ap.parse_args()

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(args.ckpt)
    restored, step = mgr.restore_latest({"params": params})
    if restored is not None:
        params = restored["params"]
        print(f"[compress] loaded checkpoint step {step}")
    else:
        print("[compress] no checkpoint found — compressing random init "
              "(train first with repro.launch.train for meaningful numbers)")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=8)
    calib = [{"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
             for t, l in calibration_batches(dc, args.calib_batches)]
    gen = ZipfMarkov(dc)
    eval_batches = [gen.batch(9000 + i) for i in range(4)]

    def ppl(p):
        def loss_fn(p, t, l):
            _, m = jax.jit(model.loss)(p, {"tokens": t, "labels": l})
            return m["sum_nll"], m["tokens"]
        return metrics.perplexity(loss_fn, p, [
            (jnp.asarray(t), jnp.asarray(l)) for t, l in eval_batches])

    before = ppl(params)
    policy = build_policy(args)
    reg = MetricsRegistry()
    cp, report = compress_model(model, params, calib, policy, verbose=True,
                                engine=args.engine, metrics=reg,
                                profile_dir=args.profile_dir,
                                profile_block=args.profile_block)
    after = ppl(cp)
    print("[compress] " + report.summary().replace("\n", "\n[compress] "))
    if args.metrics_json:
        reg.dump_json(args.metrics_json, meta={
            "source": "compress", "arch": args.arch, "engine": args.engine,
            "method": args.method})
        with open(args.metrics_json + ".prom", "w") as f:
            f.write(reg.to_prometheus())
        print(f"[compress] metrics snapshot -> {args.metrics_json} "
              f"(+ {args.metrics_json}.prom)")
    print(f"[compress] perplexity {before:.3f} -> {after:.3f}")
    if args.save_packed and report.packed_layers():
        path = save_packed_checkpoint(args.out, 0, cp, report)
        print(f"[compress] wrote packed checkpoint {path} "
              f"(serve with --packed)")
    else:
        if args.save_packed:
            print("[compress] WARNING: no quantized artifacts to pack "
                  "(pruning-only policy?) — writing a dense checkpoint; "
                  "serve it WITHOUT --packed")
        save_checkpoint(args.out, 0, {"params": cp})
        print(f"[compress] wrote {args.out}")


if __name__ == "__main__":
    main()
