"""Production mesh construction. A FUNCTION (not a module constant) so that
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def _make(shape, axes):
    """jax.make_mesh across versions: AxisType (and the axis_types kwarg)
    only exist on newer jax; older releases default to auto axes anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (TPU v5e pod slice); multi-pod adds a
    leading pod axis (2×16×16 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh helper for tests/examples (auto axis types)."""
    return _make(shape, axes)


__all__ = ["make_production_mesh", "make_mesh"]
