"""Roofline analysis from compiled dry-run artifacts (no hardware).

Three terms per (arch × shape × mesh), hardware = TPU v5e per chip:
    compute    = HLO_FLOPs / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes / (chips × 819e9 B/s HBM)
    collective = collective_bytes / (chips × 50e9 B/s per ICI link)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). collective_bytes
is parsed from the optimized HLO text: the summed operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (assignment constant)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,128]{1,0}  |  bf16[2,4,8]  |  f32[] (scalar)
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum *output* shape bytes of every collective op in the HLO, by kind.

    Notes: shapes in the optimized SPMD module are PER-PARTITION; the sum is
    therefore per-device traffic (right for the per-chip roofline term).
    Tuple-shaped collectives contribute every element."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "  <shape> <name> = <shape> op-name(" — instruction lines only
        m = re.match(r"^(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.+?)\s*("
                     + "|".join(_COLLECTIVES) + r")[\w\-\.]*\(", s)
        if not m:
            continue
        shape_part, kind = m.group(1), m.group(2)
        nbytes = sum(_shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(shape_part))
        out[kind] += nbytes
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_per_device: float
    chips: int
    model_flops: float = 0.0      # 6·N·D or 2·N·D (useful-work model)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat/redundancy waste detector)."""
        tot = self.flops_per_device * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_frac(self) -> float:
        """Useful-model-FLOPs throughput achievable at the bound, as a
        fraction of pure-compute peak: (model_flops/chips/peak) / t_bound."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        return ideal / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_per_device": self.collective_per_device,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def from_compiled(compiled, chips: int, model_flops: float,
                  hlo_text: Optional[str] = None) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = sum(collective_bytes(text).values())
    return Roofline(flops_per_device=flops, bytes_per_device=nbytes,
                    collective_per_device=float(coll), chips=chips,
                    model_flops=model_flops)


__all__ = ["Roofline", "from_compiled", "collective_bytes",
           "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
