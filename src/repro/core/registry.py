"""Method registry: pluggable compression methods with a uniform signature.

A *method* is any callable

    compress(w_paper, stats, spec) -> CompressResult

where ``w_paper`` is the weight in paper orientation (d_out, d_in),
``stats`` the layer's :class:`repro.core.calibration.CalibStats`, and
``spec`` a :class:`repro.core.specs.CompressSpec`. Register one with

    from repro.core import registry
    from repro.core.specs import QuantSpec

    @registry.register("my_method", spec_cls=QuantSpec)
    def my_method(w, stats, spec):
        return registry.CompressResult(theta=...)

and ``compress_model`` dispatches to it through any policy naming it —
no driver edits. ``spec_cls`` records which spec type the method expects
(used to build specs from legacy flat configs and CLI flags).

:class:`CompressResult` keeps the structured artifacts the old string
dispatch threw away: the sparsity mask, packed ``QTensor`` codes for
quantizing methods, and per-layer loss/iteration counts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Tuple

from repro.core.specs import CompressSpec, JointSpec


@dataclasses.dataclass
class CompressResult:
    """What one method produced for one layer.

    ``theta`` is always the dense compressed weight (paper orientation) —
    the value written back into the param tree. The artifacts ride along:

    * ``mask``    — boolean keep-mask (pruning / joint methods);
    * ``qtensor`` — packed :class:`repro.quant.QTensor` whose ``dequant()``
      equals ``theta`` (quantizing methods) — what the packed checkpoint
      stores and decode-shape serving reads;
    * ``loss``    — normalized activation-aware loss (filled by the driver
      if the method leaves it None);
    * ``iters``   — PGD iterations actually run;
    * ``aux``     — anything method-specific (grad norms, traces, α…).
    """
    theta: Any
    mask: Optional[Any] = None
    qtensor: Optional[Any] = None
    loss: Optional[float] = None
    iters: Optional[int] = None
    aux: Dict[str, Any] = dataclasses.field(default_factory=dict)


class Method(Protocol):
    def __call__(self, w_paper: Any, stats: Any,
                 spec: CompressSpec) -> CompressResult: ...


@dataclasses.dataclass(frozen=True)
class _Entry:
    fn: Method
    spec_cls: type


_REGISTRY: Dict[str, _Entry] = {}
_BATCHED: Dict[str, Callable] = {}
_BUILTINS_LOADED = False


def register(name: str, *, spec_cls: type = JointSpec) -> Callable[[Method], Method]:
    """Decorator: register ``fn`` as compression method ``name``."""
    def deco(fn: Method) -> Method:
        _REGISTRY[name] = _Entry(fn=fn, spec_cls=spec_cls)
        return fn
    return deco


def register_batched(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register a *batched* implementation for method ``name``.

    A batched method compresses a whole shape bucket — B same-shape linears
    sharing one spec — in one device program:

        batched(w_b, c_b, stats_b, spec) -> List[CompressResult]   # len B

    ``w_b`` is ``(B, d_out, d_in)``, ``c_b`` the ``(B, d_in, d_in)`` damped
    covariances (computed ONCE by the engine and reused for the loss), and
    ``stats_b`` the stacked :class:`CalibStats`. Methods without a batched
    implementation still work everywhere — the engine falls back to the
    per-layer callable inside the bucket loop.
    """
    def deco(fn: Callable) -> Callable:
        _BATCHED[name] = fn
        return fn
    return deco


def _load_builtins() -> None:
    """Import the modules that register the built-in methods (idempotent)."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    import repro.core.awp        # noqa: F401  (registers awp_*)
    import repro.core.baselines  # noqa: F401  (registers the baselines)
    import repro.core.batched    # noqa: F401  (registers batched variants)
    _BUILTINS_LOADED = True      # only after all imports succeeded


def _lookup(name: str) -> _Entry:
    if name not in _REGISTRY:
        _load_builtins()
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown compression method {name!r}; registered methods: "
            f"{', '.join(available())}")
    return _REGISTRY[name]


def get_method(name: str) -> Method:
    return _lookup(name).fn


def get_batched(name: str) -> Optional[Callable]:
    """Batched implementation of ``name``, or None (engine falls back)."""
    _lookup(name)                      # load builtins / unknown-name error
    return _BATCHED.get(name)


def spec_cls_for(name: str) -> type:
    return _lookup(name).spec_cls


def validate_spec(spec: CompressSpec) -> None:
    """Fail fast on method/spec mismatches (duck-typed: any spec carrying
    the registered spec class's fields is accepted, e.g. a JointSpec can
    drive a PruneSpec method)."""
    cls = _lookup(spec.method).spec_cls
    missing = [f.name for f in dataclasses.fields(cls)
               if not hasattr(spec, f.name)]
    if missing:
        raise TypeError(
            f"method {spec.method!r} expects a {cls.__name__} "
            f"(got {type(spec).__name__}, missing fields: "
            f"{', '.join(missing)})")


def available() -> Tuple[str, ...]:
    _load_builtins()
    return tuple(sorted(_REGISTRY))


__all__ = ["CompressResult", "Method", "register", "register_batched",
           "get_method", "get_batched", "spec_cls_for", "validate_spec",
           "available"]
