"""Distributed AWP — the paper's technique as an SPMD-first feature.

Two schedules (DESIGN.md §2/§4):

* ``awp_prune_rowsharded`` — rows of Θ are independent sub-problems (Eq. 4),
  so d_out is sharded across the ENTIRE mesh and C is replicated: the PGD
  loop runs with **zero collectives**. The default whenever C fits per-device
  (d_in ≤ ~50k f32).

* ``awp_prune_colsharded`` — for huge-fan-in layers where replicated C would
  blow HBM (nemotron's d_ff=73728 → C is 21.7 GB f32): C is row-sharded over
  'model', each shard computes its partial (W−Θ)·C_shard, one psum per
  iteration rebuilds the full-width Z, and the row-top-k projection is local.
  Collective volume = |Z| per iteration — reported in §Roofline.

Both are pure jit-able functions; the launcher lowers them for the dry-run.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import projections as proj
from repro.sharding import ShardingRules


def awp_prune_rowsharded_fn(k: int, eta, iters: int):
    """The zero-collective PGD loop body (rows independent, C replicated)."""
    def run(w, c):
        theta = proj.topk_row(w, k)            # Wanda-init done upstream
        def body(theta, _):
            z = theta + eta * (w - theta) @ c
            return proj.topk_row(z, k), None
        theta, _ = jax.lax.scan(body, theta, None, length=iters)
        return theta
    return run


def rowsharded_shardings(rules: ShardingRules, d_out: Optional[int] = None):
    """(w, c) in-shardings + theta out-sharding for the row-parallel loop.

    Graded fallback when d_out doesn't divide the whole mesh (e.g.
    internvl2's 896 rows vs 256 chips): drop trailing mesh axes until the
    row count divides."""
    mesh = rules.mesh
    axes = tuple(rules.rows_axes)
    if d_out is not None:
        while axes and d_out % rules.axis_size(axes) != 0:
            axes = axes[:-1]
    row_sh = NamedSharding(mesh, P(axes if axes else None, None))
    rep = NamedSharding(mesh, P(None, None))
    return (row_sh, rep), row_sh


def awp_prune_rowsharded(w: jax.Array, c: jax.Array, k: int, eta, iters: int,
                         rules: ShardingRules):
    """Execute the row-sharded loop (dry-run lowers the fn directly)."""
    run = awp_prune_rowsharded_fn(k, eta, iters)
    if rules.mesh is None:
        return run(w, c)
    (in_w, in_c), out_sh = rowsharded_shardings(rules, w.shape[0])
    return jax.jit(run, in_shardings=(in_w, in_c), out_shardings=out_sh)(w, c)


def awp_prune_colsharded_fn(k: int, eta, iters: int, rules: ShardingRules):
    """Builds the shard_map'd column-sharded PGD loop (for lowering or
    execution). Layout: w/theta (rows over batch axes, d_in over model);
    c (d_in over model on axis 0, full axis 1)."""
    mesh = rules.mesh
    assert mesh is not None and rules.tp_axis is not None
    tp = rules.tp_axis
    n_tp = mesh.shape[tp]
    dp = rules.batch_axes

    def local(w_loc, c_loc):
        # w_loc: (r_loc, k_loc); c_loc: (k_loc, d_in)
        d_in = c_loc.shape[1]
        k_loc = c_loc.shape[0]
        my = jax.lax.axis_index(tp)

        def col_slice(z_full):
            return jax.lax.dynamic_slice_in_dim(z_full, my * k_loc, k_loc, 1)

        theta = None

        def body(theta_loc, _):
            resid = w_loc - theta_loc                       # (r_loc, k_loc)
            partial = resid.astype(jnp.float32) @ c_loc.astype(jnp.float32)
            z_resid = jax.lax.psum(partial, tp)             # (r_loc, d_in) full
            # need full-width theta for projection: gather local cols
            theta_full = jax.lax.all_gather(theta_loc, tp, axis=1, tiled=True)
            z = theta_full + eta * z_resid
            proj_full = proj.topk_row(z, k)
            return col_slice(proj_full), None

        theta0_full = proj.topk_row(
            jax.lax.all_gather(w_loc, tp, axis=1, tiled=True), k)
        theta_loc, _ = jax.lax.scan(body, col_slice(theta0_full), None,
                                    length=iters)
        return theta_loc

    def fn(w, c):
        return compat.shard_map(
            local, mesh=mesh,
            in_specs=(P(dp, tp), P(tp, None)),
            out_specs=P(dp, tp),
            check_vma=False)(w, c)

    return fn


def calib_c_distributed(acts: jax.Array, rules: ShardingRules) -> jax.Array:
    """Form C = (1/n)XᵀX with tokens sharded over the batch axes: local
    outer-product + one psum — the only collective of calibration."""
    mesh = rules.mesh
    if mesh is None:
        a = acts.reshape(-1, acts.shape[-1]).astype(jnp.float32)
        return a.T @ a / a.shape[0]
    dp = rules.batch_axes

    def local(a_loc):
        a = a_loc.reshape(-1, a_loc.shape[-1]).astype(jnp.float32)
        c_sum = jax.lax.psum(a.T @ a, dp)
        n = jax.lax.psum(jnp.float32(a.shape[0]), dp)
        return c_sum / n

    return compat.shard_map(local, mesh=mesh,
                         in_specs=P(dp, None, None) if acts.ndim == 3 else P(dp, None),
                         out_specs=P(None, None), check_vma=False)(acts)


__all__ = ["awp_prune_rowsharded", "awp_prune_rowsharded_fn",
           "rowsharded_shardings", "awp_prune_colsharded_fn",
           "calib_c_distributed"]
