"""Shape-bucketed batched compression engine.

The paper's cost is dominated by the O(d_out·d_in²) PGD inner loop run once
per linear. The sequential driver dispatches one device program per layer
(28 programs per block for a 8-expert MoE block) with host syncs in between.
This module instead *buckets* a block's linears by ``(weight shape, spec)``
— q/k/v heads, gate/up pairs, and all E MoE experts land in the same bucket
— stacks their weights into ``(B, d_out, d_in)`` and their
:class:`~repro.core.calibration.CalibStats` into batched sufficient
statistics, and compresses the whole bucket as ONE device program via
:func:`repro.core.awp.pgd_batched` (a single while_loop over the max-iter
envelope with per-item convergence masking; the fused Pallas gradient-step
kernel on TPU).

Because bucket keys depend only on shapes and specs, the jitted programs are
compiled once for block 0 and stay warm for every later block — the per-block
marginal cost is pure compute.

Methods opt in through :func:`repro.core.registry.register_batched`; anything
without a batched implementation (numpy-path GPTQ/SparseGPT, user plugins)
silently falls back to its per-layer callable inside the bucket loop, so the
engine is a strict superset of the sequential driver.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import awp, calibration as calib, projections as proj, registry
from repro.core.baselines import wanda as _wanda
from repro.core.specs import CompressSpec
from repro.quant import QTensor


# ---------------------------------------------------------------------------
# work units and bucketing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerWork:
    """One linear queued for compression (weight in paper orientation)."""
    name: str                    # short per-block name ("wq", "moe_wu_3")
    qname: str                   # qualified name ("blocks.2.moe.wu.3")
    path: tuple                  # param-tree path
    layer: Optional[int]         # stacked-block index (None for shared)
    spec: CompressSpec
    stats: calib.CalibStats
    w: jax.Array                 # (d_out, d_in)


def bucket_key(work: LayerWork) -> Tuple[tuple, CompressSpec]:
    """Two linears batch together iff their weights are shape-identical and
    their policy resolved to the very same (frozen, hashable) spec."""
    return (tuple(work.w.shape), work.spec)


def bucket_works(works: Sequence[LayerWork]) -> Dict[tuple, List[int]]:
    """Group work indices by bucket key, preserving first-seen order."""
    buckets: Dict[tuple, List[int]] = {}
    for j, wk in enumerate(works):
        buckets.setdefault(bucket_key(wk), []).append(j)
    return buckets


def compress_block(works: Sequence[LayerWork], metrics=None):
    """Compress every queued linear; returns per-work (CompressResult, loss).

    Results line up with ``works`` order. Losses are DEVICE scalars — the
    driver materializes them (with the rest of the block's metrics) in one
    transfer at the block boundary.

    ``metrics`` (a :class:`repro.obs.MetricsRegistry`) receives per-bucket
    telemetry: bucket count, size, and dispatch wall time. The wall time
    covers program construction + dispatch only — the deferred host sync
    lands in the driver's block boundary, so per-bucket seconds understate
    true device time (documented in docs/observability.md).
    """
    out: List[Optional[tuple]] = [None] * len(works)
    for (shape, spec), idxs in bucket_works(works).items():
        bucket = [works[j] for j in idxs]
        t0 = time.perf_counter()
        results, losses = _compress_bucket(bucket)
        if metrics is not None:
            lab = {"method": spec.method,
                   "shape": "x".join(str(d) for d in shape)}
            names = ("method", "shape")
            metrics.counter("compress_buckets_total",
                            "shape/spec buckets dispatched",
                            labelnames=names).labels(**lab).inc()
            metrics.counter("compress_bucket_layers_total",
                            "layers routed through each bucket",
                            labelnames=names).labels(**lab).inc(len(bucket))
            metrics.histogram(
                "compress_bucket_seconds",
                "per-bucket dispatch wall (host syncs deferred to the "
                "block boundary)", labelnames=names,
                unit="seconds").labels(**lab).observe(
                    time.perf_counter() - t0)
        for pos, j in enumerate(idxs):
            out[j] = (results[pos], losses[pos])
    return out


def _compress_bucket(bucket: List[LayerWork]):
    spec = bucket[0].spec
    fn = registry.get_batched(spec.method)
    if fn is None or len(bucket) == 1:
        # per-layer fallback: identical numerics to the sequential driver,
        # covariance still computed once per layer (threaded through aux)
        results, losses = [], []
        for wk in bucket:
            res = registry.get_method(spec.method)(wk.w, wk.stats, spec)
            c = res.aux.pop("covariance", None)
            if c is None:
                c = calib.covariance(wk.stats, damp=spec.damp)
            losses.append(awp.activation_loss(wk.w, res.theta, c))
            results.append(res)
        return results, losses

    w_b = jnp.stack([wk.w for wk in bucket])
    stats_b = calib.stack_stats([wk.stats for wk in bucket])
    c_b = calib.covariance(stats_b, damp=spec.damp)     # once per bucket
    results = fn(w_b, c_b, stats_b, spec)
    theta_b = jnp.stack([r.theta for r in results])
    losses = awp.activation_loss_batched(w_b, theta_b, c_b)
    for r in results:
        r.aux.pop("covariance", None)
    return results, list(losses)


# ---------------------------------------------------------------------------
# batched recipe cores (jitted once per (B, shape, hyperparam) key)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "max_iters", "nm",
                                             "use_pallas"))
def prune_batched(w_b, c_b, k: int, *, max_iters: int = 200,
                  nm: Optional[tuple] = None,
                  use_pallas: bool = True) -> awp.AWPResult:
    """§4.1 pruning recipe over a (B, d_out, d_in) stack (Wanda init)."""
    theta0 = jax.vmap(lambda w, c: _wanda.prune_weight(w, c, k))(w_b, c_b)
    if nm is None:
        project = lambda z, t: proj.topk_row(z, k)      # row-local: batch-safe
    else:
        project = lambda z, t: jax.vmap(
            lambda zz: proj.prune_n_m(zz, *nm))(z)
    cfg = awp.PGDConfig(max_iters=max_iters, tol=1e-4, eta_scale=2.0,
                        use_pallas=use_pallas)
    return awp.pgd_batched(w_b, c_b, project, theta0, cfg)


PRUNE_CHUNK_ITERS = 25     # host sync cadence of the compacting prune driver


@functools.partial(jax.jit, static_argnames=("k", "iters", "nm", "init",
                                             "use_pallas"))
def _prune_chunk(w_b, c_b, theta_b, k: int, *, iters: int,
                 nm: Optional[tuple] = None, init: bool = False,
                 use_pallas: bool = True) -> awp.AWPResult:
    """One ``iters``-step slice of the §4.1 prune loop over a stack.

    ``init=True`` computes the Wanda warm start (``theta_b`` is then an
    ignored placeholder — pass the weights). The pruning projection is
    step-index-free, so restarting the loop counter each chunk leaves the
    per-item trajectory identical to one uninterrupted run."""
    if init:
        theta_b = jax.vmap(lambda w, c: _wanda.prune_weight(w, c, k))(w_b, c_b)
    if nm is None:
        project = lambda z, t: proj.topk_row(z, k)      # row-local: batch-safe
    else:
        project = lambda z, t: jax.vmap(
            lambda zz: proj.prune_n_m(zz, *nm))(z)
    cfg = awp.PGDConfig(max_iters=iters, tol=1e-4, eta_scale=2.0,
                        use_pallas=use_pallas)
    return awp.pgd_batched(w_b, c_b, project, theta_b, cfg)


def _pow2_pad(idx: "jnp.ndarray") -> "jnp.ndarray":
    """Pad an index vector to the next power of two by repeating its first
    entry — duplicate rows compute independently and are discarded, and the
    pow2 sizes bound the chunk program compile count to O(log B)."""
    n = len(idx)
    p = 1 if n <= 1 else 1 << (n - 1).bit_length()
    return np.concatenate([idx, np.repeat(idx[:1], p - n)])


def prune_batched_compacted(w_b, c_b, k: int, *, max_iters: int = 200,
                            chunk_iters: int = PRUNE_CHUNK_ITERS,
                            tol: float = 1e-4, nm: Optional[tuple] = None,
                            use_pallas: bool = True) -> awp.AWPResult:
    """§4.1 prune over a stack, re-compacted between iteration chunks.

    :func:`prune_batched`'s single while_loop pays the FULL stack's
    gradient step until the LAST item converges — with mixed conditioning
    (an 8-expert bucket where one expert is stiff) most of the batched
    FLOPs are masked no-ops, which is how the batched engine measured
    SLOWER than the sequential driver on prune workloads. This driver runs
    the same loop in ``chunk_iters`` slices, syncs only the (B,) gradient
    norms between slices, retires converged items, and re-stacks the
    survivors into a smaller (pow2-padded) problem, so late iterations pay
    only for the items still moving.

    Per-item results are exactly :func:`prune_batched`'s: the chunk
    boundary freezes nothing mid-flight (within a chunk the while_loop's
    convergence masking applies as before, and the projection is
    step-index-free), so each item sees the identical step sequence and
    stop rule."""
    b = w_b.shape[0]
    theta_parts: Dict[int, jax.Array] = {}
    gnorm_parts: Dict[int, float] = {}
    iter_counts = np.zeros(b, np.int32)
    active = np.arange(b)
    pad = _pow2_pad(active)
    w_act = jnp.take(jnp.asarray(w_b), pad, axis=0)
    c_act = jnp.take(jnp.asarray(c_b), pad, axis=0)
    theta_act = w_act                                  # ignored by init chunk
    init, done = True, 0
    while len(active) and done < max_iters:
        it = min(chunk_iters, max_iters - done)
        res = _prune_chunk(w_act, c_act, theta_act, k, iters=it, nm=nm,
                           init=init, use_pallas=use_pallas)
        init = False
        done += it
        n = len(active)
        gn = np.asarray(res.grad_norm[:n])             # the only host sync
        iter_counts[active] += np.asarray(res.iters[:n])
        conv = (gn < tol) | (done >= max_iters)
        if not conv.any():                             # nothing retired:
            theta_act = res.theta                      # keep the stacks
            continue
        for j in np.nonzero(conv)[0]:
            theta_parts[int(active[j])] = res.theta[j]
            gnorm_parts[int(active[j])] = float(gn[j])
        keep = np.nonzero(~conv)[0]
        active = active[keep]
        if len(active):
            padk = _pow2_pad(keep)
            w_act = jnp.take(w_act, padk, axis=0)
            c_act = jnp.take(c_act, padk, axis=0)
            theta_act = jnp.take(res.theta, padk, axis=0)
    theta = jnp.stack([theta_parts[i] for i in range(b)])
    return awp.AWPResult(theta=theta, iters=jnp.asarray(iter_counts),
                         grad_norm=jnp.asarray(
                             np.asarray([gnorm_parts[i] for i in range(b)],
                                        np.float32)),
                         loss_trace=None)


@functools.partial(jax.jit, static_argnames=("bits", "group_size",
                                             "max_iters", "use_pallas"))
def quantize_batched(w_b, c_b, bits: int, *, group_size: int = 128,
                     max_iters: int = 10,
                     use_pallas: bool = True) -> awp.AWPResult:
    """§4.2 quantization recipe over a stack (RTN init, per-item guard)."""
    qproj = jax.vmap(lambda z: proj.quant_project(z, bits, group_size))
    theta0 = qproj(w_b.astype(jnp.float32))
    cfg = awp.PGDConfig(max_iters=max_iters, tol=0.0, eta_scale=1.5,
                        use_pallas=use_pallas)
    res = awp.pgd_batched(w_b, c_b, lambda z, t: qproj(z), theta0, cfg)
    # same beyond-paper guard as awp.quantize, per item: the min/max grid
    # drifts with the iterate, keep the better of {init, final}
    better = (awp.activation_loss_batched(w_b, res.theta, c_b)
              <= awp.activation_loss_batched(w_b, theta0, c_b))
    theta = jnp.where(better[:, None, None], res.theta,
                      theta0.astype(jnp.float32))
    return res._replace(theta=theta)


@functools.partial(jax.jit, static_argnames=(
    "k", "bits", "group_size", "ramp_iters", "prune_only_iters",
    "total_iters", "use_pallas"))
def joint_batched(w_b, c_b, k: int, bits: int = 4, *, group_size: int = 128,
                  ramp_iters: int = 25, prune_only_iters: int = 50,
                  total_iters: int = 100,
                  use_pallas: bool = True) -> awp.AWPResult:
    """§4.3 joint prune+quant recipe over a stack (same schedule per item)."""
    d_in = w_b.shape[-1]
    target_ratio = 1.0 - k / d_in
    qproj = jax.vmap(lambda z: proj.quant_project(z, bits, group_size))

    def project(z, t):
        ratio_t = proj.ramp_ratio(t, target_ratio, ramp_iters)
        pruned = proj.topk_row_dynamic(z, 1.0 - ratio_t)   # axis=-1: batch-safe
        quantized = qproj(pruned) * (pruned != 0)
        return jnp.where(t < prune_only_iters, pruned, quantized)

    theta0 = jnp.asarray(w_b, jnp.float32)
    # tol=0 runs the while_loop exactly total_iters — no loss trace needed,
    # so the per-iter loss einsum (same asymptotic cost as the step) is not
    # paid here
    cfg = awp.PGDConfig(max_iters=total_iters, tol=0.0, eta_scale=1.5,
                        use_pallas=use_pallas)
    res = awp.pgd_batched(w_b, c_b, project, theta0, cfg)
    mask = proj.topk_row_mask(res.theta, k)
    theta = qproj(res.theta * mask) * mask
    return res._replace(theta=theta)


# ---------------------------------------------------------------------------
# batched QTensor packing: one program for the whole bucket, per-item
# QTensors assembled from slices (instead of ~15 eager dispatches per layer
# inside QTensor.from_dense — the dominant per-layer cost for quant methods)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def _pack_batched(theta_b, bits: int, group_size: int):
    """Batched mirror of :meth:`QTensor.from_dense` + ``dequant``.

    Returns (packed, scale, zero, dequant) stacks whose per-item slices are
    bit-identical to the sequential single-layer path."""
    b, d_out, d_in = theta_b.shape
    qp = jax.vmap(lambda t: proj.quant_params(t, bits, group_size))(theta_b)
    codes = qp.q.reshape(b, d_out, d_in)
    if bits == 4 and d_in % 2 == 0:
        from repro.quant.qtensor import pack_int4
        packed = pack_int4(codes)                  # handles leading dims
    elif bits <= 8:
        packed = codes.astype(jnp.uint8)
    else:
        packed = codes.astype(jnp.int32)
    deq = ((qp.q.astype(jnp.float32) - qp.zero) * qp.scale).reshape(
        b, d_out, d_in)
    return packed, qp.scale[..., 0], qp.zero[..., 0], deq


def _qtensors_from_stack(theta_b, bits: int, group_size: int):
    """[(QTensor, dequantized theta)] for each item of a theta stack."""
    packed, scale, zero, deq = _pack_batched(theta_b, bits, group_size)
    shape = tuple(theta_b.shape[1:])
    return [(QTensor(packed=packed[i], scale=scale[i], zero=zero[i],
                     bits=bits, group_size=group_size, shape=shape),
             deq[i])
            for i in range(theta_b.shape[0])]


# ---------------------------------------------------------------------------
# batched registry adapters
# ---------------------------------------------------------------------------

def _prune_results(res: awp.AWPResult):
    """Per-item CompressResults from a batched pruning AWPResult."""
    return [registry.CompressResult(theta=res.theta[i],
                                    mask=res.theta[i] != 0,
                                    iters=res.iters[i],
                                    aux={"grad_norm": res.grad_norm[i]})
            for i in range(res.theta.shape[0])]


@registry.register_batched("awp_prune")
def _awp_prune_b(w_b, c_b, stats_b, spec):
    return _prune_results(
        prune_batched_compacted(w_b, c_b, spec.k_for(w_b.shape[-1])))


@registry.register_batched("awp_prune_nm")
def _awp_prune_nm_b(w_b, c_b, stats_b, spec):
    return _prune_results(
        prune_batched_compacted(w_b, c_b, spec.k_for(w_b.shape[-1]),
                                nm=spec.nm or (2, 4)))


@registry.register_batched("awp_quant")
def _awp_quant_b(w_b, c_b, stats_b, spec):
    g = spec.group_for(w_b.shape[-1])
    res = quantize_batched(w_b, c_b, spec.bits, group_size=g)
    # bucket-wide packing (near-exact regrid; the codes become the truth)
    return [registry.CompressResult(theta=deq, qtensor=qt, iters=res.iters[i],
                                    aux={"grad_norm": res.grad_norm[i]})
            for i, (qt, deq) in enumerate(
                _qtensors_from_stack(res.theta, spec.bits, g))]


@registry.register_batched("awp_joint")
def _awp_joint_b(w_b, c_b, stats_b, spec):
    g = spec.group_for(w_b.shape[-1])
    res = joint_batched(w_b, c_b, spec.k_for(w_b.shape[-1]), spec.bits,
                        group_size=g)
    mask_b = res.theta != 0
    out = []
    for i, (qt, deq) in enumerate(
            _qtensors_from_stack(res.theta, spec.bits, g)):
        out.append(registry.CompressResult(
            theta=deq * mask_b[i], mask=mask_b[i], qtensor=qt,
            iters=res.iters[i]))
    return out


@registry.register_batched("wanda")
def _wanda_b(w_b, c_b, stats_b, spec):
    if spec.nm is not None:
        theta_b = jax.vmap(
            lambda w, c: _wanda.prune_weight_n_m(w, c, *spec.nm))(w_b, c_b)
    else:
        k = spec.k_for(w_b.shape[-1])
        theta_b = jax.vmap(lambda w, c: _wanda.prune_weight(w, c, k))(w_b, c_b)
    return [registry.CompressResult(theta=theta_b[i], mask=theta_b[i] != 0)
            for i in range(w_b.shape[0])]


@registry.register_batched("magnitude")
def _magnitude_b(w_b, c_b, stats_b, spec):
    theta_b = proj.topk_row(w_b, spec.k_for(w_b.shape[-1]))  # row-local
    return [registry.CompressResult(theta=theta_b[i], mask=theta_b[i] != 0)
            for i in range(w_b.shape[0])]


__all__ = ["LayerWork", "bucket_key", "bucket_works", "compress_block",
           "prune_batched", "prune_batched_compacted", "quantize_batched",
           "joint_batched"]
