"""Baselines the paper compares against (Tables 1-5), all fully implemented:

- magnitude  — activation-blind row-wise magnitude pruning (Eq. 1)
- wanda      — |W|·‖X‖₂ scoring (Sun et al. 2023); also AWP's pruning init
- sparsegpt  — OBS-based one-shot pruning (Frantar & Alistarh 2023)
- rtn        — round-to-nearest group quantization; AWP's quant init
- awq        — activation-aware scale search (Lin et al. 2024)
- gptq       — OBS-based quantization with error propagation (Frantar 2022)
- sequential — Wanda+AWQ and AWQ+Wanda pipelines (Table 4/5 baselines)
"""
from repro.core.baselines import magnitude, wanda, sparsegpt, rtn, awq, gptq, sequential  # noqa: F401
