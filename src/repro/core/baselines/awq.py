"""AWQ (Lin et al., 2024) — activation-aware weight quantization via
per-input-channel scale search.

For a grid of α ∈ [0, 1]: s_j = (mean|x_j|)^α (normalized), quantize W·diag(s)
with RTN group quantization, fold the scale back (Ŵ = Q(W·s)/s), and keep the
α minimizing the activation-aware loss tr(E C Eᵀ) — evaluated exactly from the
calibration covariance, no extra forward passes needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import calibration as calib, projections as proj, registry
from repro.core.specs import QuantSpec
from repro.quant import QTensor

_ALPHA_GRID = tuple(i / 20 for i in range(21))   # 0.00, 0.05, ..., 1.00


def _loss(e: jax.Array, c: jax.Array) -> jax.Array:
    return jnp.einsum("ij,jk,ik->", e, c, e)


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def quantize_weight_with_scale(w: jax.Array, c: jax.Array,
                               act_mean_abs: jax.Array, bits: int,
                               group_size: int = 128):
    """(dequantized AWQ weight, winning per-channel scale s).

    act_mean_abs: per-input-channel mean |x| from calibration
    (:func:`repro.core.calibration.act_mean_abs`).
    """
    w = w.astype(jnp.float32)
    c = c.astype(jnp.float32)
    a = jnp.maximum(act_mean_abs.astype(jnp.float32), 1e-8)

    def candidate(alpha: float):
        s = a ** alpha
        s = s / jnp.sqrt(jnp.maximum(s.max() * s.min(), 1e-12))  # official norm
        s = jnp.clip(s, 1e-4, 1e4)
        wq = proj.quant_project(w * s[None, :], bits, group_size) / s[None, :]
        return wq, s

    cands, scales = map(jnp.stack, zip(*(candidate(al) for al in _ALPHA_GRID)))
    losses = jax.vmap(lambda wq: _loss(w - wq, c))(cands)
    best = jnp.argmin(losses)
    return cands[best], scales[best]


def quantize_weight(w: jax.Array, c: jax.Array, act_mean_abs: jax.Array,
                    bits: int, group_size: int = 128) -> jax.Array:
    """Return the dequantized AWQ weight (paper orientation d_out × d_in)."""
    return quantize_weight_with_scale(w, c, act_mean_abs, bits, group_size)[0]


@registry.register("awq", spec_cls=QuantSpec)
def _compress(w, stats, spec):
    c = calib.covariance(stats, damp=spec.damp)
    am = calib.act_mean_abs(stats)
    g = spec.group_for(w.shape[1])
    wq, s = quantize_weight_with_scale(w, c, am, spec.bits, g)
    # wq·diag(s) is exactly on the group grid, so packing in scaled space
    # (col_scale=s) round-trips: qt.dequant() == wq up to regrid rounding.
    qt = QTensor.from_dense(wq, spec.bits, g, col_scale=s)
    return registry.CompressResult(theta=qt.dequant(), qtensor=qt,
                                   aux={"col_scaled": True, "covariance": c})


__all__ = ["quantize_weight", "quantize_weight_with_scale"]
