"""SparseGPT (Frantar & Alistarh, 2023) — OBS-based one-shot pruning.

Faithful reimplementation of the official algorithm structure: Hessian
H ∝ XXᵀ with 1% dampening, inverse via Cholesky, columns processed left to
right in blocks with per-block mask selection by w²/[H⁻¹]_jj² and error
propagation into the not-yet-pruned columns.

The algorithm is inherently sequential over columns, so this runs in numpy on
host (it is a *baseline*; AWP itself is the jit/Pallas path). Matches the
paper's evaluation scale: baselines are executed on real (small) layers, the
production-dim path is compile-only.
"""
from __future__ import annotations

import numpy as np

from repro.core import registry as _registry
from repro.core.specs import PruneSpec as _PruneSpec


def _prepare_hinv(c: np.ndarray, damp_frac: float = 0.01) -> np.ndarray:
    """H = C (scale-free), dead-column guard, 1% dampening, then the
    upper-Cholesky factor of H⁻¹ (as in the official torch implementation:
    cholesky → cholesky_inverse → cholesky(upper))."""
    h = np.array(c, dtype=np.float64, copy=True)
    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    damp = damp_frac * np.mean(np.diag(h))
    h[np.diag_indices_from(h)] += damp
    hinv = np.linalg.inv(h)
    # cholesky of the inverse, upper factor: hinv = Uᵀ U  (np gives lower L)
    u = np.linalg.cholesky(hinv).T
    return np.ascontiguousarray(u)


def prune_weight(w, c, k: int, blocksize: int = 128) -> np.ndarray:
    """Prune each row of w (d_out, d_in) to k nonzeros. c: (d_in, d_in)."""
    w = np.array(w, dtype=np.float64, copy=True)
    d_out, d_in = w.shape
    sparsity = 1.0 - k / d_in
    hinv = _prepare_hinv(np.asarray(c, np.float64))
    dead = np.diag(np.asarray(c)) == 0
    w[:, dead] = 0.0

    losses = np.zeros(d_out)
    for i1 in range(0, d_in, blocksize):
        i2 = min(i1 + blocksize, d_in)
        count = i2 - i1
        w1 = w[:, i1:i2].copy()
        q1 = np.zeros_like(w1)
        err1 = np.zeros_like(w1)
        hinv1 = hinv[i1:i2, i1:i2]
        # per-block mask: prune the `sparsity` fraction with smallest
        # w² / [H⁻¹]_jj² within each row's block slice
        tmp = w1 ** 2 / (np.diag(hinv1) ** 2)[None, :]
        n_prune = int(round(count * sparsity))
        if n_prune > 0:
            thresh = np.sort(tmp, axis=1)[:, n_prune - 1][:, None]
            mask1 = tmp <= thresh
        else:
            mask1 = np.zeros_like(tmp, dtype=bool)
        for j in range(count):
            wj = w1[:, j]
            d = hinv1[j, j]
            q = wj.copy()
            q[mask1[:, j]] = 0.0
            q1[:, j] = q
            losses += (wj - q) ** 2 / d ** 2
            err = (wj - q) / d
            w1[:, j:] -= np.outer(err, hinv1[j, j:])
            err1[:, j] = err
        w[:, i1:i2] = q1
        w[:, i2:] -= err1 @ hinv[i1:i2, i2:]

    # The block-local thresholds track the target rate but may drift by a few
    # entries per row; enforce exact row-k on the final matrix (keeps the
    # OBS-updated values, drops the smallest-|.| surplus).
    idx = np.argsort(-np.abs(w), axis=1)[:, :k]
    mask = np.zeros_like(w, dtype=bool)
    np.put_along_axis(mask, idx, True, axis=1)
    return (w * mask).astype(np.float32)


__all__ = ["prune_weight"]


@_registry.register("sparsegpt", spec_cls=_PruneSpec)
def _compress(w, stats, spec):
    import jax.numpy as jnp

    from repro.core import calibration as calib
    c = calib.covariance(stats, damp=spec.damp)
    theta = jnp.asarray(prune_weight(
        np.asarray(w, np.float32), np.asarray(c, np.float64),
        spec.k_for(w.shape[1])))
    return _registry.CompressResult(theta=theta, mask=theta != 0,
                                    aux={"covariance": c})
