"""GPTQ (Frantar et al., 2022) — OBS-based group quantization with error
propagation. Same Hessian machinery as SparseGPT; columns are quantized left
to right, the incurred error is folded into the remaining columns, and group
scales (group_size=128, asymmetric min/max, per output row) are refreshed at
every group boundary from the *current* (error-corrected) weights.
Sequential over columns → numpy host implementation (baseline-only path).
"""
from __future__ import annotations

import numpy as np

from repro.core import registry as _registry
from repro.core.baselines.sparsegpt import _prepare_hinv
from repro.core.specs import QuantSpec as _QuantSpec


def _group_qparams(block: np.ndarray, bits: int):
    """Asymmetric min/max scale+zero per row for one group of columns."""
    gmax = block.max(axis=1)
    gmin = block.min(axis=1)
    qmax = 2 ** bits - 1
    scale = np.maximum((gmax - gmin) / qmax, 1e-8)
    zero = np.clip(np.round(-gmin / scale), 0, qmax)
    return scale, zero, qmax


def _quant_col(col, scale, zero, qmax):
    q = np.clip(np.round(col / scale) + zero, 0, qmax)
    return (q - zero) * scale, q


def quantize_weight(w, c, bits: int, group_size: int = 128,
                    blocksize: int = 128, return_qparams: bool = False):
    """Quantize w (d_out, d_in) to INT-`bits` with per-(row, group) scales.

    With ``return_qparams`` also returns the integer codes and per-group
    scale/zero actually used — the grids are refreshed from error-corrected
    weights mid-stream, so they can NOT be recovered from the output.
    """
    w = np.array(w, dtype=np.float64, copy=True)
    d_out, d_in = w.shape
    hinv = _prepare_hinv(np.asarray(c, np.float64))
    dead = np.diag(np.asarray(c)) == 0
    w[:, dead] = 0.0

    if return_qparams:
        n_groups = (d_in + group_size - 1) // group_size
        codes = np.zeros((d_out, d_in))
        g_scale = np.ones((d_out, n_groups))
        g_zero = np.zeros((d_out, n_groups))
    scale = zero = None
    qmax = 2 ** bits - 1
    for i1 in range(0, d_in, blocksize):
        i2 = min(i1 + blocksize, d_in)
        count = i2 - i1
        w1 = w[:, i1:i2].copy()
        q1 = np.zeros_like(w1)
        err1 = np.zeros_like(w1)
        hinv1 = hinv[i1:i2, i1:i2]
        for j in range(count):
            col_idx = i1 + j
            if col_idx % group_size == 0:
                g_end = min(col_idx + group_size, d_in)
                # scales from the error-corrected current weights
                g_block = np.concatenate(
                    [w1[:, j:min(j + group_size, count)],
                     w[:, i2:g_end]], axis=1) if g_end > i2 else \
                    w1[:, j:j + (g_end - col_idx)]
                scale, zero, qmax = _group_qparams(g_block, bits)
                if return_qparams:
                    gi = col_idx // group_size
                    g_scale[:, gi] = scale
                    g_zero[:, gi] = zero
            wj = w1[:, j]
            d = hinv1[j, j]
            q, q_int = _quant_col(wj, scale, zero, qmax)
            q1[:, j] = q
            if return_qparams:
                codes[:, col_idx] = q_int
            err = (wj - q) / d
            w1[:, j:] -= np.outer(err, hinv1[j, j:])
            err1[:, j] = err
        w[:, i1:i2] = q1
        w[:, i2:] -= err1 @ hinv[i1:i2, i2:]
    out = w.astype(np.float32)
    if return_qparams:
        return out, codes, g_scale, g_zero
    return out


@_registry.register("gptq", spec_cls=_QuantSpec)
def _compress(w, stats, spec):
    import jax.numpy as jnp

    from repro.core import calibration as calib
    from repro.quant import QTensor
    c = calib.covariance(stats, damp=spec.damp)
    g = spec.group_for(w.shape[1])
    _, codes, g_scale, g_zero = quantize_weight(
        np.asarray(w, np.float32), np.asarray(c, np.float64), spec.bits, g,
        return_qparams=True)
    # Pack GPTQ's OWN codes/grids (they're refreshed mid-stream and can't be
    # recovered from the dense output); theta = dequant(codes) keeps the
    # checkpoint and serving path consistent with the artifact.
    qt = QTensor.from_codes(jnp.asarray(codes, jnp.int32),
                            jnp.asarray(g_scale, jnp.float32),
                            jnp.asarray(g_zero, jnp.float32), spec.bits, g)
    return _registry.CompressResult(theta=qt.dequant(), qtensor=qt,
                                    aux={"covariance": c})


__all__ = ["quantize_weight"]
