"""GPTQ (Frantar et al., 2022) — OBS-based group quantization with error
propagation. Same Hessian machinery as SparseGPT; columns are quantized left
to right, the incurred error is folded into the remaining columns, and group
scales (group_size=128, asymmetric min/max, per output row) are refreshed at
every group boundary from the *current* (error-corrected) weights.
Sequential over columns → numpy host implementation (baseline-only path).
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines.sparsegpt import _prepare_hinv


def _group_qparams(block: np.ndarray, bits: int):
    """Asymmetric min/max scale+zero per row for one group of columns."""
    gmax = block.max(axis=1)
    gmin = block.min(axis=1)
    qmax = 2 ** bits - 1
    scale = np.maximum((gmax - gmin) / qmax, 1e-8)
    zero = np.clip(np.round(-gmin / scale), 0, qmax)
    return scale, zero, qmax


def _quant_col(col, scale, zero, qmax):
    q = np.clip(np.round(col / scale) + zero, 0, qmax)
    return (q - zero) * scale


def quantize_weight(w, c, bits: int, group_size: int = 128,
                    blocksize: int = 128) -> np.ndarray:
    """Quantize w (d_out, d_in) to INT-`bits` with per-(row, group) scales."""
    w = np.array(w, dtype=np.float64, copy=True)
    d_out, d_in = w.shape
    hinv = _prepare_hinv(np.asarray(c, np.float64))
    dead = np.diag(np.asarray(c)) == 0
    w[:, dead] = 0.0

    scale = zero = None
    qmax = 2 ** bits - 1
    for i1 in range(0, d_in, blocksize):
        i2 = min(i1 + blocksize, d_in)
        count = i2 - i1
        w1 = w[:, i1:i2].copy()
        q1 = np.zeros_like(w1)
        err1 = np.zeros_like(w1)
        hinv1 = hinv[i1:i2, i1:i2]
        for j in range(count):
            col_idx = i1 + j
            if col_idx % group_size == 0:
                g_end = min(col_idx + group_size, d_in)
                # scales from the error-corrected current weights
                g_block = np.concatenate(
                    [w1[:, j:min(j + group_size, count)],
                     w[:, i2:g_end]], axis=1) if g_end > i2 else \
                    w1[:, j:j + (g_end - col_idx)]
                scale, zero, qmax = _group_qparams(g_block, bits)
            wj = w1[:, j]
            d = hinv1[j, j]
            q = _quant_col(wj, scale, zero, qmax)
            q1[:, j] = q
            err = (wj - q) / d
            w1[:, j:] -= np.outer(err, hinv1[j, j:])
            err1[:, j] = err
        w[:, i1:i2] = q1
        w[:, i2:] -= err1 @ hinv[i1:i2, i2:]
    return w.astype(np.float32)


__all__ = ["quantize_weight"]
