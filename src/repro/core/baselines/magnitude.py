"""Magnitude pruning — the activation-blind baseline of Eq. (1)."""
from __future__ import annotations

import functools

import jax

from repro.core import projections as proj, registry
from repro.core.specs import PruneSpec


@functools.partial(jax.jit, static_argnames=("k", "per_row"))
def prune_weight(w: jax.Array, k: int, per_row: bool = True) -> jax.Array:
    """Keep the k largest-|w| per row (Wanda's comparison-group convention,
    which the paper's Tables 1-2 use) or k·d_out globally."""
    if per_row:
        return proj.topk_row(w, k)
    return proj.topk_matrix(w, k * w.shape[0])


@registry.register("magnitude", spec_cls=PruneSpec)
def _compress(w, stats, spec):
    theta = prune_weight(w, spec.k_for(w.shape[1]))
    return registry.CompressResult(theta=theta, mask=theta != 0)


__all__ = ["prune_weight"]
