"""Wanda (Sun et al. 2023): prune by |W_ij| · ‖X[j, :]‖₂, row-wise groups.

Equivalent to approximating C^½ by its diagonal in Eq. (3). Also serves as
AWP's pruning initializer (§4.1). Operates in paper orientation (d_out, d_in);
the activation scale multiplies *columns* (input channels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import calibration as calib, projections as proj, registry
from repro.core.specs import PruneSpec


def scores(w: jax.Array, c: jax.Array) -> jax.Array:
    """Wanda importance scores. ‖X[j,:]‖₂ = sqrt(n·C_jj) ∝ sqrt(C_jj); the
    per-column scale is monotone so the constant n drops out of top-k."""
    col_scale = jnp.sqrt(jnp.maximum(jnp.diagonal(c), 0.0))
    return jnp.abs(w) * col_scale[None, :]


@functools.partial(jax.jit, static_argnames=("k",))
def prune_weight(w: jax.Array, c: jax.Array, k: int) -> jax.Array:
    """Zero everything outside the per-row top-k of the Wanda score."""
    s = scores(w, c)
    _, idx = jax.lax.top_k(s, k)
    rows = jnp.arange(w.shape[0])[:, None]
    mask = jnp.zeros(w.shape, dtype=bool).at[rows, idx].set(True)
    return jnp.where(mask, w, 0)


@functools.partial(jax.jit, static_argnames=("n", "m"))
def prune_weight_n_m(w: jax.Array, c: jax.Array, n: int = 2, m: int = 4) -> jax.Array:
    """N:M structured Wanda (keep n of every m by score)."""
    s = scores(w, c)
    d_out, d_in = w.shape
    g_s = s.reshape(d_out, d_in // m, m)
    _, idx = jax.lax.top_k(g_s, n)
    mask = jax.nn.one_hot(idx, m, dtype=bool).any(axis=-2)
    return (w.reshape(d_out, d_in // m, m) * mask).reshape(d_out, d_in)


@registry.register("wanda", spec_cls=PruneSpec)
def _compress(w, stats, spec):
    c = calib.covariance(stats, damp=spec.damp)
    if spec.nm is not None:
        theta = prune_weight_n_m(w, c, *spec.nm)
    else:
        theta = prune_weight(w, c, spec.k_for(w.shape[1]))
    return registry.CompressResult(theta=theta, mask=theta != 0,
                                   aux={"covariance": c})


__all__ = ["scores", "prune_weight", "prune_weight_n_m"]
