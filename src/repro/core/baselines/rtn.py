"""Round-To-Nearest group quantization — the activation-blind quant baseline
and AWP's quantization initializer (§4.2)."""
from __future__ import annotations

import functools

import jax

from repro.core import projections as proj, registry
from repro.core.specs import QuantSpec
from repro.quant import QTensor


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def quantize_weight(w: jax.Array, bits: int, group_size: int = 128) -> jax.Array:
    """Group-wise asymmetric min/max quantize-dequantize of W itself."""
    return proj.quant_project(w, bits, group_size)


@registry.register("rtn", spec_cls=QuantSpec)
def _compress(w, stats, spec):
    g = spec.group_for(w.shape[1])
    # from_dense(w) computes the same min/max grid as quantize_weight, so
    # qt.dequant() IS the RTN weight — codes are the source of truth.
    qt = QTensor.from_dense(w, spec.bits, g)
    return registry.CompressResult(theta=qt.dequant(), qtensor=qt)


__all__ = ["quantize_weight"]
