"""Round-To-Nearest group quantization — the activation-blind quant baseline
and AWP's quantization initializer (§4.2)."""
from __future__ import annotations

import functools

import jax

from repro.core import projections as proj


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def quantize_weight(w: jax.Array, bits: int, group_size: int = 128) -> jax.Array:
    """Group-wise asymmetric min/max quantize-dequantize of W itself."""
    return proj.quant_project(w, bits, group_size)


__all__ = ["quantize_weight"]
