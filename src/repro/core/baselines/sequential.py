"""Sequential prune→quant / quant→prune pipelines — Table 4/5 baselines.

Wanda+AWQ  = prune first with Wanda, then AWQ-quantize the pruned weight and
             re-apply the sparsity mask (quantization can perturb zeros).
AWQ+Wanda  = quantize first with AWQ, then Wanda-prune the quantized weight.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import calibration as calib, registry
from repro.core.baselines import awq, wanda
from repro.core.specs import JointSpec


def wanda_then_awq(w, c, act_mean_abs, k: int, bits: int = 4,
                   group_size: int = 128):
    pruned = wanda.prune_weight(w, c, k)
    mask = pruned != 0
    q = awq.quantize_weight(pruned, c, act_mean_abs, bits, group_size)
    return jnp.where(mask, q, 0.0)


def awq_then_wanda(w, c, act_mean_abs, k: int, bits: int = 4,
                   group_size: int = 128):
    q = awq.quantize_weight(w, c, act_mean_abs, bits, group_size)
    return wanda.prune_weight(q, c, k)


def _adapter(pipeline):
    def _compress(w, stats, spec):
        c = calib.covariance(stats, damp=spec.damp)
        am = calib.act_mean_abs(stats)
        theta = pipeline(w, c, am, spec.k_for(w.shape[1]), spec.bits,
                         spec.group_for(w.shape[1]))
        # AWQ's per-channel scale is folded into theta, so a plain-grid
        # repack would undo it — these baselines stay dense (mask only).
        return registry.CompressResult(theta=theta, mask=theta != 0,
                                       aux={"covariance": c})
    return _compress


registry.register("wanda_awq", spec_cls=JointSpec)(_adapter(wanda_then_awq))
registry.register("awq_wanda", spec_cls=JointSpec)(_adapter(awq_then_wanda))


__all__ = ["wanda_then_awq", "awq_then_wanda"]
