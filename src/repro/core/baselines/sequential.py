"""Sequential prune→quant / quant→prune pipelines — Table 4/5 baselines.

Wanda+AWQ  = prune first with Wanda, then AWQ-quantize the pruned weight and
             re-apply the sparsity mask (quantization can perturb zeros).
AWQ+Wanda  = quantize first with AWQ, then Wanda-prune the quantized weight.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.baselines import awq, wanda


def wanda_then_awq(w, c, act_mean_abs, k: int, bits: int = 4,
                   group_size: int = 128):
    pruned = wanda.prune_weight(w, c, k)
    mask = pruned != 0
    q = awq.quantize_weight(pruned, c, act_mean_abs, bits, group_size)
    return jnp.where(mask, q, 0.0)


def awq_then_wanda(w, c, act_mean_abs, k: int, bits: int = 4,
                   group_size: int = 128):
    q = awq.quantize_weight(w, c, act_mean_abs, bits, group_size)
    return wanda.prune_weight(q, c, k)


__all__ = ["wanda_then_awq", "awq_then_wanda"]
