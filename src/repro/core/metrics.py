"""Evaluation metrics: activation-aware reconstruction loss, perplexity,
and the theory-side quantities (condition number, certified step size) from
Appendix A."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.awp import activation_loss  # re-export  # noqa: F401


def recon_loss(w, theta, c) -> jax.Array:
    """Unnormalized tr((W−Θ) C (W−Θ)ᵀ) = ‖WX − ΘX‖_F² / n."""
    e = jnp.asarray(w, jnp.float32) - jnp.asarray(theta, jnp.float32)
    return jnp.einsum("ij,jk,ik->", e, jnp.asarray(c, jnp.float32), e)


def condition_number(c) -> float:
    """κ = λmax(C)/λmin(C) — Appendix A.2's convergence-rate driver."""
    ev = np.linalg.eigvalsh(np.asarray(c, np.float64))
    lo = max(ev[0], 1e-12)
    return float(ev[-1] / lo)


def certified_eta(c) -> float:
    """Step size η = 1/β = 1/(2λmax(C)) certified by the RSC/RSM analysis.
    The paper's practical 2/‖C‖_F is an inexpensive surrogate; property tests
    check both converge on well-conditioned C."""
    ev = np.linalg.eigvalsh(np.asarray(c, np.float64))
    return float(1.0 / (2.0 * max(ev[-1], 1e-12)))


def sparsity(theta) -> float:
    """Fraction of exactly-zero entries."""
    t = np.asarray(theta)
    return float((t == 0).mean())


def perplexity(loss_fn, params, batches) -> float:
    """exp(mean token NLL) over an iterable of (tokens, labels) batches.
    ``loss_fn(params, tokens, labels) -> (sum_nll, token_count)``."""
    tot, cnt = 0.0, 0.0
    for tokens, labels in batches:
        nll, n = loss_fn(params, tokens, labels)
        tot += float(nll)
        cnt += float(n)
    return float(np.exp(tot / max(cnt, 1.0)))


__all__ = ["activation_loss", "recon_loss", "condition_number",
           "certified_eta", "sparsity", "perplexity"]
