"""Whole-model layer-wise compression driver (the paper's pipeline).

Sequential block-wise compression with error propagation, exactly like the
GPTQ/SparseGPT/AWQ reference pipelines the paper compares against:

  1. embed the calibration batches,
  2. per block: capture every linear's input activations → fold into
     per-linear CalibStats (per-*expert* stats for MoE blocks),
  3. compress each linear with the method its policy rule selects
     (dispatched through :mod:`repro.core.registry` — no string if/elif),
  4. re-run the block with compressed weights to produce the next block's
     (error-propagated) inputs.

Weights are stored (d_in, d_out); the compression math runs in paper
orientation (d_out, d_in) — transposed at this boundary only.

``compress_model`` accepts a :class:`repro.core.specs.Policy` (per-layer
patterns → typed specs), a bare spec (applied everywhere), or the legacy
flat :class:`CompressionConfig`, and returns ``(params, CompressionReport)``
where the report carries per-layer metrics AND the structured artifacts
(masks, packed ``QTensor`` codes) that the packed-checkpoint path
materializes for serving.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import (ITER_BUCKETS, RESIDUAL_BUCKETS, MetricsRegistry,
                       default_registry, trace_window)
from repro.core import awp, calibration as calib, registry
from repro.core import baselines  # noqa: F401  — registers built-in methods
from repro.core.specs import (CompressSpec, Policy, effective_group,
                              qualified_name)

METHODS = registry.available()


@dataclasses.dataclass
class CompressionConfig:
    """Legacy flat config — one method/ratio/bits for every linear.

    Kept as a thin front-end: it converts to a single-rule :class:`Policy`
    (``as_policy``). New code should build Policy/specs directly.
    """
    method: str = "awp_prune"
    ratio: float = 0.5           # pruning ratio p (fraction zeroed)
    bits: int = 4
    group_size: int = 128
    damp: float = 0.01           # covariance damping (MoE low-token guard)
    skip: tuple = ()             # linear-name substrings to leave dense

    def spec(self) -> CompressSpec:
        cls = registry.spec_cls_for(self.method)
        fields = {f.name for f in dataclasses.fields(cls)}
        kwargs = {k: getattr(self, k)
                  for k in ("ratio", "bits", "group_size", "damp")
                  if k in fields}
        return cls(method=self.method, **kwargs)

    def as_policy(self) -> Policy:
        # alias_only: legacy skip substrings match the SHORT layer name only
        # ("o" must hit "wo", not the "o" in "blocks.0.…")
        return Policy([(f"*{s}*", None, True) for s in self.skip],
                      default=self.spec())


PolicyLike = Union[Policy, CompressSpec, CompressionConfig]


def as_policy(policy: PolicyLike) -> Policy:
    if isinstance(policy, Policy):
        return policy
    if isinstance(policy, CompressSpec):
        return Policy(default=policy)
    if isinstance(policy, CompressionConfig):
        return policy.as_policy()
    raise TypeError(f"expected Policy/CompressSpec/CompressionConfig, "
                    f"got {type(policy).__name__}")


def compress_weight(w_paper: jax.Array, stats: calib.CalibStats,
                    cfg: Union[CompressSpec, CompressionConfig]) -> jax.Array:
    """Legacy single-weight entry point: dense compressed weight only."""
    spec = cfg.spec() if isinstance(cfg, CompressionConfig) else cfg
    if not isinstance(spec, CompressSpec):
        raise TypeError(f"compress_weight takes one spec/config, "
                        f"got {type(cfg).__name__}")
    return compress_layer(w_paper, stats, spec).theta


def compress_layer(w_paper: jax.Array, stats: calib.CalibStats,
                   spec: CompressSpec) -> registry.CompressResult:
    """Compress one weight (paper orientation) via the registered method."""
    registry.validate_spec(spec)
    return registry.get_method(spec.method)(w_paper, stats, spec)


# ---------------------------------------------------------------------------
# capture folding
# ---------------------------------------------------------------------------

def _init_stacked(num: int, d_in: int) -> calib.CalibStats:
    """Per-expert stats stacked on a leading expert axis (one leaf each)."""
    return calib.CalibStats(n=jnp.zeros((num,), jnp.float32),
                            c_sum=jnp.zeros((num, d_in, d_in), jnp.float32),
                            abs_sum=jnp.zeros((num, d_in), jnp.float32))


def _fold_captures(stats: Dict[str, Any], caps: Dict[str, jax.Array],
                   num_experts: int):
    """Fold one batch's captures into the per-capture-key stats dict.

    MoE experts are folded as ONE einsum over the expert axis into stacked
    ``(E, d, d)`` sufficient statistics (keys ``("moe",)``/``("moe_down",)``)
    instead of E separate dispatches per batch. The routing gate is 0/1, so
    the masked update Σ (m·x)ᵀ(m·x) equals Σ m·x xᵀ — same statistics as the
    old per-expert loop, including its convention that ``n`` counts ALL
    tokens (masked rows contribute zeros but are counted)."""
    for key, val in caps.items():
        if key in ("moe_mask", "moe_up"):
            continue
        if key == "moe_in":
            x = val.reshape(-1, val.shape[-1]).astype(jnp.float32)   # (T, d)
            mask = caps["moe_mask"].astype(jnp.float32)              # (T, E)
            up = caps["moe_up"].astype(jnp.float32)                  # (T, E, f)
            t = x.shape[0]
            st = stats.get(("moe",))
            if st is None:
                st = _init_stacked(num_experts, x.shape[-1])
            stats[("moe",)] = calib.CalibStats(
                n=st.n + t,
                c_sum=st.c_sum + jnp.einsum("te,td,tf->edf", mask, x, x),
                abs_sum=st.abs_sum + jnp.einsum("te,td->ed", mask,
                                                jnp.abs(x)))
            std = stats.get(("moe_down",))
            if std is None:
                std = _init_stacked(num_experts, up.shape[-1])
            stats[("moe_down",)] = calib.CalibStats(
                n=std.n + t,
                c_sum=std.c_sum + jnp.einsum("te,tef,teg->efg", mask, up, up),
                abs_sum=std.abs_sum + jnp.einsum("te,tef->ef", mask,
                                                 jnp.abs(up)))
            continue
        d_in = val.shape[-1]
        st = stats.setdefault(key, calib.init(d_in))
        stats[key] = calib.update(st, val)


def _stats_for(stats, cap_key: str, name: str):
    if cap_key in ("moe", "moe_down"):
        e = int(name.rsplit("_", 1)[1])
        return calib.slice_stats(stats[(cap_key,)], e)
    return stats[cap_key]


# ---------------------------------------------------------------------------
# param tree get/set by path (supports int expert index inside stacked leaves)
#
# Path grammar: dict keys, optionally ending in one int (expert index); a
# leading "blocks" key means the leaf is layer-stacked and ``layer`` selects
# the leading dim. E.g. ("blocks","moe","wu", e) → params[...]["wu"][layer, e].
# ---------------------------------------------------------------------------

def resolve_path(path, layer: Optional[int]):
    """(dict-key path, stacked-leaf index tuple) for one linear's path."""
    dict_path = [p for p in path if not isinstance(p, int)]
    idx = tuple(p for p in path if isinstance(p, int))
    if dict_path[0] == "blocks" and layer is not None:
        idx = (layer,) + idx
    return dict_path, idx


_resolve = resolve_path


def get_linear(params, path, layer: Optional[int]) -> jax.Array:
    """Return weight in PAPER orientation (d_out, d_in)."""
    dict_path, idx = _resolve(path, layer)
    leaf = params
    for p in dict_path:
        leaf = leaf[p]
    if idx:
        leaf = leaf[idx]
    return leaf.T


def _tree_set(params, path, layer: Optional[int], value):
    """Functional write of one (d_in, d_out)-oriented weight back in place."""
    dict_path, idx = _resolve(path, layer)

    def rec(node, rest):
        out = dict(node)
        key = rest[0]
        if len(rest) == 1:
            leaf = node[key]
            v = value.astype(leaf.dtype)
            out[key] = leaf.at[idx].set(v) if idx else v
        else:
            out[key] = rec(node[key], rest[1:])
        return out

    return rec(params, dict_path)


def set_linear(params, path, layer: Optional[int], w_paper):
    """Functional write of one PAPER-orientation (d_out, d_in) weight."""
    return _tree_set(params, path, layer, w_paper.T)


def _tree_set_many(params, dict_path, idx_list, values):
    """Functional write of MANY same-leaf updates in one scatter.

    ``idx_list`` holds the stacked-leaf index tuples (same arity) of each
    value; values are stored orientation (d_in, d_out). Replaces the O(E)
    per-expert ``leaf.at[idx].set`` round trips — each of which copies the
    whole stacked leaf — with a single advanced-index scatter."""
    def rec(node, rest):
        out = dict(node)
        key = rest[0]
        if len(rest) == 1:
            leaf = node[key]
            vals = [v.astype(leaf.dtype) for v in values]
            if idx_list[0] == ():
                assert len(vals) == 1
                out[key] = vals[0]
            elif len(vals) == 1:
                out[key] = leaf.at[idx_list[0]].set(vals[0])
            else:
                gather = tuple(jnp.asarray(col)
                               for col in zip(*idx_list))
                out[key] = leaf.at[gather].set(jnp.stack(vals))
        else:
            out[key] = rec(node[key], rest[1:])
        return out
    return rec(params, list(dict_path))


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerReport:
    block: int
    name: str
    loss_before: float           # activation loss of uncompressed (=0)
    loss_after: float            # normalized activation-aware loss
    sparsity: float
    seconds: float
    method: str = ""
    qualname: str = ""


@dataclasses.dataclass
class LayerArtifact:
    """One layer's structured compression output, addressable for write-back.

    Inside a CompressionReport the result's ``theta`` is None — the dense
    weight lives in the returned params; only mask/qtensor/metrics are kept
    (holding every theta would double peak memory at production scale)."""
    name: str                    # qualified name, e.g. "blocks.3.attn.wq"
    path: tuple                  # param-tree path
    layer: Optional[int]         # stacked-block index (None for shared)
    spec: CompressSpec
    result: registry.CompressResult


@dataclasses.dataclass
class CompressionReport:
    """Per-layer metrics + artifacts. Iterates like the old report list."""
    layers: List[LayerReport] = dataclasses.field(default_factory=list)
    artifacts: Dict[str, LayerArtifact] = dataclasses.field(default_factory=dict)
    policy: Optional[Policy] = None

    def __iter__(self):
        return iter(self.layers)

    def __len__(self):
        return len(self.layers)

    def __getitem__(self, i):
        return self.layers[i]

    def packed_layers(self) -> Dict[str, LayerArtifact]:
        """Artifacts that carry packed QTensor codes (quantizing methods)."""
        return {n: a for n, a in self.artifacts.items()
                if a.result.qtensor is not None}

    def mean_loss(self) -> float:
        return float(np.mean([r.loss_after for r in self.layers])) \
            if self.layers else 0.0

    def mean_sparsity(self) -> float:
        return float(np.mean([r.sparsity for r in self.layers])) \
            if self.layers else 0.0

    def summary(self) -> str:
        by_method: Dict[str, int] = {}
        for r in self.layers:
            by_method[r.method] = by_method.get(r.method, 0) + 1
        packed = self.packed_layers()
        packed_bytes = sum(a.result.qtensor.nbytes() for a in packed.values())
        lines = [f"{len(self.layers)} layers compressed "
                 f"({', '.join(f'{m}×{n}' for m, n in sorted(by_method.items()))})",
                 f"mean loss {self.mean_loss():.4f}  "
                 f"mean sparsity {self.mean_sparsity():.2f}"]
        if packed:
            lines.append(f"{len(packed)} packed QTensors, "
                         f"{packed_bytes / 1e6:.2f} MB")
        return "\n".join(lines)


def _block_works(model, params, block_idx: int, stats, policy: Policy):
    """Resolve this block's linears against the policy into LayerWork items.

    One host sync per BLOCK (not per layer) fetches the routed-token counts
    for the never-routed-expert guard."""
    from repro.core import batched as _batched
    works = []
    for (name, path, cap_key) in model.block_linears(block_idx):
        layer = block_idx if path[0] == "blocks" else None
        qname = qualified_name(path, layer)
        spec = policy.spec_for(qname, name)
        if spec is None:
            continue                         # rule says: leave dense
        st = _stats_for(stats, cap_key, name)
        works.append(_batched.LayerWork(name, qname, tuple(path), layer,
                                        spec, st,
                                        get_linear(params, path, layer)))
    if not works:
        return works
    ns = jax.device_get(jnp.stack([jnp.asarray(wk.stats.n) for wk in works]))
    return [wk for wk, n in zip(works, ns) if n >= 1]   # never routed: dense


def _record_layer_metrics(metrics: Optional[MetricsRegistry], method: str,
                          loss: float, seconds: float, iters) -> None:
    """Per-layer convergence telemetry: how many PGD iterations the layer
    took, what residual it converged to, and its (possibly block-amortized)
    wall time — the measurements the paper's per-layer claims rest on."""
    if metrics is None:
        return
    lab = {"method": method}
    metrics.counter("compress_layers_total", "layers compressed",
                    labelnames=("method",)).labels(**lab).inc()
    metrics.histogram(
        "compress_residual",
        "normalized activation-aware loss after compression",
        labelnames=("method",),
        buckets=RESIDUAL_BUCKETS).labels(**lab).observe(loss)
    metrics.histogram(
        "compress_layer_seconds",
        "per-layer compression wall time (batched: block time amortized)",
        labelnames=("method",), unit="seconds").labels(**lab).observe(seconds)
    if iters is not None:
        metrics.histogram(
            "compress_pgd_iters",
            "projected-gradient iterations to convergence",
            labelnames=("method",),
            buckets=ITER_BUCKETS).labels(**lab).observe(int(iters))


def _compress_block_batched(model, params, block_idx: int, stats,
                            policy: Policy, report: CompressionReport,
                            verbose: bool,
                            metrics: Optional[MetricsRegistry] = None):
    """Shape-bucketed block compression: one device program per bucket, all
    host syncs (metrics, masks, routing guard) amortized to block scope."""
    from repro.core import batched as _batched
    t0 = time.time()
    works = _block_works(model, params, block_idx, stats, policy)
    if not works:
        return params
    outcomes = _batched.compress_block(works, metrics=metrics)

    # grouped write-back: every update targeting the same stacked leaf (all
    # E experts of a block, q/k/v of one attn dict) lands in one scatter
    groups: Dict[tuple, List[int]] = {}
    for j, wk in enumerate(works):
        dict_path, idx = _resolve(wk.path, wk.layer)
        groups.setdefault(tuple(dict_path), []).append((idx, j))
    for dict_path, entries in groups.items():
        idx_list = [e[0] for e in entries]
        vals = [outcomes[e[1]][0].theta.T for e in entries]
        params = _tree_set_many(params, dict_path, idx_list, vals)

    # deferred materialization: one transfer for the whole block's metrics
    # and masks (the sequential driver paid one sync per layer here)
    losses = jnp.stack([loss for _, loss in outcomes])
    sps = jnp.stack([jnp.mean(res.theta == 0) for res, _ in outcomes])
    host = jax.device_get({"loss": losses, "sparsity": sps,
                           "masks": [res.mask for res, _ in outcomes],
                           "iters": [res.iters for res, _ in outcomes]})
    seconds = (time.time() - t0) / len(works)   # block time, amortized

    for j, wk in enumerate(works):
        res, _ = outcomes[j]
        loss = float(host["loss"][j])
        sp = float(host["sparsity"][j])
        if res.loss is None:
            res.loss = loss
        res.theta = None        # written back: the report must not pin a
        res.mask = host["masks"][j]      # second copy of the model on device
        if host["iters"][j] is not None:
            res.iters = int(host["iters"][j])
        report.layers.append(LayerReport(block_idx, wk.name, 0.0, loss, sp,
                                         seconds, method=wk.spec.method,
                                         qualname=wk.qname))
        report.artifacts[wk.qname] = LayerArtifact(wk.qname, wk.path,
                                                   wk.layer, wk.spec, res)
        _record_layer_metrics(metrics, wk.spec.method, loss, seconds,
                              host["iters"][j])
        if verbose:
            print(f"  block {block_idx} {wk.name} [{wk.spec.method}]: "
                  f"loss={loss:.4f} sparsity={sp:.2f}")
    return params


def _compress_block_sequential(model, params, block_idx: int, stats,
                               policy: Policy, report: CompressionReport,
                               verbose: bool,
                               metrics: Optional[MetricsRegistry] = None):
    """Layer-at-a-time reference driver (one program + host sync per layer).

    Kept as the numerical baseline the batched engine is benchmarked and
    parity-tested against (benchmarks/compress_bench.py)."""
    for (name, path, cap_key) in model.block_linears(block_idx):
        layer = block_idx if path[0] == "blocks" else None
        qname = qualified_name(path, layer)
        spec = policy.spec_for(qname, name)
        if spec is None:
            continue                     # rule says: leave dense
        st = _stats_for(stats, cap_key, name)
        if float(st.n) < 1:
            continue                     # expert never routed: keep dense
        w = get_linear(params, path, layer)
        t0 = time.time()
        res = compress_layer(w, st, spec)
        # covariance once per layer: reuse the one the adapter built
        c = res.aux.pop("covariance", None)
        if c is None:
            c = calib.covariance(st, damp=spec.damp)
        loss = float(awp.activation_loss(w, res.theta, c))
        if res.loss is None:
            res.loss = loss
        sp = float((np.asarray(res.theta) == 0).mean())
        seconds = time.time() - t0
        report.layers.append(LayerReport(block_idx, name, 0.0, loss, sp,
                                         seconds,
                                         method=spec.method,
                                         qualname=qname))
        report.artifacts[qname] = LayerArtifact(qname, tuple(path), layer,
                                                spec, res)
        _record_layer_metrics(metrics, spec.method, loss, seconds,
                              res.iters)
        if verbose:
            print(f"  block {block_idx} {name} [{spec.method}]: "
                  f"loss={loss:.4f} sparsity={sp:.2f}")
        params = _tree_set(params, path, layer, res.theta.T)
        # written back: drop theta, host the mask — the report must not
        # pin a second copy of the model (or per-layer masks) on device
        res.theta = None
        if res.iters is not None:
            res.iters = int(res.iters)
        if res.mask is not None:
            res.mask = np.asarray(res.mask)
    return params


def compress_model(model, params, calib_batches: List[dict],
                   policy: PolicyLike, verbose: bool = False,
                   engine: str = "batched",
                   metrics: Optional[MetricsRegistry] = None,
                   profile_dir: str = "", profile_block: int = -1):
    """Compress every linear of every block per the policy.

    ``engine="batched"`` (default) buckets each block's linears by
    (shape, spec) and compresses every bucket as one device program with
    host syncs deferred to block boundaries; ``engine="sequential"`` is the
    layer-at-a-time reference driver. Both return the same
    ``(params, CompressionReport)`` with per-layer losses matching to ~1e-5.

    ``metrics`` is the :class:`repro.obs.MetricsRegistry` receiving the
    convergence telemetry (per-layer PGD iterations/residual/wall time,
    per-bucket dispatch counts, per-block wall time); defaults to the
    process-global :func:`repro.obs.default_registry`. ``profile_block``
    opts one block (capture + compress + propagate) into a
    ``jax.profiler`` trace window written under ``profile_dir``.
    """
    if engine not in ("batched", "sequential"):
        raise ValueError(f"engine must be 'batched' or 'sequential', "
                         f"got {engine!r}")
    if metrics is None:
        metrics = default_registry()
    policy = as_policy(policy)
    # fail fast: unknown methods / method-spec mismatches surface here, not
    # minutes into the block loop
    for s in [r.spec for r in policy.rules] + [policy.default]:
        if s is not None:
            registry.validate_spec(s)
    num_experts = getattr(model.cfg, "num_experts", 0)
    hs = [model.embed(params, b) for b in calib_batches]
    report = CompressionReport(policy=policy)
    block_fn = (_compress_block_batched if engine == "batched"
                else _compress_block_sequential)
    h_block = metrics.histogram(
        "compress_block_seconds",
        "per-block wall time: capture + compress + propagate",
        labelnames=("engine",), unit="seconds").labels(engine=engine)

    for i in range(model.num_blocks()):
        ctx = (trace_window(profile_dir) if i == profile_block
               else contextlib.nullcontext())
        tb = time.time()
        with ctx:
            # 1) capture calibration statistics for this block
            stats: Dict[Any, calib.CalibStats] = {}
            for h in hs:
                _, caps = model.block_apply_one(params, i, h, capture=True)
                _fold_captures(stats, caps, num_experts)
            # 2) compress each linear per its policy rule
            params = block_fn(model, params, i, stats, policy, report,
                              verbose, metrics=metrics)
            # 3) propagate compressed activations to the next block
            hs = [model.block_apply_one(params, i, h)[0] for h in hs]
        h_block.observe(time.time() - tb)
    return params, report


__all__ = ["CompressionConfig", "CompressionReport", "LayerArtifact",
           "LayerReport", "METHODS", "as_policy", "compress_layer",
           "compress_model", "compress_weight", "effective_group",
           "get_linear", "resolve_path", "set_linear"]
