"""Whole-model layer-wise compression driver (the paper's pipeline).

Sequential block-wise compression with error propagation, exactly like the
GPTQ/SparseGPT/AWQ reference pipelines the paper compares against:

  1. embed the calibration batches,
  2. per block: capture every linear's input activations → fold into
     per-linear CalibStats (per-*expert* stats for MoE blocks),
  3. compress each linear with the selected method,
  4. re-run the block with compressed weights to produce the next block's
     (error-propagated) inputs.

Weights are stored (d_in, d_out); the compression math runs in paper
orientation (d_out, d_in) — transposed at this boundary only.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import awp, calibration as calib
from repro.core import projections as proj
from repro.core.baselines import (magnitude, wanda, sparsegpt, rtn, awq, gptq,
                                  sequential)

METHODS = ("magnitude", "wanda", "sparsegpt", "awp_prune", "awp_prune_nm",
           "rtn", "awq", "gptq", "awp_quant", "awp_quant_scaled",
           "awp_joint", "wanda_awq", "awq_wanda")


def effective_group(d_in: int, group_size: int) -> int:
    """Largest divisor of d_in that is ≤ group_size (tiny models have
    d_in < 128; production dims are multiples of 128)."""
    g = min(group_size, d_in)
    while d_in % g:
        g -= 1
    return g


@dataclasses.dataclass
class CompressionConfig:
    method: str = "awp_prune"
    ratio: float = 0.5           # pruning ratio p (fraction zeroed)
    bits: int = 4
    group_size: int = 128
    damp: float = 0.01           # covariance damping (MoE low-token guard)
    skip: tuple = ()             # linear-name substrings to leave dense


def _k_for(cfg: CompressionConfig, d_in: int) -> int:
    return max(1, int(round((1.0 - cfg.ratio) * d_in)))


def compress_weight(w_paper: jax.Array, stats: calib.CalibStats,
                    cfg: CompressionConfig) -> jax.Array:
    """Compress one weight (paper orientation) with the configured method."""
    d_in = w_paper.shape[1]
    c = calib.covariance(stats, damp=cfg.damp)
    am = calib.act_mean_abs(stats)
    k = _k_for(cfg, d_in)
    g = effective_group(d_in, cfg.group_size)
    m = cfg.method
    if m == "magnitude":
        return magnitude.prune_weight(w_paper, k)
    if m == "wanda":
        return wanda.prune_weight(w_paper, c, k)
    if m == "sparsegpt":
        return jnp.asarray(sparsegpt.prune_weight(
            np.asarray(w_paper, np.float32), np.asarray(c, np.float64), k))
    if m == "awp_prune":
        return awp.prune(w_paper, c, k).theta
    if m == "awp_prune_nm":
        return awp.prune(w_paper, c, k, nm=(2, 4)).theta
    if m == "rtn":
        return rtn.quantize_weight(w_paper, cfg.bits, g)
    if m == "awq":
        return awq.quantize_weight(w_paper, c, am, cfg.bits, g)
    if m == "gptq":
        return jnp.asarray(gptq.quantize_weight(
            np.asarray(w_paper, np.float32), np.asarray(c, np.float64),
            cfg.bits, g))
    if m == "awp_quant":
        return awp.quantize(w_paper, c, cfg.bits, group_size=g).theta
    if m == "awp_quant_scaled":
        return awp.quantize_scaled(w_paper, c, am, cfg.bits, group_size=g).theta
    if m == "awp_joint":
        return awp.joint(w_paper, c, k, cfg.bits, group_size=g).theta
    if m == "wanda_awq":
        return sequential.wanda_then_awq(w_paper, c, am, k, cfg.bits, g)
    if m == "awq_wanda":
        return sequential.awq_then_wanda(w_paper, c, am, k, cfg.bits, g)
    raise ValueError(f"unknown method {cfg.method!r}")


# ---------------------------------------------------------------------------
# capture folding
# ---------------------------------------------------------------------------

def _fold_captures(stats: Dict[str, Any], caps: Dict[str, jax.Array],
                   num_experts: int):
    """Fold one batch's captures into the per-capture-key stats dict."""
    for key, val in caps.items():
        if key in ("moe_mask", "moe_up"):
            continue
        if key == "moe_in":
            x = val                                     # (T, d)
            mask = caps["moe_mask"].astype(jnp.float32) # (T, E)
            up = caps["moe_up"]                         # (T, E, f)
            for e in range(num_experts):
                me = mask[:, e:e + 1]
                st = stats.setdefault(("moe", e), calib.init(x.shape[-1]))
                stats[("moe", e)] = calib.update(st, x * me)
                std = stats.setdefault(("moe_down", e),
                                       calib.init(up.shape[-1]))
                stats[("moe_down", e)] = calib.update(std, up[:, e, :] * me)
            continue
        d_in = val.shape[-1]
        st = stats.setdefault(key, calib.init(d_in))
        stats[key] = calib.update(st, val)


def _stats_for(stats, cap_key: str, name: str):
    if cap_key in ("moe", "moe_down"):
        e = int(name.rsplit("_", 1)[1])
        return stats[(cap_key, e)]
    return stats[cap_key]


# ---------------------------------------------------------------------------
# param tree get/set by path (supports int expert index inside stacked leaves)
#
# Path grammar: dict keys, optionally ending in one int (expert index); a
# leading "blocks" key means the leaf is layer-stacked and ``layer`` selects
# the leading dim. E.g. ("blocks","moe","wu", e) → params[...]["wu"][layer, e].
# ---------------------------------------------------------------------------

def _resolve(path, layer: Optional[int]):
    dict_path = [p for p in path if not isinstance(p, int)]
    idx = tuple(p for p in path if isinstance(p, int))
    if dict_path[0] == "blocks" and layer is not None:
        idx = (layer,) + idx
    return dict_path, idx


def get_linear(params, path, layer: Optional[int]) -> jax.Array:
    """Return weight in PAPER orientation (d_out, d_in)."""
    dict_path, idx = _resolve(path, layer)
    leaf = params
    for p in dict_path:
        leaf = leaf[p]
    if idx:
        leaf = leaf[idx]
    return leaf.T


def _tree_set(params, path, layer: Optional[int], value):
    """Functional write of one (d_in, d_out)-oriented weight back in place."""
    dict_path, idx = _resolve(path, layer)

    def rec(node, rest):
        out = dict(node)
        key = rest[0]
        if len(rest) == 1:
            leaf = node[key]
            v = value.astype(leaf.dtype)
            out[key] = leaf.at[idx].set(v) if idx else v
        else:
            out[key] = rec(node[key], rest[1:])
        return out

    return rec(params, dict_path)


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerReport:
    block: int
    name: str
    loss_before: float           # activation loss of uncompressed (=0)
    loss_after: float            # normalized activation-aware loss
    sparsity: float
    seconds: float


def compress_model(model, params, calib_batches: List[dict],
                   cfg: CompressionConfig, verbose: bool = False):
    """Compress every linear of every block. Returns (params, reports)."""
    num_experts = getattr(model.cfg, "num_experts", 0)
    hs = [model.embed(params, b) for b in calib_batches]
    reports: List[LayerReport] = []
    skip = tuple(cfg.skip)

    for i in range(model.num_blocks()):
        # 1) capture calibration statistics for this block
        stats: Dict[Any, calib.CalibStats] = {}
        for h in hs:
            _, caps = model.block_apply_one(params, i, h, capture=True)
            _fold_captures(stats, caps, num_experts)
        # 2) compress each linear
        for (name, path, cap_key) in model.block_linears(i):
            if any(s in name for s in skip):
                continue
            layer = i if path[0] == "blocks" else None
            w = get_linear(params, path, layer)
            st = _stats_for(stats, cap_key, name)
            if float(st.n) < 1:
                continue                     # expert never routed: keep dense
            t0 = time.time()
            w_new = compress_weight(w, st, cfg)
            c = calib.covariance(st, damp=cfg.damp)
            loss = float(awp.activation_loss(w, w_new, c))
            sp = float((np.asarray(w_new) == 0).mean())
            reports.append(LayerReport(i, name, 0.0, loss, sp,
                                       time.time() - t0))
            if verbose:
                print(f"  block {i} {name}: loss={loss:.4f} sparsity={sp:.2f}")
            params = _tree_set(params, path, layer, w_new.T)
        # 3) propagate compressed activations to the next block
        hs = [model.block_apply_one(params, i, h)[0] for h in hs]
    return params, reports


__all__ = ["CompressionConfig", "compress_model", "compress_weight",
           "LayerReport", "METHODS", "effective_group", "get_linear"]
