"""Projection operators onto AWP constraint sets.

All operators use the *paper orientation*: weights are ``(d_out, d_in)`` and
"row" means an output row (one output neuron's fan-in), matching Eq. (5)'s
row-wise sparsity set ``C_row`` and the group axis of group-wise quantization
(groups tile the ``d_in`` axis, as in AWQ/GPTQ with group_size=128).

Everything here is pure jnp and jit-friendly (static k / bits / group_size).
The Pallas kernels in ``repro.kernels`` implement the hot ones for TPU; these
are also their reference semantics (kernels/... /ref.py re-export from here).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Sparsity projections (hard thresholding, Proj_{C_row} etc.)
# ---------------------------------------------------------------------------

def topk_row(z: jax.Array, k: int) -> jax.Array:
    """Keep the k largest-|.| entries of each row of z; zero the rest.

    Exact-k semantics (ties broken by index, like ``jax.lax.top_k``).
    z: (..., d_in) -> same shape (leading dims are independent rows, so the
    batched engine projects a whole (B, d_out, d_in) stack in one call).
    """
    if k >= z.shape[-1]:
        return z
    if k <= 0:
        return jnp.zeros_like(z)
    return jnp.where(topk_row_mask(z, k), z, 0)


def topk_row_mask(z: jax.Array, k: int) -> jax.Array:
    """Boolean keep-mask of :func:`topk_row` (threshold-based, scatter-free).

    kth-magnitude threshold from ``lax.top_k`` + leftmost tie-keeping, which
    reproduces the stable-argsort ranking (ties by index) bit-exactly while
    doing one O(d log k) selection instead of two full argsorts — ~3× faster
    on the CPU backend, where this is the PGD inner loop's hottest op. Every
    op is row-local, so under SPMD row sharding the projection still
    partitions with ZERO collectives (§Perf compress hillclimb).
    """
    if k >= z.shape[-1]:
        return jnp.ones(z.shape, dtype=bool)
    if k <= 0:
        return jnp.zeros(z.shape, dtype=bool)
    mag = jnp.abs(z)
    thr = jax.lax.top_k(mag, k)[0][..., -1:]       # kth largest per row
    gt = mag > thr
    need = k - gt.sum(axis=-1, keepdims=True)      # ties still to keep
    eq = mag == thr
    keep_eq = eq & (jnp.cumsum(eq, axis=-1) <= need)
    return gt | keep_eq


def topk_matrix(z: jax.Array, k_total: int) -> jax.Array:
    """Whole-matrix top-k (the unconstrained C_sparse variant of Eq. (1))."""
    flat = z.reshape(-1)
    out = topk_row(flat[None, :], k_total)[0]
    return out.reshape(z.shape)


def prune_n_m(z: jax.Array, n: int = 2, m: int = 4) -> jax.Array:
    """N:M structured sparsity (e.g. NVIDIA 2:4): keep n largest-|.| of every
    m consecutive entries along d_in. Paper §5 names this as future work; we
    ship it as a first-class projection."""
    d_out, d_in = z.shape
    assert d_in % m == 0, f"d_in={d_in} not divisible by m={m}"
    g = z.reshape(d_out, d_in // m, m)
    _, idx = jax.lax.top_k(jnp.abs(g), n)                # (d_out, d_in/m, n)
    mask = jax.nn.one_hot(idx, m, dtype=bool).any(axis=-2)
    return (g * mask).reshape(d_out, d_in)


def ramp_ratio(t: jax.Array, target: float, ramp_iters: int) -> jax.Array:
    """Linear pruning-ratio schedule used by the joint recipe (§4.3):
    ratio(t) = target * min(1, (t+1)/ramp_iters)."""
    frac = jnp.minimum(1.0, (t.astype(jnp.float32) + 1.0) / float(ramp_iters))
    return target * frac


def topk_row_dynamic(z: jax.Array, keep_ratio: jax.Array) -> jax.Array:
    """Row top-k where the *ratio* is a traced scalar (for the ramp schedule).

    Exact-k with leftmost tie-keeping (matches the static
    :func:`topk_row_mask` ranking). ``k`` is traced, so the threshold is
    gathered from ONE descending sort per row instead of the old
    double-argsort rank construction — row-local, scatter-free.
    """
    d_in = z.shape[-1]
    mag = jnp.abs(z)
    k = jnp.round(keep_ratio * d_in).astype(jnp.int32)
    srt = jnp.sort(mag, axis=-1)[..., ::-1]        # descending magnitudes
    idx = jnp.clip(k - 1, 0, d_in - 1)
    thr = jnp.take_along_axis(
        srt, jnp.broadcast_to(idx, srt.shape[:-1])[..., None], axis=-1)
    gt = mag > thr
    need = k - gt.sum(axis=-1, keepdims=True)
    eq = mag == thr
    keep = gt | (eq & (jnp.cumsum(eq, axis=-1) <= need))
    return jnp.where(jnp.logical_and(keep, k > 0), z, 0)


# ---------------------------------------------------------------------------
# Quantization projection (Proj_{C_INTb}) — group-wise asymmetric min/max,
# the AWQ/GPTQ convention with group_size=128.
# ---------------------------------------------------------------------------

class QuantParams(NamedTuple):
    """Integer codes + affine dequant parameters for one weight matrix."""
    q: jax.Array        # (d_out, n_groups, group) int8 codes in [0, 2^b-1]
    scale: jax.Array    # (d_out, n_groups, 1) f32
    zero: jax.Array     # (d_out, n_groups, 1) f32 (integer-valued zero point)


def _group(z: jax.Array, group_size: int) -> jax.Array:
    d_out, d_in = z.shape
    assert d_in % group_size == 0, (d_in, group_size)
    return z.reshape(d_out, d_in // group_size, group_size)


def quant_params(z: jax.Array, bits: int, group_size: int = 128) -> QuantParams:
    """Min/max asymmetric quantizer per (row, group)."""
    g = _group(z, group_size).astype(jnp.float32)
    gmax = g.max(axis=-1, keepdims=True)
    gmin = g.min(axis=-1, keepdims=True)
    qmax = float(2 ** bits - 1)
    scale = jnp.maximum((gmax - gmin) / qmax, 1e-8)
    zero = jnp.clip(jnp.round(-gmin / scale), 0.0, qmax)
    q = jnp.clip(jnp.round(g / scale) + zero, 0.0, qmax).astype(jnp.int8 if bits <= 7 else jnp.int32)
    return QuantParams(q=q, scale=scale, zero=zero)


def dequant(qp: QuantParams, dtype=jnp.float32) -> jax.Array:
    g = (qp.q.astype(jnp.float32) - qp.zero) * qp.scale
    d_out, n_groups, group = g.shape
    return g.reshape(d_out, n_groups * group).astype(dtype)


def quant_project(z: jax.Array, bits: int, group_size: int = 128) -> jax.Array:
    """Proj onto the INT-b group-quantizable set: quantize-dequantize."""
    return dequant(quant_params(z, bits, group_size), dtype=z.dtype)


def joint_project(z: jax.Array, keep_ratio: jax.Array, bits: int,
                  group_size: int = 128) -> jax.Array:
    """Joint prune+quant projection of §4.3: Proj_INTb(Proj_row(Z)).

    Prune first to get the support, quantize the pruned matrix, then re-apply
    the mask (quantizing can move pruned zeros off zero because the group
    zero-point is not exactly representable)."""
    pruned = topk_row_dynamic(z, keep_ratio)
    mask = pruned != 0
    return quant_project(pruned, bits, group_size) * mask


__all__ = [
    "QuantParams", "topk_row", "topk_row_mask", "topk_matrix", "prune_n_m",
    "ramp_ratio", "topk_row_dynamic", "quant_params", "dequant",
    "quant_project", "joint_project",
]
