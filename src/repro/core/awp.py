"""AWP — Algorithm 1: Activation-aware weight compression via PGD/IHT.

Paper orientation throughout: ``w`` is ``(d_out, d_in)``, the calibration
auto-correlation ``c = (1/n) X Xᵀ`` is ``(d_in, d_in)``, and one PGD step is

    z = theta + eta * (w - theta) @ c          # gradient step, no C^1/2
    theta = Proj_C(z)                          # hard threshold / quantize

Three recipes reproduce the paper's §4 settings exactly:

* :func:`prune`     — η = 2/‖C‖_F, ≤200 iters, stop ‖∇f‖_F/‖W‖_F < 1e-4,
                      Θ⁰ = Wanda solution (§4.1).
* :func:`quantize`  — η = 1.5/‖C‖_F, 10 iters, Θ⁰ = RTN (§4.2).
* :func:`joint`     — η = 1.5/‖C‖_F, 100 iters: ratio ramps 0→p over iters
                      0–24, prune-only through iter 49, Proj_INTb∘Proj_row for
                      iters 50–99, final mask re-applied after quant (§4.3).

Every recipe is a pure jit-able function of (w, c); rows are independent
(Eq. 4), which the distributed driver exploits by sharding d_out across the
entire mesh with c replicated — zero collectives in the loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import projections as proj
from repro.kernels import awp_pgd as _pgd_kernel


class AWPResult(NamedTuple):
    theta: jax.Array          # compressed weight, paper orientation
    iters: jax.Array          # iterations actually run (scalar int32)
    grad_norm: jax.Array      # final ‖∇f‖_F / ‖W‖_F
    loss_trace: Optional[jax.Array]  # per-iter normalized loss (fixed-iter mode)


@dataclasses.dataclass(frozen=True)
class PGDConfig:
    """Generic PGD loop settings (the recipes below fill these in)."""
    max_iters: int = 200
    tol: float = 1e-4                 # on ‖∇f‖_F / ‖W‖_F
    eta_scale: float = 2.0            # η = eta_scale / ‖C‖_F
    trace_loss: bool = False          # record Fig.-1 curve (forces fixed iters)
    # Fused residual+epilogue gradient step (kernels/awp_pgd.py): Pallas on
    # TPU, the jnp path everywhere else. ``interpret`` forces the kernel in
    # Pallas interpret mode on any backend (equivalence testing only).
    use_pallas: bool = False
    interpret: bool = False

    def fused_step(self) -> bool:
        return self.use_pallas and (self.interpret
                                    or jax.default_backend() == "tpu")


def _eta(c: jax.Array, eta_scale: float) -> jax.Array:
    """η = eta_scale / ‖C‖_F; per-item (B,) for a batched (B, d, d) stack."""
    return eta_scale / jnp.maximum(
        jnp.linalg.norm(c, axis=(-2, -1)), 1e-12)


def _loss(w, theta, c):
    """Normalized activation-aware loss  ‖(W−Θ)C^½‖_F / ‖W‖_F  (Fig. 1).

    tr(E C Eᵀ) = ‖E C^½‖_F², so no matrix square root is needed here either.
    """
    e = (w - theta).astype(jnp.float32)
    val = jnp.einsum("ij,jk,ik->", e, c.astype(jnp.float32), e)
    return jnp.sqrt(jnp.maximum(val, 0.0)) / jnp.maximum(jnp.linalg.norm(w), 1e-12)


def _loss_batched(w_b, theta_b, c_b):
    """Per-item normalized activation-aware loss over a (B, d_out, d_in) stack."""
    e = (w_b - theta_b).astype(jnp.float32)
    val = jnp.einsum("bij,bjk,bik->b", e, c_b.astype(jnp.float32), e)
    w_norm = jnp.maximum(jnp.linalg.norm(w_b, axis=(-2, -1)), 1e-12)
    return jnp.sqrt(jnp.maximum(val, 0.0)) / w_norm


def _make_step(w, c, eta, w_norm, project, cfg: PGDConfig):
    """One gradient step + projection, 2-D or batched.

    The gradient step is the O(d_out·d_in²) hot spot: on the fused path it is
    one Pallas program (subtract folded into the LHS load, scale+add epilogue
    in the final K-step) that also reduces the stopping-rule residual norm
    from its f32 accumulator; on the jnp path it is the explicit three-op
    form.
    """
    batched = w.ndim == 3
    axes = (-2, -1) if batched else None
    scale = eta[:, None, None] if batched else eta

    if cfg.fused_step():
        interp = cfg.interpret or jax.default_backend() != "tpu"

        def step(theta, t):
            # the kernel reduces ‖R‖ from its f32 accumulator — recovering
            # it as ‖Z−Θ‖/η would cancel catastrophically near convergence
            z, resid_norm = _pgd_kernel.awp_pgd_step(
                w, theta, c, eta, interpret=interp, with_resid_norm=True)
            return project(z, t), 2.0 * resid_norm / w_norm
        return step

    def step(theta, t):
        resid = (w - theta) @ c                   # ∝ −∇f/2;  O(d_out·d_in²)
        z = theta + scale * resid
        gnorm = 2.0 * jnp.linalg.norm(resid, axis=axes) / w_norm
        return project(z, t), gnorm
    return step


def pgd(w: jax.Array, c: jax.Array, project: Callable[[jax.Array, jax.Array], jax.Array],
        theta0: jax.Array, cfg: PGDConfig) -> AWPResult:
    """Run Algorithm 1 with projection ``project(z, t) -> theta``.

    ``project`` receives the iteration counter so schedules (joint recipe) can
    vary the constraint set over time. Uses a while_loop with the paper's
    gradient-norm stop unless cfg.trace_loss, which switches to a fixed-length
    scan that records the loss trajectory.
    """
    w = w.astype(jnp.float32)
    c = c.astype(jnp.float32)
    eta = _eta(c, cfg.eta_scale)
    w_norm = jnp.maximum(jnp.linalg.norm(w), 1e-12)
    step = _make_step(w, c, eta, w_norm, project, cfg)

    if cfg.trace_loss:
        def scan_body(theta, t):
            theta_next, gnorm = step(theta, t)
            return theta_next, (_loss(w, theta_next, c), gnorm)
        theta, (trace, gnorms) = jax.lax.scan(
            scan_body, theta0.astype(jnp.float32), jnp.arange(cfg.max_iters))
        return AWPResult(theta=theta, iters=jnp.int32(cfg.max_iters),
                         grad_norm=gnorms[-1], loss_trace=trace)

    def cond(carry):
        _, t, gnorm = carry
        return jnp.logical_and(t < cfg.max_iters, gnorm >= cfg.tol)

    def body(carry):
        theta, t, _ = carry
        theta_next, gnorm = step(theta, t)
        return theta_next, t + 1, gnorm

    theta, iters, gnorm = jax.lax.while_loop(
        cond, body, (theta0.astype(jnp.float32), jnp.int32(0), jnp.float32(jnp.inf)))
    return AWPResult(theta=theta, iters=iters, grad_norm=gnorm, loss_trace=None)


def pgd_batched(w_b: jax.Array, c_b: jax.Array,
                project: Callable[[jax.Array, jax.Array], jax.Array],
                theta0_b: jax.Array, cfg: PGDConfig) -> AWPResult:
    """Algorithm 1 over a stack of B independent problems as ONE program.

    ``w_b``: (B, d_out, d_in); ``c_b``: (B, d_in, d_in); ``project`` must act
    item-wise on the (B, d_out, d_in) iterate (vmap 2-D projections). One
    while_loop runs over the max-iter envelope of the whole stack with
    per-item convergence masking: a converged item's θ and gradient norm are
    frozen while the rest keep iterating, so per-item results match the
    sequential :func:`pgd` exactly (same steps applied, same stop rule).
    Returns an AWPResult whose fields carry a leading batch dim
    (``loss_trace``: (B, max_iters) in trace mode).
    """
    w = w_b.astype(jnp.float32)
    c = c_b.astype(jnp.float32)
    eta = _eta(c, cfg.eta_scale)                             # (B,)
    w_norm = jnp.maximum(jnp.linalg.norm(w, axis=(-2, -1)), 1e-12)
    step = _make_step(w, c, eta, w_norm, project, cfg)
    b = w.shape[0]

    if cfg.trace_loss:
        def scan_body(theta, t):
            theta_next, gnorm = step(theta, t)
            return theta_next, (_loss_batched(w, theta_next, c), gnorm)
        theta, (trace, gnorms) = jax.lax.scan(
            scan_body, theta0_b.astype(jnp.float32), jnp.arange(cfg.max_iters))
        return AWPResult(theta=theta,
                         iters=jnp.full((b,), cfg.max_iters, jnp.int32),
                         grad_norm=gnorms[-1], loss_trace=trace.T)

    def cond(carry):
        _, t, gnorm, _ = carry
        return jnp.logical_and(t < cfg.max_iters, jnp.any(gnorm >= cfg.tol))

    def body(carry):
        theta, t, gnorm, iters = carry
        active = gnorm >= cfg.tol                            # (B,)
        theta_next, gnorm_next = step(theta, t)
        theta = jnp.where(active[:, None, None], theta_next, theta)
        gnorm = jnp.where(active, gnorm_next, gnorm)
        return theta, t + 1, gnorm, iters + active.astype(jnp.int32)

    theta, _, gnorm, iters = jax.lax.while_loop(
        cond, body, (theta0_b.astype(jnp.float32), jnp.int32(0),
                     jnp.full((b,), jnp.inf, jnp.float32),
                     jnp.zeros((b,), jnp.int32)))
    return AWPResult(theta=theta, iters=iters, grad_norm=gnorm, loss_trace=None)


# ---------------------------------------------------------------------------
# Paper recipes
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "max_iters", "trace_loss",
                                             "nm", "use_pallas"))
def prune(w: jax.Array, c: jax.Array, k: int, *, theta0: Optional[jax.Array] = None,
          max_iters: int = 200, trace_loss: bool = False,
          nm: Optional[tuple] = None, use_pallas: bool = False) -> AWPResult:
    """§4.1 pruning recipe. ``k`` = kept entries per row = (1-p)·d_in.

    theta0 defaults to the Wanda solution (paper's init); pass explicitly to
    ablate. ``nm=(2,4)`` switches the constraint to N:M structured sparsity.
    ``use_pallas`` routes the gradient step through the fused kernel on TPU.
    """
    if theta0 is None:
        from repro.core.baselines import wanda   # local import: avoid cycle
        theta0 = wanda.prune_weight(w, c, k)
    if nm is None:
        project = lambda z, t: proj.topk_row(z, k)
    else:
        project = lambda z, t: proj.prune_n_m(z, *nm)
    cfg = PGDConfig(max_iters=max_iters, tol=1e-4, eta_scale=2.0,
                    trace_loss=trace_loss, use_pallas=use_pallas)
    return pgd(w, c, project, theta0, cfg)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "max_iters",
                                             "trace_loss", "use_pallas"))
def quantize(w: jax.Array, c: jax.Array, bits: int, *, group_size: int = 128,
             theta0: Optional[jax.Array] = None, max_iters: int = 10,
             trace_loss: bool = False, use_pallas: bool = False) -> AWPResult:
    """§4.2 quantization recipe (INT{2,3,4,8}, group-wise, RTN init)."""
    if theta0 is None:
        theta0 = proj.quant_project(w.astype(jnp.float32), bits, group_size)
    project = lambda z, t: proj.quant_project(z, bits, group_size)
    cfg = PGDConfig(max_iters=max_iters, tol=0.0,   # paper runs all 10 iters
                    eta_scale=1.5, trace_loss=trace_loss,
                    use_pallas=use_pallas)
    res = pgd(w, c, project, theta0, cfg)
    # Guard (beyond-paper robustness): the min/max group grid moves with the
    # iterate, so the quant projection set drifts and the loss is not
    # guaranteed monotone — keep the better of {init, final}.
    better = _loss(w, res.theta, c) <= _loss(w, theta0, c)
    theta = jnp.where(better, res.theta, theta0.astype(jnp.float32))
    return res._replace(theta=theta)


@functools.partial(jax.jit, static_argnames=(
    "k", "bits", "group_size", "ramp_iters", "prune_only_iters", "total_iters",
    "trace_loss", "use_pallas"))
def joint(w: jax.Array, c: jax.Array, k: int, bits: int = 4, *,
          group_size: int = 128, ramp_iters: int = 25, prune_only_iters: int = 50,
          total_iters: int = 100, trace_loss: bool = False,
          use_pallas: bool = False) -> AWPResult:
    """§4.3 joint prune+quant recipe.

    Schedule: iters [0, ramp) ramp the pruning ratio linearly to target;
    [ramp, prune_only) pruning-only at target; [prune_only, total) joint
    Proj_INTb(Proj_row(.)). After the loop the final sparsity mask is applied
    on top of the quantized weight (paper: "the corresponding sparsity mask is
    applied to ensure that the final weight is both sparsified and quantized").
    """
    d_in = w.shape[-1]
    target_ratio = 1.0 - k / d_in                      # pruning ratio p
    keep_target = k / d_in

    def project(z, t):
        ratio_t = proj.ramp_ratio(t, target_ratio, ramp_iters)  # pruned frac
        keep_t = 1.0 - ratio_t
        pruned = proj.topk_row_dynamic(z, keep_t)
        quantized = proj.quant_project(pruned, bits, group_size) * (pruned != 0)
        return jnp.where(t < prune_only_iters, pruned, quantized)

    theta0 = jnp.asarray(w, jnp.float32)               # ramp starts from W
    # tol=0 already makes the while_loop run exactly total_iters, so the
    # per-iter loss einsum is only paid when the trace is requested
    cfg = PGDConfig(max_iters=total_iters, tol=0.0, eta_scale=1.5,
                    trace_loss=trace_loss, use_pallas=use_pallas)
    res = pgd(w, c, project, theta0, cfg)
    # Final projection: exact-k mask from the last iterate, quantize, re-mask.
    mask = proj.topk_row_mask(res.theta, k)
    theta = proj.quant_project(res.theta * mask, bits, group_size) * mask
    return res._replace(theta=theta)


def activation_loss(w: jax.Array, theta: jax.Array, c: jax.Array) -> jax.Array:
    """Public normalized activation-aware loss (Fig. 1 metric)."""
    return _loss(jnp.asarray(w, jnp.float32), jnp.asarray(theta, jnp.float32),
                 jnp.asarray(c, jnp.float32))


def activation_loss_batched(w_b: jax.Array, theta_b: jax.Array,
                            c_b: jax.Array) -> jax.Array:
    """Per-item Fig.-1 loss over a (B, d_out, d_in) stack — one reduction."""
    return _loss_batched(jnp.asarray(w_b, jnp.float32),
                         jnp.asarray(theta_b, jnp.float32),
                         jnp.asarray(c_b, jnp.float32))


# ---------------------------------------------------------------------------
# Beyond-paper extension: AWP-S — PGD in an AWQ-style scaled space.
#
# Motivation (EXPERIMENTS.md §Perf "algorithmic"): the min/max group grid is
# blind to per-input-channel activation scales, so in unscaled space most PGD
# updates are smaller than half a quantization bin and the projection snaps
# them back (confirmed experimentally: 10-iter AWP-quant ≈ RTN on synthetic
# lognormal-channel data). Folding an AWQ-style per-channel scale s into the
# problem — W' = W·diag(s), C' = diag(1/s)·C·diag(1/s) — leaves the objective
# identical (tr(E'C'E'ᵀ) = tr(ECEᵀ)) but equalizes bin sizes relative to
# activation importance, so IHT steps actually flip codes. α is grid-searched
# like AWQ, with the PGD refinement inside the search.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("bits", "group_size", "max_iters",
                                             "n_alphas"))
def _quantize_scaled_search(w: jax.Array, c: jax.Array, act_mean_abs: jax.Array,
                            bits: int, group_size: int, max_iters: int,
                            n_alphas: int):
    """α-grid search core: (best theta, its loss, winning scale s)."""
    from repro.core import projections as proj_mod
    w = w.astype(jnp.float32)
    c = c.astype(jnp.float32)
    a = jnp.maximum(act_mean_abs.astype(jnp.float32), 1e-8)
    alphas = jnp.linspace(0.0, 1.0, n_alphas)

    def run_alpha(alpha):
        s = a ** alpha
        s = s / jnp.sqrt(jnp.maximum(s.max() * s.min(), 1e-12))
        s = jnp.clip(s, 1e-4, 1e4)
        wp = w * s[None, :]
        cp = c / s[None, :] / s[:, None]
        theta0 = proj_mod.quant_project(wp, bits, group_size)
        project = lambda z, t: proj_mod.quant_project(z, bits, group_size)
        res = pgd(wp, cp, project, theta0,
                  PGDConfig(max_iters=max_iters, tol=0.0, eta_scale=1.5))
        theta = res.theta / s[None, :]
        return theta, _loss(w, theta, c), s

    # lax.map keeps peak memory at one candidate at a time.
    thetas, losses, scales = jax.lax.map(run_alpha, alphas)
    best = jnp.argmin(losses)
    return thetas[best], losses[best], scales[best]


def quantize_scaled(w: jax.Array, c: jax.Array, act_mean_abs: jax.Array,
                    bits: int, *, group_size: int = 128, max_iters: int = 10,
                    n_alphas: int = 21) -> AWPResult:
    """AWP-S: α-grid scaled-space AWP quantization (beyond-paper)."""
    theta, loss, _ = _quantize_scaled_search(w, c, act_mean_abs, bits,
                                             group_size, max_iters, n_alphas)
    return AWPResult(theta=theta, iters=jnp.int32(max_iters),
                     grad_norm=loss, loss_trace=None)


# ---------------------------------------------------------------------------
# Registry adapters — the paper recipes behind the uniform
# compress(w, stats, spec) -> CompressResult signature.
# ---------------------------------------------------------------------------

from repro.core import calibration as _calib, registry as _registry  # noqa: E402
from repro.core.specs import JointSpec as _JointSpec  # noqa: E402
from repro.core.specs import PruneSpec as _PruneSpec  # noqa: E402
from repro.core.specs import QuantSpec as _QuantSpec  # noqa: E402
from repro.quant import QTensor as _QTensor  # noqa: E402


# Adapters run the fused gradient step (use_pallas=True: Pallas on TPU,
# the identical jnp formulation elsewhere) so both driver engines execute
# the same math on every backend. They keep every metric as a DEVICE scalar (no int()/float() host syncs
# in the per-layer path) and thread the covariance they built through
# ``aux["covariance"]`` so the driver reuses it for the loss instead of
# paying the O(d_in²·n) reduction a second time; the driver pops it before
# the report is stored (a pinned (d_in, d_in) per layer would not scale).

def _prune_result(res: AWPResult, c) -> "_registry.CompressResult":
    return _registry.CompressResult(
        theta=res.theta, mask=res.theta != 0, iters=res.iters,
        aux={"grad_norm": res.grad_norm, "covariance": c})


@_registry.register("awp_prune", spec_cls=_PruneSpec)
def _awp_prune(w, stats, spec):
    c = _calib.covariance(stats, damp=spec.damp)
    return _prune_result(prune(w, c, spec.k_for(w.shape[1]),
                               use_pallas=True), c)


@_registry.register("awp_prune_nm", spec_cls=_PruneSpec)
def _awp_prune_nm(w, stats, spec):
    c = _calib.covariance(stats, damp=spec.damp)
    return _prune_result(prune(w, c, spec.k_for(w.shape[1]),
                               nm=spec.nm or (2, 4), use_pallas=True), c)


@_registry.register("awp_quant", spec_cls=_QuantSpec)
def _awp_quant(w, stats, spec):
    c = _calib.covariance(stats, damp=spec.damp)
    g = spec.group_for(w.shape[1])
    res = quantize(w, c, spec.bits, group_size=g, use_pallas=True)
    # res.theta is on the group grid already, so packing is a near-exact
    # regrid; the codes become the source of truth (theta = dequant(codes)).
    qt = _QTensor.from_dense(res.theta, spec.bits, g)
    return _registry.CompressResult(theta=qt.dequant(), qtensor=qt,
                                    iters=res.iters,
                                    aux={"grad_norm": res.grad_norm,
                                         "covariance": c})


@_registry.register("awp_quant_scaled", spec_cls=_QuantSpec)
def _awp_quant_scaled(w, stats, spec):
    c = _calib.covariance(stats, damp=spec.damp)
    am = _calib.act_mean_abs(stats)
    g = spec.group_for(w.shape[1])
    theta, loss, s = _quantize_scaled_search(w, c, am, spec.bits, g, 10, 21)
    # theta·diag(s) is on the group grid — pack in scaled space (AWQ-style).
    qt = _QTensor.from_dense(theta, spec.bits, g, col_scale=s)
    return _registry.CompressResult(theta=qt.dequant(), qtensor=qt, iters=10,
                                    aux={"col_scaled": True, "covariance": c})


@_registry.register("awp_joint", spec_cls=_JointSpec)
def _awp_joint(w, stats, spec):
    c = _calib.covariance(stats, damp=spec.damp)
    g = spec.group_for(w.shape[1])
    res = joint(w, c, spec.k_for(w.shape[1]), spec.bits, group_size=g,
                use_pallas=True)
    mask = res.theta != 0
    # Zeros land exactly on the zero-point code, so the packed artifact
    # preserves the sparsity pattern bit-exactly.
    qt = _QTensor.from_dense(res.theta, spec.bits, g)
    theta = qt.dequant() * mask
    return _registry.CompressResult(theta=theta, mask=mask, qtensor=qt,
                                    iters=res.iters, aux={"covariance": c})


__all__ = ["AWPResult", "PGDConfig", "pgd", "pgd_batched", "prune",
           "quantize", "joint", "quantize_scaled", "activation_loss",
           "activation_loss_batched"]
