"""Typed compression specs and per-layer policies.

Replaces the flat ``CompressionConfig`` with a small hierarchy:

* :class:`PruneSpec` / :class:`QuantSpec` / :class:`JointSpec` — what to do
  to one weight (method name + its hyper-parameters);
* :class:`Policy` — which spec applies to which layer, by fnmatch pattern
  over the layer's qualified name (``blocks.3.attn.wq``), first match wins,
  with an optional default. A rule mapping to ``None`` skips the layer
  (stays dense) — the first-class replacement for the old substring
  ``skip`` tuple.

Specs are frozen dataclasses so they can key jit caches and serialize to
JSON (checkpoint manifests record the policy that produced them).
"""
from __future__ import annotations

import dataclasses
import fnmatch
import math
from typing import Dict, Iterable, List, Optional, Tuple, Union


def effective_group(d_in: int, group_size: int) -> int:
    """Largest divisor of ``d_in`` that is ≤ ``group_size``.

    Direct divisor enumeration in O(√d_in) (the old linear descent was
    O(d_in) for prime fan-ins). Production dims are multiples of 128, but
    tiny/test models have odd and even-prime d_in.
    """
    g = min(group_size, d_in)
    if g <= 1 or d_in % g == 0:
        return max(g, 1)
    best = 1
    for i in range(1, math.isqrt(d_in) + 1):
        if d_in % i:
            continue
        if i <= g and i > best:
            best = i
        j = d_in // i
        if j <= g and j > best:
            best = j
    return best


@dataclasses.dataclass(frozen=True)
class CompressSpec:
    """Base spec: a registered method name plus shared knobs."""
    method: str = ""
    damp: float = 0.01           # covariance damping (MoE low-token guard)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["kind"] = type(self).__name__
        return d


@dataclasses.dataclass(frozen=True)
class PruneSpec(CompressSpec):
    """Sparsify: ``ratio`` = fraction zeroed; ``nm`` = N:M structured."""
    method: str = "awp_prune"
    ratio: float = 0.5
    nm: Optional[Tuple[int, int]] = None

    def k_for(self, d_in: int) -> int:
        """Kept entries per row, k = (1-ratio)·d_in (≥ 1)."""
        return max(1, int(round((1.0 - self.ratio) * d_in)))


@dataclasses.dataclass(frozen=True)
class QuantSpec(CompressSpec):
    """Quantize to INT-``bits`` with per-(row, group) affine params."""
    method: str = "awp_quant"
    bits: int = 4
    group_size: int = 128

    def group_for(self, d_in: int) -> int:
        return effective_group(d_in, self.group_size)


@dataclasses.dataclass(frozen=True)
class JointSpec(CompressSpec):
    """Prune AND quantize (native joint recipe or sequential pipelines)."""
    method: str = "awp_joint"
    ratio: float = 0.5
    nm: Optional[Tuple[int, int]] = None
    bits: int = 4
    group_size: int = 128

    k_for = PruneSpec.k_for
    group_for = QuantSpec.group_for


_SPEC_KINDS = {c.__name__: c for c in
               (CompressSpec, PruneSpec, QuantSpec, JointSpec)}


def spec_from_dict(d: dict) -> CompressSpec:
    d = dict(d)
    cls = _SPEC_KINDS[d.pop("kind", "CompressSpec")]
    if d.get("nm") is not None:
        d["nm"] = tuple(d["nm"])
    return cls(**d)


# ---------------------------------------------------------------------------
# Policy: layer-name patterns → specs
# ---------------------------------------------------------------------------

RuleValue = Optional[CompressSpec]
RulesLike = Union[Dict[str, RuleValue], Iterable[Tuple[str, RuleValue]]]


@dataclasses.dataclass(frozen=True)
class Rule:
    pattern: str                 # fnmatch pattern over the qualified name
    spec: RuleValue              # None = leave this layer dense
    alias_only: bool = False     # match ONLY the short-name aliases (legacy
                                 # substring-skip semantics: "*o*" must not
                                 # hit the "o" in "blocks.0...")


class Policy:
    """Ordered pattern → spec map with first-match precedence.

    >>> Policy({"blocks.0.*": None,            # skip block 0
    ...         "*.attn.*": QuantSpec(bits=8),
    ...         "*.mlp.*": QuantSpec(bits=4)},
    ...        default=PruneSpec(ratio=0.5))

    ``spec_for(name, *aliases)`` returns the spec of the first rule whose
    pattern matches the qualified name (or any alias, e.g. the short
    per-block name), falling back to ``default``. ``None`` means "leave
    dense".
    """

    def __init__(self, rules: RulesLike = (), *,
                 default: RuleValue = None):
        if isinstance(rules, dict):
            rules = rules.items()
        self.rules: List[Rule] = [r if isinstance(r, Rule) else Rule(*r)
                                  for r in rules]
        self.default = default

    def spec_for(self, name: str, *aliases: str) -> RuleValue:
        for rule in self.rules:
            names = aliases if rule.alias_only else (name,) + aliases
            if any(fnmatch.fnmatchcase(n, rule.pattern) for n in names):
                return rule.spec
        return self.default

    def methods(self) -> Tuple[str, ...]:
        """Distinct method names this policy can dispatch to."""
        specs = [r.spec for r in self.rules] + [self.default]
        return tuple(dict.fromkeys(s.method for s in specs if s is not None))

    def to_dict(self) -> dict:
        return {"rules": [[r.pattern,
                           None if r.spec is None else r.spec.to_dict()]
                          + ([True] if r.alias_only else [])
                          for r in self.rules],
                "default": (None if self.default is None
                            else self.default.to_dict())}

    @staticmethod
    def from_dict(d: dict) -> "Policy":
        rules = [Rule(r[0], None if r[1] is None else spec_from_dict(r[1]),
                      *r[2:])
                 for r in d.get("rules", ())]
        default = d.get("default")
        return Policy(rules, default=None if default is None
                      else spec_from_dict(default))

    def __repr__(self):
        rs = ", ".join(f"{r.pattern!r}→{getattr(r.spec, 'method', None)}"
                       for r in self.rules)
        return (f"Policy([{rs}], default="
                f"{getattr(self.default, 'method', None)})")


def qualified_name(path, layer: Optional[int]) -> str:
    """Dotted layer name for policy matching: ("blocks","attn","wq") at
    block 3 → "blocks.3.attn.wq"; expert paths keep their trailing index
    ("blocks","moe","wu",7) → "blocks.2.moe.wu.7"."""
    parts = [str(p) for p in path]
    if parts and parts[0] == "blocks" and layer is not None:
        parts.insert(1, str(layer))
    return ".".join(parts)


__all__ = ["CompressSpec", "PruneSpec", "QuantSpec", "JointSpec", "Policy",
           "Rule", "effective_group", "qualified_name", "spec_from_dict"]
