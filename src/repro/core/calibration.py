"""Calibration statistics for activation-aware compression.

Accumulates, per linear layer, the input auto-correlation ``C = (1/n) X Xᵀ``
(paper Alg. 1) plus the per-channel mean |x| that AWQ-style baselines need.
Streaming: batches are folded in one at a time so the full calibration set is
never materialized. Distributed: each data-parallel worker folds its local
shard and :func:`cross_replica` psums the sufficient statistics once per layer
— the only collective in the whole compression pass.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp


class CalibStats(NamedTuple):
    """Sufficient statistics for one linear layer's input activations.

    Every field may carry leading batch dims (the batched compression engine
    stacks B layers' stats into one ``CalibStats``); the derived quantities
    below (:func:`covariance`, :func:`act_mean_abs`, :func:`col_l2`) operate
    on the trailing axes and broadcast over the rest.
    """
    n: jax.Array          # () f32 — total token count folded in  (or (B,))
    c_sum: jax.Array      # (d_in, d_in) f32 — Σ xᵀx              (or (B, d, d))
    abs_sum: jax.Array    # (d_in,) f32 — Σ |x| (AWQ act scales)  (or (B, d))


def init(d_in: int) -> CalibStats:
    return CalibStats(n=jnp.zeros((), jnp.float32),
                      c_sum=jnp.zeros((d_in, d_in), jnp.float32),
                      abs_sum=jnp.zeros((d_in,), jnp.float32))


def update(stats: CalibStats, acts: jax.Array) -> CalibStats:
    """Fold a batch of activations. acts: (..., d_in) — leading dims are
    flattened into tokens (the paper's n counts tokens)."""
    a = acts.reshape(-1, acts.shape[-1]).astype(jnp.float32)
    return CalibStats(
        n=stats.n + a.shape[0],
        c_sum=stats.c_sum + a.T @ a,
        abs_sum=stats.abs_sum + jnp.abs(a).sum(axis=0),
    )


def cross_replica(stats: CalibStats, axis_name) -> CalibStats:
    """psum sufficient statistics across a data-parallel mesh axis (or tuple
    of axes). Call once after all local batches are folded."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), stats)


def covariance(stats: CalibStats, damp: float = 0.0) -> jax.Array:
    """C = (1/n) Σ xᵀx, optionally damped by ``damp·mean(diag(C))·I``.

    Damping is the standard guard (SparseGPT uses 1%) for layers whose
    calibration slice is rank-deficient — e.g. MoE experts that routed few
    tokens (DESIGN.md §5). Broadcasts over leading batch dims, so stacked
    stats yield a ``(B, d_in, d_in)`` covariance in one reduction."""
    n = jnp.maximum(stats.n, 1.0)
    c = stats.c_sum / n[..., None, None]
    if damp:
        d_in = c.shape[-1]
        tr = jnp.trace(c, axis1=-2, axis2=-1)
        c = c + (damp * tr[..., None, None] / d_in) * jnp.eye(d_in,
                                                              dtype=c.dtype)
    return c


def act_mean_abs(stats: CalibStats) -> jax.Array:
    """Per-channel mean |x| (AWQ's activation scale)."""
    return stats.abs_sum / jnp.maximum(stats.n, 1.0)[..., None]


def col_l2(stats: CalibStats) -> jax.Array:
    """Per-channel ‖X[i, :]‖₂ (Wanda's activation scale) = sqrt(n·C_ii)."""
    return jnp.sqrt(jnp.maximum(
        jnp.diagonal(stats.c_sum, axis1=-2, axis2=-1), 0.0))


def stack_stats(stats_list: Sequence[CalibStats]) -> CalibStats:
    """Stack B layers' stats into one batched CalibStats (leading dim B)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *stats_list)


def slice_stats(stats: CalibStats, i) -> CalibStats:
    """Select item ``i`` of a stacked CalibStats (device op, no host sync)."""
    return jax.tree.map(lambda x: x[i], stats)


__all__ = ["CalibStats", "init", "update", "cross_replica", "covariance",
           "act_mean_abs", "col_l2", "stack_stats", "slice_stats"]
