from repro.checkpoint.checkpoint import (CheckpointManager, latest_step,
                                         load_packed_checkpoint,
                                         restore_checkpoint, save_checkpoint,
                                         save_packed_checkpoint)

__all__ = ["CheckpointManager", "latest_step", "load_packed_checkpoint",
           "restore_checkpoint", "save_checkpoint", "save_packed_checkpoint"]
