"""Fault-tolerant checkpointing: atomic, sharded-aware, mesh-elastic.

Layout:  <dir>/step_<N>/
           manifest.json     — tree structure, shapes, dtypes, step
           arrays.npz        — one entry per leaf (keyed by flattened path)

Guarantees used by the fault-tolerance story (DESIGN.md §4):
* **atomic**: written to ``step_<N>.tmp`` then os.rename — a crash mid-save
  never corrupts the latest checkpoint;
* **elastic restore**: leaves are stored as full logical arrays, so a
  checkpoint saved under one mesh restores onto *any* mesh — pass
  ``shardings`` to lay leaves out directly on the new topology (this is the
  re-shard-on-shrink/grow primitive; tested across mesh shapes);
* **rotation**: CheckpointManager keeps the newest ``keep_n``;
* **async**: ``save_async`` hands the host copy to a worker thread so the
  train loop is not blocked (double-buffered, one in flight).
"""
from __future__ import annotations

import itertools
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}


def _write_step_dir(directory: str, step: int, tree: Any, *,
                    extra_manifest: Optional[dict] = None,
                    extra_arrays: Optional[dict] = None,
                    compress: bool = False) -> str:
    """Shared atomic writer: step_<N>.tmp → os.rename(step_<N>)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {}
    for k, v in flat.items():
        arr = np.asarray(v)
        if arr.dtype.kind == "V":        # ml_dtypes (bf16/fp8): npz can't
            arr = arr.astype(np.float32)  # round-trip; f32 widening is exact
        arrays[k] = arr
    savez = np.savez_compressed if compress else np.savez
    savez(os.path.join(tmp, "arrays.npz"), **arrays)
    if extra_arrays:
        np.savez_compressed(os.path.join(tmp, "packed.npz"), **extra_arrays)
    manifest = {"step": step,
                "keys": sorted(arrays.keys()),
                "treedef": str(jax.tree_util.tree_structure(tree))}
    manifest.update(extra_manifest or {})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomicity point
    return final


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Atomic save; returns the final path."""
    return _write_step_dir(directory, step, tree)


def restore_checkpoint(path: str, target: Any,
                       shardings: Optional[Any] = None, *,
                       _allow_packed: bool = False,
                       _skip_keys: frozenset = frozenset()) -> Any:
    """Restore into the structure of ``target``. If ``shardings`` (a pytree
    of NamedSharding matching target) is given, leaves are placed directly
    onto the (possibly different) mesh — elastic restart.

    ``_skip_keys`` (internal, used by the QTensor-leaf packed loader) names
    leaves whose stored dense arrays are NOT loaded; the target's own leaf
    is passed through as a placeholder for the caller to replace."""
    if not _allow_packed:
        # a packed checkpoint's dense arrays have zeroed holes where the
        # QTensor codes live — loading it densely would silently serve
        # zeroed weights
        man_path = os.path.join(path, "manifest.json")
        if os.path.exists(man_path):
            with open(man_path) as f:
                if "packed" in json.load(f):
                    raise ValueError(
                        f"{path} is a packed checkpoint — load it with "
                        f"load_packed_checkpoint (serve with --packed)")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        # skipped leaves' (zeroed-hole) entries are never decompressed —
        # peak host memory stays at packed size for QTensor-leaf loads
        data = {k: z[k] for k in z.files if k not in _skip_keys}
    paths = jax.tree_util.tree_flatten_with_path(target)[0]
    treedef = jax.tree_util.tree_structure(target)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(paths))
    out = []
    for (path_k, leaf), sh in zip(paths, shard_leaves):
        key = jax.tree_util.keystr(path_k)
        if key in _skip_keys:
            out.append(leaf)              # placeholder; caller replaces it
            continue
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = jax.numpy.asarray(arr).astype(leaf.dtype)  # jnp handles bf16
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Packed quantized checkpoints
#
# A packed checkpoint stores the param tree with every quantized layer's
# dense slice ZEROED (arrays.npz is compressed, so the holes cost ~nothing)
# plus a packed.npz holding the QTensor codes/scales (and sparsity masks,
# bit-packed). Loading rebuilds the QTensors and materializes
# ``qt.dequant()`` into the holes — serving never re-quantizes dense floats,
# and the on-disk weight bytes shrink by the 4-8× packing factor.
# ---------------------------------------------------------------------------

def _packed_key(name: str, field: str) -> str:
    return f"{name}#{field}"


def save_packed_checkpoint(directory: str, step: int, params: Any,
                           report: Any) -> str:
    """Save ``params`` with the report's QTensor artifacts stored packed.

    ``report`` is a :class:`repro.core.compress.CompressionReport` (anything
    with ``packed_layers() -> {name: LayerArtifact}`` works). Returns the
    final checkpoint path.
    """
    from repro.core.compress import resolve_path  # local: checkpoint is low-level
    packed = report.packed_layers()
    arrays: dict = {}
    meta: dict = {}
    holes: dict = {}                      # dict-key path → [stacked indices]
    for name, art in packed.items():
        qt = art.result.qtensor
        arrays[_packed_key(name, "packed")] = np.asarray(qt.packed)
        arrays[_packed_key(name, "scale")] = np.asarray(qt.scale)
        arrays[_packed_key(name, "zero")] = np.asarray(qt.zero)
        if qt.col_scale is not None:
            arrays[_packed_key(name, "col_scale")] = np.asarray(qt.col_scale)
        mask = art.result.mask
        if mask is not None:
            arrays[_packed_key(name, "mask")] = np.packbits(
                np.asarray(mask).astype(bool))
        meta[name] = {"bits": qt.bits, "group_size": qt.group_size,
                      "shape": list(qt.shape), "path": list(art.path),
                      "layer": art.layer,
                      "has_mask": mask is not None,
                      "has_col_scale": qt.col_scale is not None}
        dict_path, idx = resolve_path(art.path, art.layer)
        holes.setdefault(tuple(dict_path), []).append(idx)

    def zero_holes(node, prefix=()):
        # zero the dense slices so the compressed npz stores them in ~0
        # bytes; one host copy per LEAF (not per layer — stacked-block
        # leaves collect all their hole indices first)
        if prefix in holes:
            arr = np.array(node)          # host copy, written in place
            for idx in holes[prefix]:
                if idx:
                    arr[idx] = 0
                else:
                    arr[...] = 0
            return arr
        if isinstance(node, dict):
            return {k: zero_holes(v, prefix + (k,)) for k, v in node.items()}
        return node

    holed = zero_holes(params)
    extra = {"packed": meta}
    policy = getattr(report, "policy", None)
    if policy is not None:
        extra["policy"] = policy.to_dict()
    return _write_step_dir(directory, step, holed, extra_manifest=extra,
                           extra_arrays=arrays, compress=True)


def _leaf_at(tree: Any, dict_path) -> Any:
    node = tree
    for k in dict_path:
        node = node[k]
    return node


def _set_leaf(tree: Any, dict_path, value: Any) -> Any:
    """Functional replacement of one dict-path leaf (any leaf type)."""
    out = dict(tree)
    key = dict_path[0]
    if len(dict_path) == 1:
        out[key] = value
    else:
        out[key] = _set_leaf(tree[key], dict_path[1:], value)
    return out


def _packable_groups(packed_meta: dict, target: Any):
    """Group packed layers by param-tree leaf and split into leaves that can
    become stacked QTensors vs layers that must materialize densely.

    A leaf is QTensor-packable iff every slice of it is quantized (full
    coverage of the leading stacked dims), with uniform bits / group_size /
    col_scale presence / shape, and no sparsity mask (a masked weight is
    dequant·mask, which packed codes alone can't reproduce).
    Returns ``(packable, dense_names)`` where packable maps
    ``dict_path -> [(idx, name, meta), ...]``.
    """
    from repro.core.compress import resolve_path
    groups: dict = {}
    for name, m in packed_meta.items():
        dict_path, idx = resolve_path(tuple(m["path"]), m["layer"])
        groups.setdefault(tuple(dict_path), []).append((idx, name, m))
    packable, dense_names = {}, []
    for dict_path, entries in groups.items():
        metas = [m for _, _, m in entries]
        leaf = _leaf_at(target, dict_path)
        lead = tuple(getattr(leaf, "shape", ())[:-2])
        full = set(itertools.product(*(range(n) for n in lead)))
        uniform = (
            not any(m["has_mask"] for m in metas)
            and len({(m["bits"], m["group_size"], m["has_col_scale"],
                      tuple(m["shape"])) for m in metas}) == 1
            and all(len(idx) == len(lead) for idx, _, _ in entries)
            and {idx for idx, _, _ in entries} == full)
        if uniform:
            packable[dict_path] = entries
        else:
            dense_names.extend(name for _, name, _ in entries)
    return packable, dense_names


def load_packed_checkpoint(path: str, target: Any, *,
                           materialize: bool = False):
    """Load a packed checkpoint: ``(params, {name: QTensor}, manifest)``.

    By default (``materialize=False``) quantized slots come back as packed
    :class:`~repro.quant.QTensor` **leaves** of the params tree — stacked
    along the scanned block (and expert) dims, never expanded to dense
    floats — and the model forward pass reads them directly through
    ``repro.models.layers.linear_apply`` (fused Pallas dequant-matmul on
    TPU). A leaf only stays packed when every slice of it is uniformly
    quantized and unmasked (see serving docs); other packed layers fall
    back to dense materialization for that leaf.

    ``materialize=True`` is the legacy escape hatch: every packed layer is
    expanded with ``qt.dequant()`` (masked if a sparsity mask was stored) —
    bitwise what ``compress_model`` produced, with no re-quantization.

    Either way the per-layer ``{name: QTensor}`` dict and the manifest are
    returned alongside the params.
    """
    from repro.core.compress import set_linear
    from repro.quant import QTensor
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if "packed" not in manifest:
        raise ValueError(
            f"{path} is not a packed checkpoint (no 'packed' manifest "
            f"entry) — load it with restore_checkpoint / serve without "
            f"--packed")
    packed_meta = manifest.get("packed", {})
    if not packed_meta:
        return (restore_checkpoint(path, target, _allow_packed=True),
                {}, manifest)
    with np.load(os.path.join(path, "packed.npz")) as z:
        data = {k: z[k] for k in z.files}

    # per-layer QTensor views stay HOST-side (numpy children): the device
    # copies are the stacked leaves below — building this dict with
    # device arrays would double resident packed-weight HBM
    qtensors = {}
    for name, m in packed_meta.items():
        qtensors[name] = QTensor(
            packed=data[_packed_key(name, "packed")],
            scale=data[_packed_key(name, "scale")],
            zero=data[_packed_key(name, "zero")],
            bits=int(m["bits"]), group_size=int(m["group_size"]),
            shape=tuple(m["shape"]),
            col_scale=(data[_packed_key(name, "col_scale")]
                       if m["has_col_scale"] else None))

    packable: dict = {}
    dense_names = list(packed_meta)
    if not materialize:
        packable, dense_names = _packable_groups(packed_meta, target)
    skip = frozenset("".join(f"[{k!r}]" for k in dict_path)
                     for dict_path in packable)
    params = restore_checkpoint(path, target, _allow_packed=True,
                                _skip_keys=skip)

    # stacked QTensor leaves: codes/scales gathered host-side into one array
    # per field, leading dims = the leaf's stacked (layer[, expert]) dims —
    # no dense float is ever built for these layers
    for dict_path, entries in packable.items():
        lead = tuple(_leaf_at(target, dict_path).shape[:-2])
        m0 = entries[0][2]

        def stack(field):
            first = data[_packed_key(entries[0][1], field)]
            out = np.empty(lead + first.shape, first.dtype)
            for idx, name, _ in entries:
                out[idx] = data[_packed_key(name, field)]
            return jax.numpy.asarray(out)

        qt = QTensor(packed=stack("packed"), scale=stack("scale"),
                     zero=stack("zero"), bits=int(m0["bits"]),
                     group_size=int(m0["group_size"]),
                     shape=tuple(m0["shape"]),
                     col_scale=stack("col_scale")
                     if m0["has_col_scale"] else None)
        params = _set_leaf(params, list(dict_path), qt)

    # remaining packed layers (masked / partially-quantized leaves, or
    # everything under materialize=True): legacy dense expansion
    for name in dense_names:
        m = packed_meta[name]
        shape = tuple(m["shape"])
        w = qtensors[name].dequant()
        if m["has_mask"]:
            bits = np.unpackbits(data[_packed_key(name, "mask")],
                                 count=shape[0] * shape[1])
            w = w * jax.numpy.asarray(bits.reshape(shape).astype(np.float32))
        params = set_linear(params, tuple(m["path"]), m["layer"], w)
    return params, qtensors, manifest


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.directory = directory
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any):
        save_checkpoint(self.directory, step, tree)
        self._rotate()

    def save_async(self, step: int, tree: Any):
        """Non-blocking save: snapshot to host, write on a worker thread."""
        self.wait()                            # one in flight
        host_tree = jax.tree.map(np.asarray, tree)
        self._thread = threading.Thread(
            target=lambda: (save_checkpoint(self.directory, step, host_tree),
                            self._rotate()))
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def latest_path(self) -> Optional[str]:
        step = self.latest_step()
        if step is None:
            return None
        return os.path.join(self.directory, f"step_{step:08d}")

    def restore_latest(self, target, shardings=None):
        path = self.latest_path()
        if path is None:
            return None, None
        step = int(os.path.basename(path).split("_")[1])  # the step we load
        return restore_checkpoint(path, target, shardings), step

    def restore_latest_packed(self, target, *, materialize: bool = False):
        """(params, {name: QTensor}, manifest) from the newest packed
        checkpoint, or (None, None, None) if the directory is empty."""
        path = self.latest_path()
        if path is None:
            return None, None, None
        return load_packed_checkpoint(path, target, materialize=materialize)

    def _rotate(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))


__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "save_packed_checkpoint", "load_packed_checkpoint",
           "CheckpointManager"]
