"""Fault-tolerant checkpointing: atomic, sharded-aware, mesh-elastic.

Layout:  <dir>/step_<N>/
           manifest.json     — tree structure, shapes, dtypes, step
           arrays.npz        — one entry per leaf (keyed by flattened path)

Guarantees used by the fault-tolerance story (DESIGN.md §4):
* **atomic**: written to ``step_<N>.tmp`` then os.rename — a crash mid-save
  never corrupts the latest checkpoint;
* **elastic restore**: leaves are stored as full logical arrays, so a
  checkpoint saved under one mesh restores onto *any* mesh — pass
  ``shardings`` to lay leaves out directly on the new topology (this is the
  re-shard-on-shrink/grow primitive; tested across mesh shapes);
* **rotation**: CheckpointManager keeps the newest ``keep_n``;
* **async**: ``save_async`` hands the host copy to a worker thread so the
  train loop is not blocked (double-buffered, one in flight).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Atomic save; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {}
    for k, v in flat.items():
        arr = np.asarray(v)
        if arr.dtype.kind == "V":        # ml_dtypes (bf16/fp8): npz can't
            arr = arr.astype(np.float32)  # round-trip; f32 widening is exact
        arrays[k] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step,
                "keys": sorted(arrays.keys()),
                "treedef": str(jax.tree_util.tree_structure(tree))}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomicity point
    return final


def restore_checkpoint(path: str, target: Any,
                       shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``target``. If ``shardings`` (a pytree
    of NamedSharding matching target) is given, leaves are placed directly
    onto the (possibly different) mesh — elastic restart."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        data = {k: z[k] for k in z.files}
    paths = jax.tree_util.tree_flatten_with_path(target)[0]
    treedef = jax.tree_util.tree_structure(target)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(paths))
    out = []
    for (path_k, leaf), sh in zip(paths, shard_leaves):
        key = jax.tree_util.keystr(path_k)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = jax.numpy.asarray(arr).astype(leaf.dtype)  # jnp handles bf16
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.directory = directory
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any):
        save_checkpoint(self.directory, step, tree)
        self._rotate()

    def save_async(self, step: int, tree: Any):
        """Non-blocking save: snapshot to host, write on a worker thread."""
        self.wait()                            # one in flight
        host_tree = jax.tree.map(np.asarray, tree)
        self._thread = threading.Thread(
            target=lambda: (save_checkpoint(self.directory, step, host_tree),
                            self._rotate()))
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore_latest(self, target, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.directory, f"step_{step:08d}")
        return restore_checkpoint(path, target, shardings), step

    def _rotate(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))


__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]
