from repro.optim.optimizers import (OptimizerConfig, make_optimizer, adamw_init,
                                    adamw_update, adafactor_init,
                                    adafactor_update, lr_schedule)

__all__ = ["OptimizerConfig", "make_optimizer", "adamw_init", "adamw_update",
           "adafactor_init", "adafactor_update", "lr_schedule"]
