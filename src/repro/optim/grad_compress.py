"""Distributed-optimization tricks: gradient compression for the DP sync.

Two composable compressors, used by the shard_map DDP train-step variant
(``training.train_loop.train_step_ddp``) where the data-parallel gradient
reduction is explicit and can therefore be compressed:

* int8 stochastic-free linear quantization around the max-|g| scale —
  8× all-reduce volume reduction, unbiased up to rounding;
* top-k sparsification with **error feedback** (memory of the residual is
  added back next step) — the standard convergence-preserving trick.

Under plain pjit the reduction is implicit in the compiled collectives; the
DDP variant exists precisely to expose it (DESIGN.md §4 fault-tolerance /
distributed-optimization notes).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_psum(g: jax.Array, axis_name) -> jax.Array:
    """All-reduce-mean of int8-compressed gradients inside shard_map.

    Quantize locally, all-gather the int8 payload + scales over the DP axis,
    dequantize and average. 8× ICI volume vs f32 psum (report in §Perf)."""
    q, scale = int8_compress(g)
    qs = jax.lax.all_gather(q, axis_name)              # (ndev, ...) int8
    ss = jax.lax.all_gather(scale, axis_name)
    n = qs.shape[0]
    deq = qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * g.ndim)
    return deq.mean(axis=0)


@functools.partial(jax.jit, static_argnames=("k",))
def topk_error_feedback(g: jax.Array, err: jax.Array, k: int):
    """Keep the k largest-|.| entries of (g + err); return (sparse_g, new_err)."""
    corrected = g.astype(jnp.float32) + err
    flat = corrected.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat, dtype=bool).at[idx].set(True)
    sparse = jnp.where(mask, flat, 0.0).reshape(g.shape)
    return sparse, (corrected.reshape(g.shape) - sparse)


__all__ = ["int8_compress", "int8_decompress", "int8_psum",
           "topk_error_feedback"]
