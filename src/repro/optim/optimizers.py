"""Optimizers from scratch (no optax): AdamW and Adafactor.

AdamW      — full m/v states (small / medium models).
Adafactor  — factored second moments for ≥2D leaves (row/col RMS), O(n+m)
             state instead of O(n·m): the memory-fitting choice for the
             340B-class dry-run configs (DESIGN.md §4).
Both return (new_params, new_state); all state is a pytree mirroring params
so it checkpoints/shards with the same logical-axis rules as the params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Literal

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: Literal["adamw", "adafactor"] = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    sq = jax.tree.reduce(
        lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))), tree, 0.0)
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def _layerwise(fn, *trees):
    """Apply fn to aligned leaf tuples; for layer-stacked leaves (ndim ≥ 3)
    scan over the leading axis so the f32 temporaries are one layer's worth,
    not the whole stack (the 340B-class memory fix — EXPERIMENTS.md §Perf)."""
    def leaf(*xs):
        p = xs[-1]
        if p.ndim >= 3:
            return jax.lax.map(lambda sl: fn(*sl), xs)
        return fn(*xs)
    return jax.tree.map(leaf, *trees)


def adamw_update(grads, state, params, cfg: OptimizerConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = _layerwise(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gn}


# ---------------------------------------------------------------------------
# Adafactor (factored v for ≥2D, momentum-free)
# ---------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params):
    def leaf(p):
        if _factored(p):
            # factor over the last two dims; leading dims (layer stack) kept
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(leaf, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, cfg: OptimizerConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8     # adafactor beta2 schedule

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if _factored(p):
            vr = decay * v["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * v["vc"] + (1 - decay) * g2.mean(axis=-2)
            denom = vr.mean(axis=-1, keepdims=True)[..., None]
            vhat = (vr[..., None] * vc[..., None, :]) / jnp.maximum(denom, 1e-30)
            new_v = {"vr": vr, "vc": vc}
        else:
            vhat = decay * v["v"] + (1 - decay) * g2
            new_v = {"v": vhat}
        update = g / jnp.sqrt(vhat + cfg.eps)
        # relative step clipping (adafactor d=1.0)
        rms = jnp.sqrt(jnp.mean(update * update))
        update = update / jnp.maximum(1.0, rms)
        new_p = (p.astype(jnp.float32) - lr * update
                 - lr * cfg.weight_decay * p.astype(jnp.float32)).astype(p.dtype)
        return new_p, new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    new_p, new_v = [], []
    for g, v, p in zip(flat_g, flat_v, flat_p):
        if p.ndim >= 3:
            # scan the update over the layer stack: one layer of f32
            # temporaries at a time (340B-class memory fix, §Perf)
            np_, nv_ = jax.lax.map(lambda sl: upd(*sl), (g, v, p))
        else:
            np_, nv_ = upd(g, v, p)
        new_p.append(np_)
        new_v.append(nv_)
    return (jax.tree.unflatten(treedef, new_p),
            {"v": jax.tree.unflatten(treedef, new_v), "step": step},
            {"lr": lr, "grad_norm": gn})


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return adamw_init, lambda g, s, p: adamw_update(g, s, p, cfg)
    if cfg.name == "adafactor":
        return adafactor_init, lambda g, s, p: adafactor_update(g, s, p, cfg)
    raise ValueError(cfg.name)


__all__ = ["OptimizerConfig", "make_optimizer", "adamw_init", "adamw_update",
           "adafactor_init", "adafactor_update", "lr_schedule", "global_norm",
           "clip_by_global_norm"]
