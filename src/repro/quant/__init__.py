from repro.quant.qtensor import (QTensor, matmul_impl, pack_int4,
                                 resolved_impl, set_matmul_impl, unpack_int4)

__all__ = ["QTensor", "matmul_impl", "pack_int4", "resolved_impl",
           "set_matmul_impl", "unpack_int4"]
