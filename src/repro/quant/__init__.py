from repro.quant.qtensor import QTensor, pack_int4, unpack_int4

__all__ = ["QTensor", "pack_int4", "unpack_int4"]
