"""Packed quantized-weight storage — the serving payoff of compression.

``QTensor`` stores AWP/RTN/AWQ-quantized weights as packed integers
(int4 → two nibbles per uint8; other widths ≤ 8 bits as uint8 codes) +
per-(row, group) scale/zero, a 4-8× memory saving that decode-shape serving
reads instead of the dense weight. The fused dequant-matmul lives in
``repro.kernels.dequant_matmul`` (Pallas); ``QTensor.dequant()`` is its
reference.

AWQ-style methods quantize in a per-input-channel scaled space
(W' = W·diag(s)); the optional ``col_scale`` field stores that s so the
codes live on the scaled grid and ``dequant()`` folds it back — packing
stays exact instead of re-quantizing on an unscaled grid.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import projections as proj


def pack_int4(q: jax.Array) -> jax.Array:
    """(…, n) int codes in [0,15] → (…, n//2) uint8 (low nibble first)."""
    assert q.shape[-1] % 2 == 0
    q = q.astype(jnp.uint8)
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    lo = packed & 0xF
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


class QTensor(NamedTuple):
    """Quantized (d_out, d_in) weight, paper orientation."""
    packed: jax.Array      # (d_out, d_in//2) uint8 for bits=4;
                           # (d_out, d_in) uint8 codes for bits≤8, int32 above
    scale: jax.Array       # (d_out, n_groups) f32
    zero: jax.Array        # (d_out, n_groups) f32
    bits: int
    group_size: int
    shape: tuple           # logical (d_out, d_in)
    col_scale: Optional[jax.Array] = None   # (d_in,) f32 — AWQ-style s

    @staticmethod
    def from_codes(codes: jax.Array, scale: jax.Array, zero: jax.Array,
                   bits: int, group_size: int,
                   col_scale: Optional[jax.Array] = None) -> "QTensor":
        """Pack pre-computed integer codes (d_out, d_in) + per-(row, group)
        scale/zero — for methods (GPTQ) whose grids aren't re-derivable from
        the dequantized weight."""
        shape = tuple(codes.shape)
        if bits == 4 and shape[1] % 2 == 0:
            packed = pack_int4(codes)
        elif bits <= 8:
            # codes span [0, 2^bits-1] — int8 would wrap at bits=8; odd
            # d_in at bits=4 also lands here (nibble packing needs pairs)
            packed = codes.astype(jnp.uint8)
        else:
            packed = codes.astype(jnp.int32)
        return QTensor(packed=packed, scale=scale, zero=zero, bits=bits,
                       group_size=group_size, shape=shape,
                       col_scale=col_scale)

    @staticmethod
    def from_dense(w: jax.Array, bits: int = 4, group_size: int = 128,
                   col_scale: Optional[jax.Array] = None) -> "QTensor":
        """Quantize ``w`` (or ``w·diag(col_scale)`` when given) onto the
        per-(row, group) min/max grid and pack the codes."""
        ws = w if col_scale is None else w * col_scale[None, :]
        qp = proj.quant_params(ws, bits, group_size)
        codes = qp.q.reshape(w.shape[0], -1)           # (d_out, d_in)
        return QTensor.from_codes(codes, qp.scale[..., 0], qp.zero[..., 0],
                                  bits, group_size, col_scale=col_scale)

    def codes(self) -> jax.Array:
        """Unpacked integer codes, (d_out, d_in)."""
        if self.bits == 4 and self.packed.shape[-1] * 2 == self.shape[1]:
            return unpack_int4(self.packed)
        return self.packed

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        d_out, d_in = self.shape
        codes = self.codes().astype(jnp.float32)
        g = codes.reshape(d_out, -1, self.group_size)
        deq = (g - self.zero[..., None]) * self.scale[..., None]
        deq = deq.reshape(d_out, d_in)
        if self.col_scale is not None:
            deq = deq / self.col_scale[None, :]
        return deq.astype(dtype)

    def matmul(self, x: jax.Array) -> jax.Array:
        """x @ Wᵀ with on-the-fly dequant (reference; kernel in kernels/)."""
        return x @ self.dequant(x.dtype).T

    def kernel_matmul(self, x: jax.Array) -> jax.Array:
        """x @ Wᵀ via the fused Pallas dequant-matmul where supported.

        The kernel handles nibble-packed int4 without a per-channel scale;
        every other layout (bits≠4, odd d_in, AWQ-style ``col_scale``)
        falls back to the reference ``matmul`` — callers get correct
        results either way.
        """
        nibble_packed = (self.bits == 4
                         and self.packed.shape[-1] * 2 == self.shape[1])
        if not nibble_packed or self.col_scale is not None:
            return self.matmul(x)
        from repro.kernels import ops    # local: avoid import cycle
        return ops.dequant_matmul(x, self.packed, self.scale, self.zero,
                                  self.group_size)

    def nbytes(self) -> int:
        n = self.packed.size * self.packed.dtype.itemsize
        n += self.scale.size * 4 + self.zero.size * 4
        if self.col_scale is not None:
            n += self.col_scale.size * 4
        return n


__all__ = ["QTensor", "pack_int4", "unpack_int4"]
