"""Packed quantized-weight storage — the serving payoff of compression.

``QTensor`` stores AWP/RTN/AWQ-quantized weights as packed integers
(int4 → two nibbles per uint8) + per-(row, group) scale/zero, a 4-8× memory
saving that decode-shape serving reads instead of the dense weight. The
fused dequant-matmul lives in ``repro.kernels.dequant_matmul`` (Pallas);
``QTensor.dequant()`` is its reference.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import projections as proj


def pack_int4(q: jax.Array) -> jax.Array:
    """(…, n) int codes in [0,15] → (…, n//2) uint8 (low nibble first)."""
    assert q.shape[-1] % 2 == 0
    q = q.astype(jnp.uint8)
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    lo = packed & 0xF
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


class QTensor(NamedTuple):
    """Quantized (d_out, d_in) weight, paper orientation."""
    packed: jax.Array      # (d_out, d_in//2) uint8 for bits=4; int8 codes else
    scale: jax.Array       # (d_out, n_groups) f32
    zero: jax.Array        # (d_out, n_groups) f32
    bits: int
    group_size: int
    shape: tuple           # logical (d_out, d_in)

    @staticmethod
    def from_dense(w: jax.Array, bits: int = 4, group_size: int = 128) -> "QTensor":
        qp = proj.quant_params(w, bits, group_size)
        codes = qp.q.reshape(w.shape[0], -1)           # (d_out, d_in)
        packed = pack_int4(codes) if bits == 4 else codes.astype(jnp.int8)
        return QTensor(packed=packed, scale=qp.scale[..., 0], zero=qp.zero[..., 0],
                       bits=bits, group_size=group_size, shape=tuple(w.shape))

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        d_out, d_in = self.shape
        codes = (unpack_int4(self.packed) if self.bits == 4
                 else self.packed).astype(jnp.float32)
        g = codes.reshape(d_out, -1, self.group_size)
        deq = (g - self.zero[..., None]) * self.scale[..., None]
        return deq.reshape(d_out, d_in).astype(dtype)

    def matmul(self, x: jax.Array) -> jax.Array:
        """x @ Wᵀ with on-the-fly dequant (reference; kernel in kernels/)."""
        return x @ self.dequant(x.dtype).T

    def nbytes(self) -> int:
        n = self.packed.size * self.packed.dtype.itemsize
        n += self.scale.size * 4 + self.zero.size * 4
        return n


__all__ = ["QTensor", "pack_int4", "unpack_int4"]
