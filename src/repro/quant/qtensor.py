"""Packed quantized-weight storage — the serving payoff of compression.

``QTensor`` stores AWP/RTN/AWQ-quantized weights as packed integers
(int4 → two nibbles per uint8; other widths ≤ 8 bits as uint8 codes) +
per-(row, group) scale/zero, a 4-8× memory saving that decode-shape serving
reads instead of the dense weight. The fused dequant-matmul lives in
``repro.kernels.dequant_matmul`` (Pallas); ``QTensor.dequant()`` is its
reference.

AWQ-style methods quantize in a per-input-channel scaled space
(W' = W·diag(s)); the optional ``col_scale`` field stores that s so the
codes live on the scaled grid and ``dequant()`` folds it back — packing
stays exact instead of re-quantizing on an unscaled grid.

``QTensor`` is registered as a jax pytree node (children = the arrays,
aux = bits / group_size / logical shape), so packed weights live directly
as leaves of a model's param tree: they flow through ``jax.jit`` /
``jax.lax.scan`` / donation, stack per-block for the scanned transformer
(children grow leading dims; the aux shape stays the per-layer logical
``(d_out, d_in)``), and slice back out via ``tree.map(lambda x: x[i], …)``.
``matmul_dispatch`` picks the execution path per backend — see
:func:`matmul_impl`.
"""
from __future__ import annotations

import contextlib
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import projections as proj


# ---------------------------------------------------------------------------
# matmul implementation switch (module state, read at trace time — static
# under jit): "auto" = fused Pallas kernel on TPU, reference dequant-matmul
# everywhere else; "kernel" forces the kernel (interpret mode off-TPU — the
# test path); "reference" forces the jnp dequant.
#
# CAVEAT: the mode is baked in when a jitted function first traces and is
# NOT part of its cache key — switching afterwards does not retrace
# already-compiled functions. Set the mode (or enter the context) BEFORE
# jitting / first call, as the parity tests do.
# ---------------------------------------------------------------------------

_MATMUL_IMPL = "auto"
_IMPLS = ("auto", "kernel", "reference")


def set_matmul_impl(mode: str) -> str:
    """Set the QTensor matmul implementation; returns the previous mode."""
    global _MATMUL_IMPL
    if mode not in _IMPLS:
        raise ValueError(f"matmul impl must be one of {_IMPLS}, got {mode!r}")
    prev, _MATMUL_IMPL = _MATMUL_IMPL, mode
    return prev


@contextlib.contextmanager
def matmul_impl(mode: str):
    """Context manager scoping :func:`set_matmul_impl` (tests force
    ``"kernel"`` to exercise the Pallas path in interpret mode on CPU)."""
    prev = set_matmul_impl(mode)
    try:
        yield
    finally:
        set_matmul_impl(prev)


def resolved_impl() -> str:
    """The implementation the current mode resolves to ("kernel" or
    "reference") — shared by the weight matmuls here and the serving KV
    cache's dequant (``repro.serving.kv_cache``)."""
    if _MATMUL_IMPL != "auto":
        return _MATMUL_IMPL
    return "kernel" if jax.default_backend() == "tpu" else "reference"


_resolved_impl = resolved_impl


def pack_int4(q: jax.Array) -> jax.Array:
    """(…, n) int codes in [0,15] → (…, n//2) uint8 (low nibble first)."""
    assert q.shape[-1] % 2 == 0
    q = q.astype(jnp.uint8)
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    lo = packed & 0xF
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


class QTensor(NamedTuple):
    """Quantized (d_out, d_in) weight, paper orientation."""
    packed: jax.Array      # (d_out, d_in//2) uint8 for bits=4;
                           # (d_out, d_in) uint8 codes for bits≤8, int32 above
    scale: jax.Array       # (d_out, n_groups) f32
    zero: jax.Array        # (d_out, n_groups) f32
    bits: int
    group_size: int
    shape: tuple           # logical (d_out, d_in)
    col_scale: Optional[jax.Array] = None   # (d_in,) f32 — AWQ-style s

    @staticmethod
    def from_codes(codes: jax.Array, scale: jax.Array, zero: jax.Array,
                   bits: int, group_size: int,
                   col_scale: Optional[jax.Array] = None) -> "QTensor":
        """Pack pre-computed integer codes (d_out, d_in) + per-(row, group)
        scale/zero — for methods (GPTQ) whose grids aren't re-derivable from
        the dequantized weight."""
        shape = tuple(codes.shape)
        if bits == 4 and shape[1] % 2 == 0:
            packed = pack_int4(codes)
        elif bits <= 8:
            # codes span [0, 2^bits-1] — int8 would wrap at bits=8; odd
            # d_in at bits=4 also lands here (nibble packing needs pairs)
            packed = codes.astype(jnp.uint8)
        else:
            packed = codes.astype(jnp.int32)
        return QTensor(packed=packed, scale=scale, zero=zero, bits=bits,
                       group_size=group_size, shape=shape,
                       col_scale=col_scale)

    @staticmethod
    def from_dense(w: jax.Array, bits: int = 4, group_size: int = 128,
                   col_scale: Optional[jax.Array] = None) -> "QTensor":
        """Quantize ``w`` (or ``w·diag(col_scale)`` when given) onto the
        per-(row, group) min/max grid and pack the codes."""
        ws = w if col_scale is None else w * col_scale[None, :]
        qp = proj.quant_params(ws, bits, group_size)
        codes = qp.q.reshape(w.shape[0], -1)           # (d_out, d_in)
        return QTensor.from_codes(codes, qp.scale[..., 0], qp.zero[..., 0],
                                  bits, group_size, col_scale=col_scale)

    def codes(self) -> jax.Array:
        """Unpacked integer codes, (d_out, d_in)."""
        if self.bits == 4 and self.packed.shape[-1] * 2 == self.shape[1]:
            return unpack_int4(self.packed)
        return self.packed

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        d_out, d_in = self.shape
        codes = self.codes().astype(jnp.float32)
        g = codes.reshape(d_out, -1, self.group_size)
        deq = (g - self.zero[..., None]) * self.scale[..., None]
        deq = deq.reshape(d_out, d_in)
        if self.col_scale is not None:
            deq = deq / self.col_scale[None, :]
        return deq.astype(dtype)

    def matmul(self, x: jax.Array) -> jax.Array:
        """x @ Wᵀ with on-the-fly dequant (reference; kernel in kernels/)."""
        return x @ self.dequant(x.dtype).T

    def kernel_matmul(self, x: jax.Array) -> jax.Array:
        """x @ Wᵀ via the fused Pallas dequant-matmul where supported.

        Handles nibble-packed int4 including AWQ-style ``col_scale`` layers:
        dequant divides by s per input column, so
        ``x @ deq.T == (x / s) @ base.T`` and the kernel runs on pre-scaled
        activations. Only bits≠4 / odd d_in fall back to the reference
        ``matmul`` — callers get correct results either way.
        """
        nibble_packed = (self.bits == 4
                         and self.packed.shape[-1] * 2 == self.shape[1])
        if not nibble_packed:
            return self.matmul(x)
        if self.col_scale is not None:
            x = (x / self.col_scale).astype(x.dtype)
        from repro.kernels import ops    # local: avoid import cycle
        return ops.dequant_matmul(x, self.packed, self.scale, self.zero,
                                  self.group_size)

    def matmul_dispatch(self, x: jax.Array) -> jax.Array:
        """x @ Wᵀ on the active implementation (see :func:`matmul_impl`):
        fused Pallas kernel on TPU, reference dequant elsewhere. This is
        what ``repro.models.layers.linear_apply`` calls in the serving
        forward pass."""
        if _resolved_impl() == "reference":
            return self.matmul(x)
        return self.kernel_matmul(x)

    def nbytes(self) -> int:
        n = self.packed.size * self.packed.dtype.itemsize
        n += self.scale.size * 4 + self.zero.size * 4
        if self.col_scale is not None:
            n += self.col_scale.size * 4
        return n


# ---------------------------------------------------------------------------
# pytree registration: children = the arrays, aux = the static metadata.
# Explicit registration overrides the NamedTuple fallback, keeping
# bits/group_size/shape OUT of the leaf set — a QTensor leaf survives
# jit/scan/vmap/donation with its integer metadata static, and stacked
# per-block leaves (children with leading dims) scan like any other param.
# ---------------------------------------------------------------------------

def _qt_flatten_with_keys(qt: QTensor):
    children = ((jax.tree_util.GetAttrKey("packed"), qt.packed),
                (jax.tree_util.GetAttrKey("scale"), qt.scale),
                (jax.tree_util.GetAttrKey("zero"), qt.zero),
                (jax.tree_util.GetAttrKey("col_scale"), qt.col_scale))
    return children, (qt.bits, qt.group_size, qt.shape)


def _qt_flatten(qt: QTensor):
    return ((qt.packed, qt.scale, qt.zero, qt.col_scale),
            (qt.bits, qt.group_size, qt.shape))


def _qt_unflatten(aux, children) -> QTensor:
    bits, group_size, shape = aux
    packed, scale, zero, col_scale = children
    return QTensor(packed=packed, scale=scale, zero=zero, bits=bits,
                   group_size=group_size, shape=shape, col_scale=col_scale)


jax.tree_util.register_pytree_with_keys(QTensor, _qt_flatten_with_keys,
                                        _qt_unflatten, _qt_flatten)


__all__ = ["QTensor", "matmul_impl", "pack_int4", "resolved_impl",
           "set_matmul_impl", "unpack_int4"]
