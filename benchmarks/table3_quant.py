"""Table 3 analogue: perplexity of the INT4/INT3/INT2 quantized model for
RTN / GPTQ / AWQ / AWP / AWP-S (scaled-space, beyond-paper)."""
from benchmarks.common import trained_bench_model, ppl
from repro.core.compress import compress_model
from repro.core.specs import QuantSpec

BITS = (4, 3, 2)
METHODS = ("rtn", "gptq", "awq", "awp_quant", "awp_quant_scaled")


def run():
    model, params, calib, eval_batches = trained_bench_model()
    rows = [("dense", 16, ppl(model, params, eval_batches))]
    table = {}
    for method in METHODS:
        for bits in BITS:
            cfg = QuantSpec(method=method, bits=bits, group_size=64)
            cp, _ = compress_model(model, params, calib, cfg)
            p = ppl(model, cp, eval_batches)
            table[(method, bits)] = p
            rows.append((method, bits, p))
    checks = {
        "awp<=rtn@4": table[("awp_quant", 4)] <= table[("rtn", 4)] * 1.001,
        "awq~rtn@4(within1%)": table[("awq", 4)] <= table[("rtn", 4)] * 1.01,
        "awp_s<=awq@4": table[("awp_quant_scaled", 4)] <= table[("awq", 4)] * 1.02,
        "int2_degrades": table[("awp_quant", 2)] > table[("awp_quant", 4)],
    }
    return rows, checks


def main():
    rows, checks = run()
    print("method,bits,ppl")
    for m, b, p in rows:
        print(f"{m},{b},{p:.4f}")
    for k, v in checks.items():
        print(f"check,{k},{v}")


if __name__ == "__main__":
    main()
