"""Render the §Roofline table from the dry-run JSONs (results/dryrun/)."""
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(mesh="single"):
    d = os.path.join(RESULTS, mesh)
    if not os.path.isdir(d):
        return []
    out = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def fmt_row(c):
    r = c["roofline"]
    mem = c.get("memory", {}).get("total_per_device_bytes", 0) / 1e9
    return (f"{c['arch']},{c['shape']},{c['mesh']},{c['chips']},"
            f"{r['t_compute_s']:.4f},{r['t_memory_s']:.4f},"
            f"{r['t_collective_s']:.4f},{r['bottleneck']},"
            f"{r['useful_flops_frac']:.3f},{r['roofline_frac']:.3f},{mem:.2f}")


def main():
    print("arch,shape,mesh,chips,t_compute_s,t_memory_s,t_collective_s,"
          "bottleneck,useful_flops_frac,roofline_frac,mem_GB_per_dev")
    for mesh in ("single", "multi"):
        for c in load(mesh):
            print(fmt_row(c))


if __name__ == "__main__":
    main()
