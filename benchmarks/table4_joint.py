"""Table 4/5 analogue: joint pruning + INT4 quantization — AWP's native
joint recipe vs the sequential AWQ+Wanda / Wanda+AWQ pipelines, plus the
§4.3 headline: INT4 + 75% pruning beats INT2 at equal effective bits."""
from benchmarks.common import trained_bench_model, ppl
from repro.core.compress import compress_model
from repro.core.specs import JointSpec, QuantSpec

RATIOS = (0.25, 0.5, 0.75)
METHODS = ("awq_wanda", "wanda_awq", "awp_joint")


def run():
    model, params, calib, eval_batches = trained_bench_model()
    rows = []
    table = {}
    for method in METHODS:
        for ratio in RATIOS:
            cfg = JointSpec(method=method, ratio=ratio, bits=4,
                            group_size=64)
            cp, _ = compress_model(model, params, calib, cfg)
            p = ppl(model, cp, eval_batches)
            table[(method, ratio)] = p
            rows.append((method, ratio, p))
    # INT2 reference for the equal-effective-bits comparison
    cfg2 = QuantSpec(method="awp_quant", bits=2, group_size=64)
    cp2, _ = compress_model(model, params, calib, cfg2)
    p_int2 = ppl(model, cp2, eval_batches)
    rows.append(("awp_quant_int2", 0.0, p_int2))
    checks = {
        "awp_joint_best@0.5": table[("awp_joint", 0.5)] <= min(
            table[("wanda_awq", 0.5)], table[("awq_wanda", 0.5)]) * 1.05,
        "int4+75%_vs_int2_ratio(see EXPERIMENTS.md scale note)": round(
            table[("awp_joint", 0.75)] / p_int2, 2),
    }
    return rows, checks


def main():
    rows, checks = run()
    print("method,ratio,ppl")
    for m, r, p in rows:
        print(f"{m},{r},{p:.4f}")
    for k, v in checks.items():
        print(f"check,{k},{v}")


if __name__ == "__main__":
    main()
