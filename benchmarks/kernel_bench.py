"""Kernel microbenchmarks: Pallas (interpret on CPU; native on TPU) vs the
jnp oracle, with FLOP-derived throughput. On this CPU container the µs are
indicative only — the structural payload is the HLO/roofline work in
benchmarks/roofline_report.py."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops
from repro.quant.qtensor import QTensor


def run():
    rng = np.random.default_rng(0)
    rows = []
    m, k = 256, 512
    w = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    th = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, k)), jnp.float32)

    flops = 2 * m * k * k
    for use in (True, False):
        us = timed(lambda: ops.awp_pgd_step(w, th, c, 0.1, use_pallas=use))
        rows.append((f"awp_pgd_step[{'pallas' if use else 'jnp'}]", us,
                     f"{flops / us / 1e3:.1f}GFLOP/s"))

    for use in (True, False):
        us = timed(lambda: ops.topk_row(w, k // 2, use_pallas=use))
        rows.append((f"topk_row[{'pallas' if use else 'jnp'}]", us,
                     f"{m * k / us:.0f}elem/us"))

    for use in (True, False):
        us = timed(lambda: ops.quant_project(w, 4, 128, use_pallas=use))
        rows.append((f"quant_proj[{'pallas' if use else 'jnp'}]", us,
                     f"{m * k / us:.0f}elem/us"))

    qt = QTensor.from_dense(w, 4, 128)
    x = jnp.asarray(rng.normal(size=(64, k)), jnp.float32)
    for use in (True, False):
        us = timed(lambda: ops.dequant_matmul(x, qt.packed, qt.scale, qt.zero,
                                              128, use_pallas=use))
        rows.append((f"dequant_matmul[{'pallas' if use else 'jnp'}]", us,
                     f"{2 * 64 * m * k / us / 1e3:.1f}GFLOP/s"))
    return rows


def main():
    print("kernel,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
