"""Kernel microbenchmarks: Pallas (interpret on CPU; native on TPU) vs the
jnp oracle, with FLOP-derived throughput. On this CPU container the µs are
indicative only — the structural payload is the HLO/roofline work in
benchmarks/roofline_report.py. Emits machine-readable
``results/BENCH_kernels.json`` alongside the stdout CSV."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json, timed
from repro.kernels import ops
from repro.quant.qtensor import QTensor
from repro.serving.kv_cache import kv_quantize

_BACKEND = jax.default_backend()
_INTERPRET = ops._interpret()


def _row(name, us, derived_value, derived_unit):
    # every row carries where it ran: Pallas kernels execute in interpret
    # mode off-TPU, so CPU µs are comparable only to other interpret rows
    return {"kernel": name, "us_per_call": round(us, 1),
            "derived_value": round(derived_value, 1),
            "derived_unit": derived_unit,
            "backend": _BACKEND, "interpret": _INTERPRET}


def run():
    rng = np.random.default_rng(0)
    rows = []
    m, k = 256, 512
    w = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    th = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, k)), jnp.float32)

    flops = 2 * m * k * k
    for use in (True, False):
        us = timed(lambda: ops.awp_pgd_step(w, th, c, 0.1, use_pallas=use))
        rows.append(_row(f"awp_pgd_step[{'pallas' if use else 'jnp'}]", us,
                         flops / us / 1e3, "GFLOP/s"))

    # batched (shape-bucket) form: B small problems as one program
    b, mb, kb = 8, 64, 128
    w_b = jnp.asarray(rng.normal(size=(b, mb, kb)), jnp.float32)
    th_b = jnp.asarray(rng.normal(size=(b, mb, kb)), jnp.float32)
    c_b = jnp.asarray(rng.normal(size=(b, kb, kb)), jnp.float32)
    eta_b = jnp.full((b,), 0.1, jnp.float32)
    bflops = 2 * b * mb * kb * kb
    for use in (True, False):
        us = timed(lambda: ops.awp_pgd_step(w_b, th_b, c_b, eta_b,
                                            use_pallas=use))
        rows.append(_row(
            f"awp_pgd_step_batched[{'pallas' if use else 'jnp'}]", us,
            bflops / us / 1e3, "GFLOP/s"))

    for use in (True, False):
        us = timed(lambda: ops.topk_row(w, k // 2, use_pallas=use))
        rows.append(_row(f"topk_row[{'pallas' if use else 'jnp'}]", us,
                         m * k / us, "elem/us"))

    for use in (True, False):
        us = timed(lambda: ops.quant_project(w, 4, 128, use_pallas=use))
        rows.append(_row(f"quant_proj[{'pallas' if use else 'jnp'}]", us,
                         m * k / us, "elem/us"))

    qt = QTensor.from_dense(w, 4, 128)
    x = jnp.asarray(rng.normal(size=(64, k)), jnp.float32)
    for use in (True, False):
        us = timed(lambda: ops.dequant_matmul(x, qt.packed, qt.scale, qt.zero,
                                              128, use_pallas=use))
        rows.append(_row(f"dequant_matmul[{'pallas' if use else 'jnp'}]", us,
                         2 * 64 * m * k / us / 1e3, "GFLOP/s"))

    rows += _decode_attn_rows(rng)
    return rows


def _decode_attn_rows(rng):
    """Fused flash-decode vs reference dequant-then-attend over the
    slot/paged × dense/INT8 matrix at two cache depths. Derived metric is
    effective KV bandwidth (bytes the dense read would stream) — decode
    attention is memory-bound, so that's the roofline axis. Each fused/ref
    pair is allclose-checked before timing (the benchmark doubles as an
    interpret-mode parity gate)."""
    b, hk, g, d, page = 8, 4, 4, 64, 16
    h = hk * g
    rows = []
    for t in (128, 512):
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(b, t, hk, d)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(b, t, hk, d)), jnp.float32)
        lens = jnp.asarray(rng.integers(1, t + 1, size=b), jnp.int32)
        kv_bytes = 2 * b * t * hk * d * 4            # dense f32 K+V stream
        qk, qv = kv_quantize(kc, d), kv_quantize(vc, d)
        qargs = dict(k_scale=qk.scale, k_zero=qk.zero, v_scale=qv.scale,
                     v_zero=qv.zero, group_size=d)

        # paged pool: identity page mapping is enough for timing — the
        # kernel's gather cost doesn't depend on the permutation
        npg = t // page
        pool_k = kc.reshape(b * npg, page, hk, d)
        pool_v = vc.reshape(b * npg, page, hk, d)
        table = jnp.arange(b * npg, dtype=jnp.int32).reshape(b, npg)
        pk, pv = kv_quantize(pool_k, d), kv_quantize(pool_v, d)
        pargs = dict(k_scale=pk.scale, k_zero=pk.zero, v_scale=pv.scale,
                     v_zero=pv.zero, group_size=d)

        cases = [
            ("slot_dense", lambda use: ops.decode_attn(
                q, kc, vc, lens, use_pallas=use)),
            ("slot_int8", lambda use: ops.decode_attn(
                q, qk.codes, qv.codes, lens, use_pallas=use, **qargs)),
            ("paged_dense", lambda use: ops.decode_attn_paged(
                q, pool_k, pool_v, table, lens, use_pallas=use)),
            ("paged_int8", lambda use: ops.decode_attn_paged(
                q, pk.codes, pv.codes, table, lens, use_pallas=use,
                **pargs)),
        ]
        for name, fn in cases:
            np.testing.assert_allclose(np.asarray(fn(True)),
                                       np.asarray(fn(False)),
                                       atol=1e-4, rtol=1e-4)
            for use in (True, False):
                us = timed(lambda: fn(use))
                label = "fused" if use else "ref"
                rows.append(_row(f"decode_attn_{name}_t{t}[{label}]", us,
                                 kv_bytes / us / 1e3, "GB/s"))
    return rows


def main():
    rows = run()
    print("kernel,us_per_call,derived")
    for r in rows:
        print(f"{r['kernel']},{r['us_per_call']:.1f},"
              f"{r['derived_value']:.1f}{r['derived_unit']}")
    path = emit_json("kernels", {"rows": rows})
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
