"""Kernel microbenchmarks: Pallas (interpret on CPU; native on TPU) vs the
jnp oracle, with FLOP-derived throughput. On this CPU container the µs are
indicative only — the structural payload is the HLO/roofline work in
benchmarks/roofline_report.py. Emits machine-readable
``results/BENCH_kernels.json`` alongside the stdout CSV."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json, timed
from repro.kernels import ops
from repro.quant.qtensor import QTensor


def _row(name, us, derived_value, derived_unit):
    return {"kernel": name, "us_per_call": round(us, 1),
            "derived_value": round(derived_value, 1),
            "derived_unit": derived_unit}


def run():
    rng = np.random.default_rng(0)
    rows = []
    m, k = 256, 512
    w = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    th = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, k)), jnp.float32)

    flops = 2 * m * k * k
    for use in (True, False):
        us = timed(lambda: ops.awp_pgd_step(w, th, c, 0.1, use_pallas=use))
        rows.append(_row(f"awp_pgd_step[{'pallas' if use else 'jnp'}]", us,
                         flops / us / 1e3, "GFLOP/s"))

    # batched (shape-bucket) form: B small problems as one program
    b, mb, kb = 8, 64, 128
    w_b = jnp.asarray(rng.normal(size=(b, mb, kb)), jnp.float32)
    th_b = jnp.asarray(rng.normal(size=(b, mb, kb)), jnp.float32)
    c_b = jnp.asarray(rng.normal(size=(b, kb, kb)), jnp.float32)
    eta_b = jnp.full((b,), 0.1, jnp.float32)
    bflops = 2 * b * mb * kb * kb
    for use in (True, False):
        us = timed(lambda: ops.awp_pgd_step(w_b, th_b, c_b, eta_b,
                                            use_pallas=use))
        rows.append(_row(
            f"awp_pgd_step_batched[{'pallas' if use else 'jnp'}]", us,
            bflops / us / 1e3, "GFLOP/s"))

    for use in (True, False):
        us = timed(lambda: ops.topk_row(w, k // 2, use_pallas=use))
        rows.append(_row(f"topk_row[{'pallas' if use else 'jnp'}]", us,
                         m * k / us, "elem/us"))

    for use in (True, False):
        us = timed(lambda: ops.quant_project(w, 4, 128, use_pallas=use))
        rows.append(_row(f"quant_proj[{'pallas' if use else 'jnp'}]", us,
                         m * k / us, "elem/us"))

    qt = QTensor.from_dense(w, 4, 128)
    x = jnp.asarray(rng.normal(size=(64, k)), jnp.float32)
    for use in (True, False):
        us = timed(lambda: ops.dequant_matmul(x, qt.packed, qt.scale, qt.zero,
                                              128, use_pallas=use))
        rows.append(_row(f"dequant_matmul[{'pallas' if use else 'jnp'}]", us,
                         2 * 64 * m * k / us / 1e3, "GFLOP/s"))
    return rows


def main():
    rows = run()
    print("kernel,us_per_call,derived")
    for r in rows:
        print(f"{r['kernel']},{r['us_per_call']:.1f},"
              f"{r['derived_value']:.1f}{r['derived_unit']}")
    path = emit_json("kernels", {"rows": rows})
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
