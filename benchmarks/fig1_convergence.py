"""Figure 1 analogue: normalized activation-aware loss ‖WC^½−Θ⁽ᵗ⁾C^½‖_F/‖W‖_F
per PGD iteration while AWP-pruning a trained layer."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_bench_model
from repro.core import awp, calibration as calib
from repro.core.compress import get_linear


def run(iters: int = 60):
    model, params, calib_batches, _ = trained_bench_model()
    # capture the first block's mlp_in activations
    h = model.embed(params, calib_batches[0])
    _, caps = model.block_apply_one(params, 0, h, capture=True)
    stats = calib.update(calib.init(caps["mlp_in"].shape[-1]), caps["mlp_in"])
    c = calib.covariance(stats)
    w = get_linear(params, ("blocks", "mlp", "wu"), 0)
    k = w.shape[1] // 2
    res = awp.prune(w, c, k, trace_loss=True, max_iters=iters)
    return np.asarray(res.loss_trace)


def main():
    trace = run()
    print("iter,normalized_loss")
    for i, v in enumerate(trace):
        print(f"{i},{v:.6f}")
    drops = np.diff(trace) <= 1e-4
    print(f"check,monotone_decreasing,{bool(drops.all())}")
    print(f"check,final<initial,{bool(trace[-1] < trace[0])}")


if __name__ == "__main__":
    main()
