"""Benchmark driver — one section per paper table/figure, plus kernels and
the dry-run roofline table. Prints ``name,us_per_call,derived`` style CSV
per section."""
import time
import traceback


def _section(name, main_fn):
    print(f"\n=== {name} ===")
    t0 = time.time()
    try:
        main_fn()
        print(f"--- {name} done in {time.time() - t0:.1f}s")
    except Exception:
        traceback.print_exc()
        print(f"--- {name} FAILED")


def main() -> None:
    from benchmarks import (table1_pruning, table3_quant, table4_joint,
                            fig1_convergence, kernel_bench, roofline_report)
    _section("table1_pruning (paper Tables 1-2)", table1_pruning.main)
    _section("table3_quant (paper Table 3)", table3_quant.main)
    _section("table4_joint (paper Tables 4-5)", table4_joint.main)
    _section("fig1_convergence (paper Figure 1)", fig1_convergence.main)
    _section("kernel_bench", kernel_bench.main)
    _section("roofline_report (EXPERIMENTS.md §Roofline)", roofline_report.main)


if __name__ == "__main__":
    main()
