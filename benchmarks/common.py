"""Shared benchmark fixture: a small LM trained on the Zipf-Markov corpus so
compression methods see *real* (trained, correlated, outlier-bearing)
activation statistics — the paper's regime at reduced scale. Cached on disk
so every table reuses the same model. Plus the machine-readable
``BENCH_*.json`` emitter every perf benchmark shares (see
docs/performance.md for the schema conventions)."""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_tiny_config
from repro.core import metrics
from repro.data import DataConfig, ZipfMarkov, calibration_batches
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.training.train_loop import TrainConfig, make_train_step

CACHE = os.path.join(os.path.dirname(__file__), "..", "results", "bench_model")
TRAIN_STEPS = 200


def trained_bench_model(arch: str = "llama2-7b"):
    """(model, params, calib_batches, eval_batches). The tiny preset of the
    paper's own Table-1 target (llama2-7b family)."""
    cfg = get_tiny_config(arch)
    model = build_model(cfg, remat=False)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=16)
    gen = ZipfMarkov(dc)
    mgr = CheckpointManager(os.path.abspath(CACHE), keep_n=1)
    params_t = model.init(jax.random.PRNGKey(0))
    if mgr.latest_step() == TRAIN_STEPS:
        params, _ = mgr.restore_latest(params_t)
    else:
        tcfg = TrainConfig(optimizer=OptimizerConfig(
            lr=1e-3, warmup_steps=20, total_steps=TRAIN_STEPS))
        step_fn, opt_init = make_train_step(model, tcfg)
        state = {"params": params_t, "opt": opt_init(params_t),
                 "step": jnp.zeros((), jnp.int32)}
        jstep = jax.jit(step_fn, donate_argnums=0)
        for i in range(TRAIN_STEPS):
            t, l = gen.batch(i)
            state, m = jstep(state, {"tokens": jnp.asarray(t),
                                     "labels": jnp.asarray(l)})
        params = state["params"]
        mgr.save(TRAIN_STEPS, params)
    calib = [{"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
             for t, l in calibration_batches(dc, 4)]
    eval_batches = [gen.batch(5000 + i) for i in range(4)]
    return model, params, calib, eval_batches


def ppl(model, params, eval_batches) -> float:
    def loss_fn(params, tokens, labels):
        _, m = jax.jit(model.loss)(params, {"tokens": tokens,
                                            "labels": labels})
        return m["sum_nll"], m["tokens"]
    return metrics.perplexity(
        loss_fn, params,
        [(jnp.asarray(t), jnp.asarray(l)) for t, l in eval_batches])


def timed(fn, *args, reps: int = 3):
    fn(*args)                                   # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # µs


RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def emit_json(name: str, payload: dict) -> str:
    """Write ``results/BENCH_<name>.json`` and return its path.

    The machine-readable sibling of each benchmark's stdout table, so the
    perf trajectory is diffable across PRs (and uploadable as a CI
    artifact). Adds ``benchmark``/``backend``/``timestamp`` keys unless the
    caller already set them."""
    path = os.path.abspath(os.path.join(RESULTS_DIR, f"BENCH_{name}.json"))
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = dict(payload)
    payload.setdefault("benchmark", name)
    payload.setdefault("backend", jax.default_backend())
    payload.setdefault("timestamp",
                       time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()))
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path
