"""Serving throughput: dense weights vs packed QTensor leaves.

Compresses a tiny LM to int4 (RTN — quantization grid identical to the AWP
packing, cheap to build), writes the packed checkpoint, and times batched
prefill + greedy decode twice: once on the dense-dequantized params and
once serving straight from the QTensor-leaf tree. Reports tok/s and the
quantized-layer weight bytes resident in each param tree.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]

Emits ``results/BENCH_serve.json`` via the shared emitter (CI uploads it
next to the other BENCH artifacts). On CPU the packed path runs the
reference dequant-matmul; on TPU it runs the fused Pallas kernel — the
JSON records the backend.
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json
from repro.checkpoint import load_packed_checkpoint, save_packed_checkpoint
from repro.configs import get_tiny_config
from repro.core.compress import compress_model
from repro.core.specs import Policy, QuantSpec
from repro.launch.serve import (make_step_fns, packed_weight_bytes,
                                qtensor_leaves)
from repro.models import build_model, make_batch
from repro.obs import MetricsRegistry, snapshot_series


def bench_serving(model, params, prompts, gen_len: int, reps: int = 2):
    """(prefill tok/s, decode tok/s) for greedy generation, best of reps
    (first rep also pays compilation)."""
    b, prompt = prompts.shape
    prefill, decode = make_step_fns(model)
    best_pre = best_dec = 0.0
    for _ in range(reps + 1):                      # +1 warm/compile pass
        cache = model.init_cache(b, prompt + gen_len, jnp.float32)
        t0 = time.perf_counter()
        tok, cache = prefill(params, {"tokens": prompts}, cache)
        jax.block_until_ready(tok)
        t_pre = time.perf_counter() - t0
        t1 = time.perf_counter()
        for _ in range(gen_len - 1):
            tok, cache = decode(params, tok, cache)
        jax.block_until_ready(tok)
        t_dec = time.perf_counter() - t1
        best_pre = max(best_pre, b * prompt / t_pre)
        best_dec = max(best_dec, b * (gen_len - 1) / max(t_dec, 1e-9))
    return best_pre, best_dec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: batch 4, prompt 16, gen 8")
    args = ap.parse_args()
    if args.smoke:
        args.batch, args.prompt_len, args.gen = 4, 16, 8

    cfg = get_tiny_config(args.arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    calib = [make_batch(cfg, jax.random.PRNGKey(1), 4, args.prompt_len)]
    reg = MetricsRegistry()
    cp, report = compress_model(
        model, params, calib,
        Policy({"*": QuantSpec(method="rtn", bits=4, group_size=32)}),
        metrics=reg)
    # compression telemetry off the registry the compress pass recorded
    # into: layer count, mean residual, dispatch wall per engine
    snap = reg.snapshot()
    res_h = snapshot_series(snap, "histograms", "compress_residual")
    blk_h = snapshot_series(snap, "histograms", "compress_block_seconds")
    layers = snapshot_series(snap, "counters", "compress_layers_total")
    compression = {
        "layers": int(layers["value"]) if layers else 0,
        "mean_residual": (res_h["sum"] / res_h["count"]
                          if res_h and res_h["count"] else 0.0),
        "block_seconds": blk_h["sum"] if blk_h else 0.0,
        "blocks": int(blk_h["count"]) if blk_h else 0,
    }
    assert compression["layers"] == len(report), \
        "compression telemetry must count every compressed layer"
    path = save_packed_checkpoint(tempfile.mkdtemp(prefix="serve_bench_"),
                                  0, cp, report)
    packed_params, qts, _ = load_packed_checkpoint(path, params)
    packed_b, dense_equiv = packed_weight_bytes(packed_params)
    n_qleaves = len(qtensor_leaves(packed_params))
    assert n_qleaves > 0, "packed tree has no QTensor leaves"

    prompts = make_batch(cfg, jax.random.PRNGKey(2), args.batch,
                         args.prompt_len)["tokens"]
    dense_pre, dense_dec = bench_serving(model, cp, prompts, args.gen)
    packed_pre, packed_dec = bench_serving(model, packed_params, prompts,
                                           args.gen)

    rows = [("dense", dense_pre, dense_dec, dense_equiv),
            ("packed", packed_pre, packed_dec, packed_b)]
    print(f"serve bench: {args.arch} tiny, batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    for name, pre, dec, wb in rows:
        print(f"  {name:7s} prefill {pre:8.0f} tok/s   decode {dec:8.0f} "
              f"tok/s   quantized-layer weights {wb / 1e6:7.2f} MB")
    print(f"  compress {compression['layers']} layers in "
          f"{compression['blocks']} blocks, "
          f"{compression['block_seconds']:.2f}s dispatch wall, "
          f"mean residual {compression['mean_residual']:.3e}")

    out = emit_json("serve", {
        "arch": args.arch,
        "batch": args.batch, "prompt_len": args.prompt_len, "gen": args.gen,
        "n_qtensor_leaves": n_qleaves,
        "compression": compression,
        "dense": {"prefill_tok_s": dense_pre, "decode_tok_s": dense_dec,
                  "weight_bytes": dense_equiv},
        "packed": {"prefill_tok_s": packed_pre, "decode_tok_s": packed_dec,
                   "weight_bytes": packed_b},
        "compression_x": dense_equiv / max(packed_b, 1),
    })
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
